"""Population-scale client engine: lazy populations, cohort scheduling,
bit-identity with the dense path, subsampling-amplified accounting."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GFLConfig
from repro.core.population import (
    CohortScheduler,
    DensePopulation,
    DirichletPopulation,
    SyntheticPopulation,
    estimate_w_ref,
    parse_cohort_spec,
    parse_population_spec,
    parse_trace_spec,
    population_from_spec,
    run_gfl_population,
    uniform_cohort_batch,
)
from repro.core.resilience import TopologyProcess
from repro.core.simulate import (
    base_combination_matrix,
    generate_problem,
    run_gfl,
    sample_round_batches,
)
from repro.data.partition import dirichlet_partition


@pytest.fixture(scope="module")
def problem():
    return generate_problem(jax.random.PRNGKey(0), P=4, K=6, N=30, M=2)


# ------------------------------------------------- the regression anchor --
#
# run_gfl and sample_round_batches now DELEGATE to the population engine,
# so comparing them against run_gfl_population would be circular.  The
# reference below re-implements the ORIGINAL pre-engine dense program
# verbatim (direct fancy-indexing sampler + the run_gfl loop as it stood
# before the delegation) — the engine must stay bit-identical to THIS,
# independent of how the production code is wired.


def _dense_reference_sample(key, prob, L, batch_size):
    """The original sample_round_batches body (pre-delegation), verbatim."""
    P, K, N, M = prob.features.shape
    kc, kb = jax.random.split(key)

    def pick_clients(k):
        return jax.random.choice(k, K, (L,), replace=False)

    client_idx = jax.vmap(pick_clients)(jax.random.split(kc, P))

    def pick_batch(k):
        return jax.random.choice(k, N, (batch_size,), replace=False)

    batch_idx = jax.vmap(pick_batch)(
        jax.random.split(kb, P * L)).reshape(P, L, batch_size)
    p_idx = jnp.arange(P)[:, None, None]
    h = prob.features[p_idx, client_idx[:, :, None], batch_idx]
    g = prob.labels[p_idx, client_idx[:, :, None], batch_idx]
    return (h, g)


def _dense_reference_run(prob, cfg, *, iters, batch_size, seed):
    """The original run_gfl loop (pre-delegation), verbatim."""
    from repro.core import gfl
    from repro.core.simulate import make_grad_fn

    P = prob.features.shape[0]
    A = base_combination_matrix(cfg, P)
    step = gfl.make_gfl_step(jnp.asarray(A), make_grad_fn(prob.rho), cfg)
    L = cfg.effective_clients
    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    state = gfl.init_state(k_init, P, prob.w_opt.shape[0])
    sample = jax.jit(
        lambda k: _dense_reference_sample(k, prob, L, batch_size))
    msd = []
    for _ in range(iters):
        key, kb = jax.random.split(key)
        state = step(state, sample(kb))
        wc = gfl.centroid(state.params)
        msd.append(float(jnp.sum((wc - prob.w_opt) ** 2)))
    return np.asarray(msd), state.params


@pytest.mark.parametrize("scheme", ["none", "iid_dp", "hybrid"])
def test_full_participation_bit_identical(problem, scheme):
    """THE anchor: the population engine with L = K and an always-available
    trace reproduces the paper's original dense program bit-for-bit (and
    run_gfl, which now delegates, still does too)."""
    cfg = GFLConfig(num_servers=4, clients_per_server=6, privacy=scheme,
                    sigma_g=0.3, mu=0.1, topology="ring", grad_bound=10.0)
    msd_ref, par_ref = _dense_reference_run(problem, cfg, iters=6,
                                            batch_size=5, seed=3)
    res = run_gfl_population(problem, cfg, iters=6, batch_size=5, seed=3)
    assert np.array_equal(msd_ref, res.msd)
    assert np.array_equal(np.asarray(par_ref), np.asarray(res.params))
    msd_d, par_d = run_gfl(problem, cfg, iters=6, batch_size=5, seed=3)
    assert np.array_equal(msd_ref, msd_d)
    assert np.array_equal(np.asarray(par_ref), np.asarray(par_d))


def test_subsampled_pure_path_bit_identical(problem):
    """The pure cohort path (uniform, always-available) is the original
    dense program at any L, not just full participation."""
    cfg = GFLConfig(num_servers=4, clients_per_server=6, clients_sampled=3,
                    privacy="none", mu=0.1, topology="ring")
    _, par_ref = _dense_reference_run(problem, cfg, iters=5, batch_size=5,
                                      seed=1)
    res = run_gfl_population(problem, cfg, iters=5, batch_size=5, seed=1)
    assert np.array_equal(np.asarray(par_ref), np.asarray(res.params))
    np.testing.assert_allclose(res.q, 0.5)  # L/K recorded per round


def test_sample_round_batches_is_population_gather(problem):
    """simulate.sample_round_batches, the engine's cohort sampler, and the
    original fancy-indexing sampler are the same program."""
    key = jax.random.PRNGKey(9)
    h0, g0 = _dense_reference_sample(key, problem, 3, 5)
    h1, g1 = sample_round_batches(key, problem, 3, 5)
    pop = DensePopulation.from_problem(problem)
    h2, g2 = uniform_cohort_batch(key, pop, 3, 5)
    for h, g in ((h1, g1), (h2, g2)):
        assert np.array_equal(np.asarray(h0), np.asarray(h))
        assert np.array_equal(np.asarray(g0), np.asarray(g))


# ------------------------------------------------------- lazy populations --


def test_synthetic_population_deterministic_and_lazy():
    pop = SyntheticPopulation(3, 10_000, mode="hetero", N=40, M=2,
                              data_seed=5)
    h1, g1 = pop.client_shard(1, 9_999)
    h2, g2 = pop.client_shard(1, 9_999)
    assert np.array_equal(np.asarray(h1), np.asarray(h2))
    assert np.array_equal(np.asarray(g1), np.asarray(g2))
    h3, _ = pop.client_shard(1, 9_998)
    assert not np.array_equal(np.asarray(h1), np.asarray(h3))
    # lazy: no [P, K, ...] tensor anywhere on the object
    assert not any(hasattr(pop, a) for a in ("features", "labels"))
    # cohort gather materializes exactly [P, L, B, M]
    idx = jnp.asarray([[0, 42], [9_999, 17], [123, 4_567]])
    bidx = jnp.tile(jnp.arange(5)[None, None], (3, 2, 1))
    h, g = pop.gather(idx, bidx)
    assert h.shape == (3, 2, 5, 2) and g.shape == (3, 2, 5)
    # the gathered rows are the (server 1, client 9999) shard rows
    np.testing.assert_array_equal(np.asarray(h[1, 0]), np.asarray(h1[:5]))


def test_iid_vs_hetero_sigma():
    """iid mode uses one global sigma; hetero draws per-client scales."""
    iid = SyntheticPopulation(1, 50, mode="iid", sigma=1.0, N=400)
    het = SyntheticPopulation(1, 50, mode="hetero", lo=0.5, hi=1.5, N=400)

    def residual_std(pop, k):
        h, g = pop.client_shard(0, k)
        return float(jnp.std(h - g[:, None]))

    iid_stds = [residual_std(iid, k) for k in range(8)]
    het_stds = [residual_std(het, k) for k in range(8)]
    assert np.std(iid_stds) < 0.05          # all clients alike
    assert np.std(het_stds) > 2 * np.std(iid_stds)  # clients differ


def test_mixture_cluster_structure():
    pop = SyntheticPopulation(1, 100, mode="mixture", clusters=4, drift=1.0)
    m0 = np.asarray(pop._client_mean(jnp.asarray(0)))
    m4 = np.asarray(pop._client_mean(jnp.asarray(4)))   # same cluster
    m1 = np.asarray(pop._client_mean(jnp.asarray(1)))   # different cluster
    np.testing.assert_array_equal(m0, m4)
    assert np.abs(m0 - m1).max() > 1e-3


def test_population_spec_grammar():
    assert parse_population_spec("dense").kind == "dense"
    s = parse_population_spec("synthetic:mixture,clusters=8,drift=0.25")
    assert s.kind == "mixture" and s.args == {"clusters": 8, "drift": 0.25}
    assert parse_population_spec("dirichlet:0.3").args["alpha"] == 0.3
    for bad in ("synthetic:what", "dense:x", "nope", "synthetic:iid,x"):
        with pytest.raises(ValueError):
            parse_population_spec(bad)
    cfg = GFLConfig(num_servers=2, clients_per_server=7,
                    population="synthetic:iid,n=20,dim=3", data_seed=3)
    pop = population_from_spec(cfg)
    assert (pop.P, pop.num_clients, pop.samples_per_client, pop.dim) \
        == (2, 7, 20, 3)
    with pytest.raises(ValueError):
        population_from_spec(GFLConfig(population="dense"))


def test_estimate_w_ref_recovers_dense_optimum(problem):
    pop = DensePopulation.from_problem(problem)
    w = estimate_w_ref(pop, sample_clients=pop.num_clients, iters=3000)
    np.testing.assert_allclose(np.asarray(w), np.asarray(problem.w_opt),
                               atol=1e-3)


# --------------------------------------------------- dirichlet partition --


def test_dirichlet_partition_assigns_every_index_exactly_once():
    rng = np.random.default_rng(0)
    for seed in range(4):
        labels = rng.integers(0, 5, size=237)
        out = dirichlet_partition(labels, P=3, K=4, alpha=0.2, seed=seed)
        flat = np.concatenate([a for row in out for a in row])
        assert len(flat) == len(labels)
        assert np.array_equal(np.sort(flat), np.arange(len(labels)))


def test_dirichlet_partition_min_per_client():
    rng = np.random.default_rng(1)
    labels = rng.integers(0, 2, size=60)
    out = dirichlet_partition(labels, P=4, K=5, alpha=0.05, seed=2,
                              min_per_client=2)
    sizes = [len(a) for row in out for a in row]
    assert min(sizes) >= 2 and sum(sizes) == 60
    with pytest.raises(ValueError):
        dirichlet_partition(labels, P=4, K=5, alpha=0.05, min_per_client=4)


def test_dirichlet_partition_skew_tracks_alpha():
    rng = np.random.default_rng(3)
    labels = rng.integers(0, 4, size=2000)

    def mean_majority_share(alpha):
        out = dirichlet_partition(labels, P=2, K=5, alpha=alpha, seed=7)
        shares = []
        for row in out:
            for idx in row:
                if len(idx) == 0:
                    continue
                _, counts = np.unique(labels[idx], return_counts=True)
                shares.append(counts.max() / counts.sum())
        return float(np.mean(shares))

    assert mean_majority_share(0.05) > mean_majority_share(100.0) + 0.2


def test_dirichlet_population_wiring():
    pop = DirichletPopulation.synthetic_pool(3, 8, alpha=0.2, pool=600,
                                             data_seed=1)
    assert pop.index.shape[:2] == (3, 8)
    cfg = GFLConfig(num_servers=3, clients_per_server=8, clients_sampled=4,
                    privacy="none", topology="full")
    res = run_gfl_population(pop, cfg, iters=4, batch_size=5, seed=0)
    assert np.isfinite(res.msd).all()


# --------------------------------------------------------- cohort scheduling


def test_trace_spec_grammar_and_bounds():
    t = parse_trace_spec("diurnal,period=12,min=0.3")
    p0 = t.probs(0, 48)
    assert p0.shape == (48,) and (p0 >= 0.3 - 1e-12).all() \
        and (p0 <= 1.0 + 1e-12).all()
    # phases spread clients around the clock: some high, some low
    assert p0.max() - p0.min() > 0.3
    d = parse_trace_spec("devclass,slow=0.5,p=0.2")
    pd = d.probs(0, 1000)
    assert set(np.unique(pd).tolist()) == {0.2, 1.0}
    assert 0.3 < (pd == 0.2).mean() < 0.7
    for bad in ("diurnal,xyz=1", "nope", "devclass,period=3"):
        with pytest.raises(ValueError):
            parse_trace_spec(bad)


def test_cohort_spec_grammar():
    assert parse_cohort_spec("uniform")[0] == "uniform"
    sampler, floor, trace = parse_cohort_spec(
        "importance,floor=0.25+trace:diurnal,period=6,min=0.1")
    assert sampler == "importance" and floor == 0.25
    assert trace.kind == "diurnal" and trace.period == 6
    with pytest.raises(ValueError):
        parse_cohort_spec("fancy")


def test_scheduler_pure_path_and_q():
    s = CohortScheduler(K=20, L=5, P=3)
    assert s.pure
    sel = s.select(jax.random.PRNGKey(0), 0)
    assert sel.weights is None and sel.alive is None
    assert sel.client_idx.shape == (3, 5)
    assert sel.q == pytest.approx(0.25)
    # without replacement on the pure path
    for row in np.asarray(sel.client_idx):
        assert len(set(row.tolist())) == 5


def test_scheduler_availability_deterministic_and_respected():
    s = CohortScheduler(K=30, L=4, P=2, trace="diurnal,period=8,min=0.1",
                        seed=11)
    a1, a2 = s.availability(3), s.availability(3)
    assert np.array_equal(a1, a2)
    assert a1.any(axis=1).all()       # forced survivor per server
    sel = s.select(jax.random.PRNGKey(1), 3)
    # sampled ids must all be available; weights recorded, q in (0, 1]
    for p in range(2):
        assert a1[p, np.asarray(sel.client_idx[p])].all()
    assert sel.weights is not None and np.isfinite(
        np.asarray(sel.weights)).all()
    assert 0 < sel.q <= 1.0


def test_scheduler_dropout_matches_topology_process():
    """Same seed => the scheduler and the resilience process realize the
    SAME per-round dropout masks (shared stream constants)."""
    cfg = GFLConfig(num_servers=4, topology="ring", fault="dropout:0.4",
                    topology_seed=13)
    s = CohortScheduler(K=50, L=6, P=4, fault="dropout:0.4", seed=13)
    proc = TopologyProcess(base_combination_matrix(cfg, 4), "dropout:0.4",
                           seed=13)
    for i in (0, 3, 17):
        np.testing.assert_array_equal(s.client_alive(i),
                                      proc.client_alive(i, 6))


def test_importance_scheduler_feedback():
    s = CohortScheduler(K=12, L=4, P=2, sampler="importance", seed=0)
    assert not s.pure
    sel = s.select(jax.random.PRNGKey(2), 0)
    norms = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (2, 4))) + 5.0
    before = np.asarray(s.is_state.norm_est).copy()
    s.observe(sel.client_idx, norms)
    assert not np.array_equal(before, np.asarray(s.is_state.norm_est))
    probs = s.effective_probs(np.ones((2, 12), bool))
    np.testing.assert_allclose(np.asarray(probs.sum(axis=1)), 1.0,
                               atol=1e-6)


# -------------------------------------------------------- engine behavior --


def test_weighted_engine_runs_with_everything_on():
    cfg = GFLConfig(num_servers=4, clients_per_server=50, clients_sampled=5,
                    privacy="iid_dp", sigma_g=0.1, mu=0.1, topology="ring",
                    population="synthetic:mixture,clusters=3,drift=0.7",
                    cohort="importance,floor=0.2+trace:diurnal,period=12,"
                           "min=0.3",
                    fault="dropout:0.3")
    res = run_gfl_population(None, cfg, iters=6, batch_size=5, seed=0)
    assert np.isfinite(res.msd).all()
    assert res.q.shape == (6,) and ((res.q > 0) & (res.q <= 1)).all()


def test_weighted_engine_rejects_unsafe_dropout_and_stragglers():
    cfg = GFLConfig(num_servers=3, clients_per_server=20, clients_sampled=4,
                    privacy="hybrid", sigma_g=0.2, topology="ring",
                    population="synthetic:iid",
                    cohort="uniform+trace:devclass",
                    fault="straggler:0.3,stale=2")
    with pytest.raises(ValueError, match="straggler"):
        run_gfl_population(None, cfg, iters=2, batch_size=5, seed=0)


def test_scan_executor_matches_streaming_loop():
    cfg = GFLConfig(num_servers=4, clients_per_server=200,
                    clients_sampled=5, privacy="none", mu=0.1,
                    topology="ring", population="synthetic:hetero")
    res_loop = run_gfl_population(None, cfg, iters=5, batch_size=5, seed=0)
    res_scan = run_gfl_population(None, cfg, iters=5, batch_size=5, seed=0,
                                  scan=True)
    np.testing.assert_allclose(res_loop.msd, res_scan.msd, rtol=1e-4,
                               atol=1e-6)


def test_engine_surfaces_gap_and_staleness_trajectories(problem):
    """Fault runs surface the resilience runtime's per-round realizations
    instead of dropping them: the realized spectral-gap trajectory and
    (pure path) the per-server straggler psi ages."""
    cfg = GFLConfig(num_servers=4, clients_per_server=6, privacy="none",
                    topology="ring",
                    fault="links:0.2+straggler:0.4,stale=3",
                    topology_seed=5)
    res = run_gfl_population(problem, cfg, iters=8, batch_size=5, seed=0)
    proc = TopologyProcess(base_combination_matrix(cfg, 4), cfg.fault,
                           seed=5)
    assert res.gaps is not None and res.gaps.shape == (8,)
    np.testing.assert_allclose(res.gaps, proc.gap_trajectory(8))
    assert res.staleness is not None and res.staleness.shape == (8, 4)
    assert res.staleness.min() >= 0 and res.staleness.max() <= 3
    assert res.staleness.max() > 0    # stragglers actually aged psi
    # weighted path surfaces gaps too (no stragglers there)
    cfg_w = GFLConfig(num_servers=4, clients_per_server=50,
                      clients_sampled=5, privacy="iid_dp", sigma_g=0.1,
                      topology="ring", population="synthetic:hetero",
                      cohort="importance", fault="links:0.2",
                      topology_seed=5)
    res_w = run_gfl_population(None, cfg_w, iters=4, batch_size=5, seed=0)
    assert res_w.gaps is not None and res_w.gaps.shape == (4,)
    assert res_w.staleness is None
    # clean runs keep both unset
    cfg_0 = GFLConfig(num_servers=4, clients_per_server=6, privacy="none",
                      topology="ring")
    res_0 = run_gfl_population(problem, cfg_0, iters=3, batch_size=5,
                               seed=0)
    assert res_0.gaps is None and res_0.staleness is None


def test_engine_feeds_amplified_accountant():
    from repro.core.privacy.mechanism import mechanism_for

    cfg = GFLConfig(num_servers=4, clients_per_server=100,
                    clients_sampled=5, privacy="hybrid", sigma_g=0.5,
                    topology="ring", population="synthetic:hetero")
    res = run_gfl_population(None, cfg, iters=10, batch_size=5, seed=0)
    acc = mechanism_for(cfg).accountant()
    acc.advance(10, q=res.scheduler.realized_q)
    assert acc.amplified_epsilon() < acc.epsilon()
    assert acc.amplified_epsilon(1.0) == pytest.approx(acc.epsilon())


# ----------------------------------------------------- mesh integration ---


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_mesh_cohort_weights_runtime_arg():
    """cohort_weights on the mesh train step: all-ones reproduces the
    unweighted step, non-uniform weights change it; virtual client ids
    flow through federated_token_batches."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import GFLConfig
        from repro.configs.registry import get_config
        from repro.core.population import CohortScheduler
        from repro.data import TokenStream, federated_token_batches
        from repro.launch import steps as S
        from repro.launch.mesh import make_test_mesh
        from repro.models import Model

        mesh = make_test_mesh((2, 2), ("data", "model"))
        cfg = get_config("smollm-135m").reduced()
        model = Model(cfg)
        gfl = GFLConfig(topology="ring", privacy="none", mu=0.05,
                        grad_bound=10.0, combine_impl="dense")
        stream = TokenStream(vocab=cfg.vocab_size, seed=0)
        sched = CohortScheduler(1000, 2, 2,
                                trace="devclass,slow=0.5,p=0.4", seed=0)
        sel = sched.select(jax.random.PRNGKey(7), 0)
        with mesh:
            step = jax.jit(S.make_train_step(model, gfl, mesh))
            state = S.init_train_state(model, gfl, mesh,
                                       jax.random.PRNGKey(0))
            batch = federated_token_batches(stream, 0, 0, P=2, L=2,
                                            per_client=2, seq_len=16,
                                            client_ids=sel.client_idx)
            s_plain, _ = step(state, batch)
            s_ones, _ = step(state, batch,
                             cohort_weights=jnp.ones((2, 2)))
            s_wgt, _ = step(state, batch,
                            cohort_weights=jnp.asarray([[2.0, 0.5],
                                                        [1.5, 1.0]]))
            s_sched, _ = step(state, batch, cohort_weights=sel.weights)
        t0 = np.asarray(jax.device_get(s_plain.params["embed"]["table"]))
        t1 = np.asarray(jax.device_get(s_ones.params["embed"]["table"]))
        t2 = np.asarray(jax.device_get(s_wgt.params["embed"]["table"]))
        t3 = np.asarray(jax.device_get(s_sched.params["embed"]["table"]))
        np.testing.assert_allclose(t0, t1, atol=1e-6)
        assert np.isfinite(t2).all() and np.isfinite(t3).all()
        # non-uniform weights change the update
        assert np.abs(t2 - t0).max() > 1e-7
        print("OK")
    """
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout
