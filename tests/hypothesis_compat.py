"""Degrade hypothesis property tests to skips when hypothesis is missing.

The suite must not ERROR at collection on a machine without the dev extras
(pip install -r requirements-dev.txt): test modules import `given`,
`settings`, `st` from here instead of from hypothesis directly.  With
hypothesis installed this is a pass-through; without it, @given(...) marks
the test skipped (finer-grained than a module-level
pytest.importorskip("hypothesis"), which would also skip the many
example-based tests sharing those files).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed "
                   "(pip install -r requirements-dev.txt)")

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """st.integers(...) etc. — return placeholders; the test is
        skip-marked before the strategy would ever be drawn from."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
