"""Privacy substrate: exact cancellation identities (eqs. 23, 25) and the
Theorem-2 accountant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.configs.base import GFLConfig
from repro.core.gfl import pairwise_masks_vec, server_aggregate
from repro.core.privacy import (
    PrivacyAccountant,
    homomorphic_noise_matrix,
    sample_laplace,
    sensitivity,
    sigma_for_epsilon,
)
from repro.core.privacy.accountant import epsilon_at
from repro.core.privacy.homomorphic import homomorphic_combine_noise
from repro.core.privacy.secure_agg import masked_client_mean, pairwise_masks
from repro.core.topology import combination_matrix


# --------------------------------------------------------------- eq. (23) --


@given(L=st.integers(2, 12), dim=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_pairwise_masks_cancel_exactly(L, dim, seed):
    key = jax.random.PRNGKey(seed)
    masks = pairwise_masks_vec(key, L, dim, scale=3.0)
    # eq. 23: sum over clients is exactly zero (antisymmetric construction)
    assert np.abs(np.asarray(masks.sum(axis=0))).max() < 1e-4


def test_masked_mean_reveals_only_aggregate():
    key = jax.random.PRNGKey(0)
    upd = jax.random.normal(jax.random.fold_in(key, 1), (6, 32))
    agg = masked_client_mean(upd, key, mask_scale=5.0)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(upd.mean(0)),
                               atol=1e-4)
    # but individual masked updates differ wildly from the raw ones
    masks = pairwise_masks(key, 6, 32, 5.0)
    assert float(jnp.abs(masks).mean()) > 1.0


# --------------------------------------------------------------- eq. (25) --


def _random_doubly_stochastic(P, seed):
    rng = np.random.default_rng(seed)
    A = rng.random((P, P)) + 0.1
    A = (A + A.T) / 2
    for _ in range(200):
        A /= A.sum(0, keepdims=True)
        A = (A + A.T) / 2
    A /= A.sum(0, keepdims=True)
    return A


@given(P=st.integers(2, 12), dim=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_homomorphic_nullspace(P, dim, seed):
    """(1/P) sum_p sum_m a_mp g_mp == 0 for any doubly-stochastic A."""
    A = jnp.asarray(_random_doubly_stochastic(P, seed % 1000), jnp.float32)
    key = jax.random.PRNGKey(seed)
    G = homomorphic_noise_matrix(key, A, dim, sigma=2.0)   # [P,P,dim]
    total = jnp.einsum("mp,mpd->d", A, G) / P
    assert np.abs(np.asarray(total)).max() < 1e-4


def test_homomorphic_combine_matches_materialized():
    P, dim = 6, 40
    A = jnp.asarray(combination_matrix("ring", P), jnp.float32)
    key = jax.random.PRNGKey(3)
    psi = jax.random.normal(jax.random.fold_in(key, 9), (P, dim))
    out = homomorphic_combine_noise(key, A, psi, sigma=0.5)
    G = homomorphic_noise_matrix(key, A, dim, sigma=0.5)
    expected = jnp.einsum("mp,mpd->pd", A, psi[:, None, :] * 0 + psi[:, None, :]) \
        + jnp.einsum("mp,mpd->pd", A, G)
    # centroid of combine output equals centroid of psi (noise cancels)
    np.testing.assert_allclose(np.asarray(out.mean(0)),
                               np.asarray(psi.mean(0)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-3)


def test_iid_noise_does_not_cancel():
    cfg = GFLConfig(privacy="iid_dp", sigma_g=1.0)
    key = jax.random.PRNGKey(0)
    upd = jnp.zeros((8, 64))
    agg = server_aggregate(upd, key, cfg)
    assert float(jnp.abs(agg).mean()) > 0.01  # residual noise present


# ---------------------------------------------------------------- Thm 2 ---


def test_sensitivity_linear_in_iterations():
    assert sensitivity(10, mu=0.1, B=5) == pytest.approx(10.0)
    assert sensitivity(20, 0.1, 5) == 2 * sensitivity(10, 0.1, 5)


def test_theorem2_sigma_epsilon_inverse():
    mu, B, i = 0.1, 10.0, 50
    eps = 2.0
    sig = sigma_for_epsilon(i, mu, B, eps)
    assert epsilon_at(i, mu, B, sig) == pytest.approx(eps)


def test_epsilon_grows_quadratically():
    mu, B, sig = 0.1, 10.0, 0.2
    e = [epsilon_at(i, mu, B, sig) for i in (10, 20, 40)]
    # eps(i) = c (1+i) i: ratio for doubling i approaches 4
    assert 3.5 < e[1] / e[0] < 4.6
    assert 3.7 < e[2] / e[1] < 4.3


def test_accountant_ledger():
    acc = PrivacyAccountant(mu=0.1, grad_bound=10.0, sigma_g=0.2)
    e1 = acc.advance()
    e2 = acc.advance()
    assert e2 > e1 > 0
    assert len(acc.history) == 2
    horizon_sigma = acc.sigma_schedule(100, eps_target=5.0)
    assert epsilon_at(100, 0.1, 10.0, horizon_sigma) == pytest.approx(5.0)


def test_amplified_epsilon_q1_pins_to_unamplified():
    """q = 1 (full participation) reproduces every curve exactly — the
    amplification ledger is a strict generalization, not a new curve."""
    lap = PrivacyAccountant(mu=0.1, grad_bound=10.0, sigma_g=0.5)
    lap.advance(40)
    assert lap.amplified_epsilon(1.0) == pytest.approx(lap.epsilon(),
                                                       rel=1e-9)
    gau = PrivacyAccountant(mu=0.1, grad_bound=10.0, sigma_g=100.0,
                            curve="gaussian", distribution="gaussian")
    gau.advance(25)
    assert gau.amplified_epsilon(1.0) == pytest.approx(gau.epsilon(),
                                                       rel=1e-9)
    assert gau.amplified_delta(1.0) == pytest.approx(gau.delta_spent())
    sch = PrivacyAccountant(mu=0.1, grad_bound=10.0, sigma_g=0.0,
                            curve="scheduled", horizon=50,
                            epsilon_target=4.0)
    sch.advance(50)
    assert sch.amplified_epsilon(1.0) == pytest.approx(sch.epsilon(),
                                                       rel=1e-9)


def test_amplified_epsilon_subsampling_shrinks_budget():
    """q < 1 strictly shrinks the composed epsilon (and q-scales delta);
    realized per-round rates recorded via advance(q=...) are honored."""
    acc = PrivacyAccountant(mu=0.1, grad_bound=10.0, sigma_g=200.0,
                            curve="gaussian", distribution="gaussian")
    acc.advance(10, q=0.1)
    acc.advance(10, q=0.5)
    assert 0 < acc.amplified_epsilon() < acc.epsilon()
    # small-epsilon linear regime: amp(eps, q) ~ q * eps per release
    per = acc.per_release_epsilon(1)
    from repro.core.privacy import amplified_release_epsilon
    assert amplified_release_epsilon(per, 0.01) == pytest.approx(
        0.01 * per, rel=0.05)
    assert acc.amplified_delta() == pytest.approx(
        acc.delta * (10 * 0.1 + 10 * 0.5))
    # ledger bookkeeping: one q per release
    assert len(acc.q_history) == acc.step == 20
    # overflow-guarded large-epsilon branch stays finite and ordered
    big = amplified_release_epsilon(500.0, 0.25)
    assert np.isfinite(big) and big == pytest.approx(
        500.0 + np.log(0.25))


def test_amplification_curve_monotone():
    acc = PrivacyAccountant(mu=0.1, grad_bound=10.0, sigma_g=1.0)
    curve = acc.amplification_curve(20, q=0.2)
    eps = [e for _, e in curve]
    assert all(b > a for a, b in zip(eps, eps[1:]))
    assert eps[-1] < acc.amplification_curve(20, q=1.0)[-1][1]


def test_laplace_variance():
    key = jax.random.PRNGKey(0)
    x = sample_laplace(key, (200_000,), sigma=0.7)
    assert float(jnp.std(x)) == pytest.approx(0.7, rel=0.02)
