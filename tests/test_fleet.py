"""Fleet tier-1 tests: spec grammar properties, crash-atomic checkpoints,
transport round-trips, and the inproc chaos contract — kill + restore a
worker mid-buffer and the run is EXACTLY the uninterrupted one (fold
counts, per-server q-ledgers and accountant epsilon identical).

The multi-process transports (filelog/socket) are exercised by
``examples/fleet_demo.py`` and the nightly ``fleet_chaos`` CI job; tier-1
stays on the inproc substrate so the suite is fast and hermetic.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.core.fleet import (FleetProblem, FleetSpec, chaos_run,
                              parse_fleet_spec, plan_kills)
from repro.core.fleet.transport import (FileLogTransport, Message,
                                        pack_array, unpack_array)
from repro.core.privacy.accountant import PrivacyAccountant
from repro.core.resilience.faults import (STREAM_TOPOLOGY, fault_stream_rng)

# ------------------------------------------------------------ fleet spec


def test_fleet_spec_defaults_and_canonical_form():
    s = parse_fleet_spec("fleet")
    assert s == FleetSpec()
    assert s.to_spec() == "fleet"
    full = parse_fleet_spec(
        "fleet:transport=socket,retry=3,timeout=2.0,backoff=exp")
    assert full.transport == "socket" and full.timeout == 2.0
    # defaults are omitted from the canonical form
    assert full.to_spec() == "fleet:transport=socket,timeout=2"


def test_fleet_spec_rejects_bad_values():
    with pytest.raises(ValueError):
        parse_fleet_spec("fleet:transport=carrier_pigeon")
    with pytest.raises(ValueError):
        parse_fleet_spec("fleet:retry=3,retry=4")
    with pytest.raises(ValueError):
        parse_fleet_spec("fleet:bogus=1")
    with pytest.raises(ValueError):
        FleetSpec(timeout=-1.0)


def _g(x: float) -> float:
    """Pre-canonicalize a float through the spec's %g formatting."""
    return float(f"{x:g}")


if HAVE_HYPOTHESIS:
    _spec_strategy = st.builds(
        FleetSpec,
        transport=st.sampled_from(("inproc", "filelog", "socket")),
        retry=st.integers(min_value=0, max_value=16),
        timeout=st.floats(min_value=0.01, max_value=60.0,
                          allow_nan=False).map(_g),
        backoff=st.sampled_from(("exp", "const")),
        heartbeat=st.floats(min_value=0.01, max_value=10.0,
                            allow_nan=False).map(_g),
        ckpt_every=st.integers(min_value=1, max_value=8))
else:  # placeholder; the @given mark skips before drawing
    _spec_strategy = None


@given(_spec_strategy)
@settings(max_examples=60, deadline=None)
def test_fleet_spec_roundtrip_property(spec):
    canonical = spec.to_spec()
    assert parse_fleet_spec(canonical) == spec
    # canonical form is a fixed point
    assert parse_fleet_spec(canonical).to_spec() == canonical


# ------------------------------------------------------------ checkpoint


def test_checkpoint_publish_is_crash_atomic(tmp_path):
    path = str(tmp_path / "ckpt")
    tree = {"w": np.arange(4.0), "v": np.int64(7)}
    save_checkpoint(path, tree, step=1)

    # a stale staging dir from a crashed writer must not shadow the
    # published checkpoint
    torn = path + ".tmp-99999"
    os.makedirs(torn)
    with open(os.path.join(torn, "manifest.json"), "w") as fh:
        fh.write('{"truncated')
    restored, step = load_checkpoint(path, tree)
    assert step == 1
    np.testing.assert_array_equal(restored["w"], tree["w"])

    # republish over the live checkpoint; new state wins, no debris
    save_checkpoint(path, {"w": np.arange(4.0) * 2, "v": np.int64(8)},
                    step=2)
    restored, step = load_checkpoint(path, tree)
    assert step == 2 and int(restored["v"]) == 8
    leftovers = [d for d in os.listdir(tmp_path)
                 if ".old-" in d or (".tmp-" in d and d != "ckpt.tmp-99999")]
    assert not leftovers, leftovers


def test_checkpoint_rejects_extra_and_missing_keys(tmp_path):
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, {"a": np.ones(2), "b": np.zeros(3)})
    with pytest.raises(ValueError, match="extra"):
        load_checkpoint(path, {"a": np.ones(2)})
    with pytest.raises(ValueError, match="missing"):
        load_checkpoint(path, {"a": np.ones(2), "b": np.zeros(3),
                               "c": np.ones(1)})


def test_checkpoint_bf16_f8_roundtrip_bitexact(tmp_path):
    rng = np.random.default_rng(3)
    tree = {"w": jnp.asarray(rng.normal(size=(5, 3)), jnp.bfloat16),
            "b": jnp.asarray(rng.normal(size=(7,)), jnp.bfloat16)}
    f8 = getattr(jnp, "float8_e4m3fn", None)
    if f8 is not None:
        tree["q"] = jnp.asarray(rng.normal(size=(4,)), f8)
    path = str(tmp_path / "lowprec")
    save_checkpoint(path, tree, step=5)
    restored, step = load_checkpoint(path, tree)
    assert step == 5
    for k, leaf in tree.items():
        got = restored[k]
        assert got.dtype == leaf.dtype, k
        # bit-exact: compare the raw storage bits, not a float cast
        np.testing.assert_array_equal(
            np.asarray(got).view(np.uint8), np.asarray(leaf).view(np.uint8))


# ------------------------------------------------------------- transport


def test_message_and_array_codec_roundtrip():
    psi = np.array([1.5, -2.25, 0.0])
    msg = Message("psi", "worker1", 4, {"psi": pack_array(psi), "q": 0.5})
    back = Message.decode(msg.encode())
    assert back.kind == "psi" and back.sender == "worker1"
    assert back.version == 4 and back.payload["q"] == 0.5
    np.testing.assert_array_equal(unpack_array(back.payload["psi"]), psi)


def test_filelog_transport_replay_and_lag(tmp_path):
    root = str(tmp_path)
    a = FileLogTransport(root, "a")
    b = FileLogTransport(root, "b")
    for v in range(3):
        a.send("b", Message("psi", "a", v, {"v": v}))
    got = [b.recv(timeout=1.0) for _ in range(3)]
    assert [m.version for m in got] == [0, 1, 2]
    assert b.recv(timeout=0.05) is None
    # a torn trailing line (crashed writer mid-append) is tolerated
    with open(os.path.join(root, "b.log"), "a") as fh:
        fh.write('{"kind": "psi", "sen')
    assert b.recv(timeout=0.05) is None
    # a fresh endpoint replays from offset 0: full backlog shows as lag
    b2 = FileLogTransport(root, "b", replay=True)
    assert b2.stats()["replay_lag"] == 3
    a.close(), b.close(), b2.close()


# ---------------------------------------------------------- kill planning


def test_plan_kills_matches_topology_stream():
    P, ticks, outage, seed = 4, 12, 0.5, 7
    plan = plan_kills(f"outage:{outage},kill=1", P, ticks, seed=seed)
    for t in range(ticks):
        rng = fault_stream_rng(seed, STREAM_TOPOLOGY, t)
        down = [p for p, u in enumerate(rng.random(P)) if u < outage]
        assert plan.get(t, []) == down[:P - 1], t
    # masked-only outage (no kill=1) plans nothing
    assert plan_kills(f"outage:{outage}", P, ticks, seed=seed) == {}
    assert plan_kills("none", P, ticks) == {}


# ---------------------------------------------------------- chaos (inproc)


def _ledger_epsilon(prob: FleetProblem, qs) -> float:
    acct = PrivacyAccountant(mu=prob.mu, grad_bound=prob.grad_bound,
                             sigma_g=prob.sigma_g)
    for q in qs:
        acct.advance(1, q=float(q))
    return acct.epsilon()


def test_inproc_chaos_kill_restore_is_exact(tmp_path):
    """Kill worker 1 mid-buffer at tick 2 (buffer=4, events=3: 3 folded
    arrivals pending).  Write-ahead checkpointing + idempotent dedup +
    pure (seed, tick/version) randomness make the restored run
    bit-identical to the never-killed twin."""
    prob = FleetProblem(P=3, K=12, n=10, buffer=4, events=3, sigma_g=0.3,
                        seed=11)
    out = chaos_run(prob, "fleet:timeout=2", ticks=8,
                    ckpt_root=str(tmp_path), kill_at={2: [1]})
    assert out.faulted.kills == 1
    assert out.faulted.restarts >= 1
    # fold counts (flush schedule) and realized q identical per tick/server
    np.testing.assert_array_equal(out.faulted.flushed, out.clean.flushed)
    np.testing.assert_array_equal(out.faulted.q, out.clean.q)
    assert out.clean.flushed.sum() > 0     # the run actually flushed
    # trajectories bit-identical, not just same neighborhood
    np.testing.assert_array_equal(out.faulted.msd, out.clean.msd)
    np.testing.assert_array_equal(out.faulted.params, out.clean.params)
    assert out.msd_gap == 0.0
    # worker-authoritative q-ledgers and the accountant eps they imply
    assert len(out.faulted.q_ledgers) == len(out.clean.q_ledgers) == prob.P
    for p, qs in enumerate(out.clean.q_ledgers):
        assert out.faulted.q_ledgers[p] == qs, p
        assert _ledger_epsilon(prob, out.faulted.q_ledgers[p]) == \
            _ledger_epsilon(prob, qs)
    assert _ledger_epsilon(prob, out.clean.q_ledgers[1]) > 0.0


def test_fleet_telemetry_stream_schema_registered():
    from repro.telemetry.schema import get_schema
    schema = get_schema("fleet")
    assert schema.index == "tick"
    names = {f.name for f in schema.fields}
    assert {"heartbeat_age", "retries", "restarts",
            "replay_lag"} <= names
