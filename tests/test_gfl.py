"""GFL protocol semantics + convergence (Theorem 1 structure, Fig. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GFLConfig
from repro.core import gfl
from repro.core.simulate import (
    generate_problem,
    global_risk,
    make_grad_fn,
    run_gfl,
    sample_round_batches,
)
from repro.core.topology import combination_matrix


@pytest.fixture(scope="module")
def problem():
    return generate_problem(jax.random.PRNGKey(0), P=5, K=8, N=40, M=2)


def _round_once(prob, scheme, seed=7, sigma=0.5):
    P = prob.features.shape[0]
    cfg = GFLConfig(num_servers=P, clients_per_server=8, privacy=scheme,
                    sigma_g=sigma, mu=0.1, topology="ring", grad_bound=10.0)
    A = jnp.asarray(combination_matrix("ring", P))
    grad_fn = make_grad_fn(prob.rho)
    key = jax.random.PRNGKey(seed)
    params = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (P, 2))
    batch = sample_round_batches(jax.random.fold_in(key, 2), prob, 4, 5)
    new = gfl.gfl_round(params, batch, jax.random.fold_in(key, 3),
                        A=A, grad_fn=grad_fn, cfg=cfg)
    return params, new


def test_hybrid_centroid_identity(problem):
    """The paper's core identity: after ONE round from identical state, the
    hybrid scheme's CENTROID equals the non-private centroid exactly —
    all injected noise lies in the nullspace of the averaging operator."""
    _, w_none = _round_once(problem, "none")
    _, w_hybrid = _round_once(problem, "hybrid", sigma=2.0)
    np.testing.assert_allclose(np.asarray(gfl.centroid(w_hybrid)),
                               np.asarray(gfl.centroid(w_none)), atol=1e-4)
    # but individual servers DO see noise (privacy is not free-riding)
    assert float(jnp.abs(w_hybrid - w_none).max()) > 0.05


def test_iid_centroid_differs(problem):
    _, w_none = _round_once(problem, "none")
    _, w_iid = _round_once(problem, "iid_dp", sigma=2.0)
    assert float(jnp.abs(gfl.centroid(w_iid) - gfl.centroid(w_none)).max()) \
        > 1e-3


def test_combine_preserves_centroid(problem):
    """Doubly-stochastic combine never moves the centroid (eq. 15/16)."""
    P = 6
    A = jnp.asarray(combination_matrix("erdos", P))
    psi = jax.random.normal(jax.random.PRNGKey(1), (P, 11))
    from repro.core.privacy.homomorphic import combine_nonprivate
    out = combine_nonprivate(A, psi)
    np.testing.assert_allclose(np.asarray(out.mean(0)),
                               np.asarray(psi.mean(0)), atol=1e-5)


def test_grad_clipping_enforced():
    g = jnp.full((100,), 10.0)
    clipped = gfl.clip_to_bound(g, 5.0)
    assert float(jnp.linalg.norm(clipped)) == pytest.approx(5.0, rel=1e-5)
    small = jnp.full((4,), 0.1)
    np.testing.assert_allclose(np.asarray(gfl.clip_to_bound(small, 5.0)),
                               np.asarray(small))


@pytest.mark.slow
def test_convergence_matches_paper(problem):
    """Fig. 2 structure: hybrid ~= non-private; both beat iid at high noise."""
    iters = 150
    cfgs = {
        s: GFLConfig(num_servers=5, clients_per_server=8, privacy=s,
                     sigma_g=0.6, mu=0.1, topology="full", grad_bound=10.0)
        for s in ("none", "iid_dp", "hybrid")
    }
    msd = {}
    for s, cfg in cfgs.items():
        trace, _ = run_gfl(problem, cfg, iters=iters, batch_size=10, seed=3)
        msd[s] = trace
    # all converge below starting error
    for s in msd:
        assert msd[s][-1] < msd[s][0]
    tail = slice(-20, None)
    final = {s: float(np.mean(msd[s][tail])) for s in msd}
    # hybrid within 2x of non-private steady state; iid strictly worse
    assert final["hybrid"] < 2.5 * final["none"] + 1e-3
    assert final["iid_dp"] > final["hybrid"]


def test_gfl_step_jit_and_state(problem):
    P = problem.features.shape[0]
    cfg = GFLConfig(num_servers=P, clients_per_server=8, privacy="hybrid",
                    sigma_g=0.2, mu=0.1, topology="ring")
    A = combination_matrix("ring", P)
    step = gfl.make_gfl_step(A, make_grad_fn(problem.rho), cfg)
    state = gfl.init_state(jax.random.PRNGKey(0), P, 2)
    batch = sample_round_batches(jax.random.PRNGKey(5), problem, 4, 5)
    s1 = step(state, batch)
    assert int(s1.step) == 1
    assert s1.params.shape == (P, 2)
    assert np.isfinite(np.asarray(s1.params)).all()


def test_use_kernels_matches_reference(problem):
    """Pallas-kernel combine/aggregate path == jnp path (same seeds)."""
    import dataclasses
    P = problem.features.shape[0]
    base = GFLConfig(num_servers=P, clients_per_server=8, privacy="hybrid",
                     sigma_g=0.3, mu=0.1, topology="ring", grad_bound=10.0)
    kern = dataclasses.replace(base, use_kernels=True)
    A = jnp.asarray(combination_matrix("ring", P))
    grad_fn = make_grad_fn(problem.rho)
    key = jax.random.PRNGKey(11)
    params = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (P, 2))
    batch = sample_round_batches(jax.random.fold_in(key, 2), problem, 4, 5)
    out_ref = gfl.gfl_round(params, batch, key, A=A, grad_fn=grad_fn,
                            cfg=base)
    out_kern = gfl.gfl_round(params, batch, key, A=A, grad_fn=grad_fn,
                             cfg=kern)
    # identical noise draws are not guaranteed (kernel PRG differs), but the
    # centroid is noise-free under the hybrid scheme in both paths
    np.testing.assert_allclose(np.asarray(gfl.centroid(out_kern)),
                               np.asarray(gfl.centroid(out_ref)), atol=1e-4)


def test_combine_every_amortized(problem):
    """combine_every=2: servers only mix on every 2nd step."""
    import dataclasses
    P = problem.features.shape[0]
    cfg = GFLConfig(num_servers=P, clients_per_server=8, privacy="none",
                    mu=0.1, topology="ring", grad_bound=10.0,
                    combine_every=2)
    A = combination_matrix("ring", P)
    step = gfl.make_gfl_step(A, make_grad_fn(problem.rho), cfg)
    state = gfl.init_state(jax.random.PRNGKey(0), P, 2)
    # seed distinct per-server params to detect mixing
    state = gfl.GFLState(
        state.params + jnp.arange(P)[:, None] * 1.0, state.step, state.key)
    batch = sample_round_batches(jax.random.PRNGKey(5), problem, 4, 5)
    s1 = step(state, batch)            # step 0: no combine
    spread1 = float(jnp.std(s1.params[:, 0]))
    s2 = step(s1, batch)               # step 1: combine fires
    spread2 = float(jnp.std(s2.params[:, 0]))
    assert spread2 < spread1 * 0.9     # mixing contracted the spread


def test_non_combine_rounds_add_no_combine_noise(problem):
    """Regression (tau-local privatization): with combine_every=2, the
    non-combine round must not invoke the mechanism's server level — a
    private run's step-0 params equal the non-private run's exactly (the
    hybrid client masks cancel in the mean), and only the combine round
    injects per-server noise."""
    P = problem.features.shape[0]
    A = combination_matrix("ring", P)
    batch = sample_round_batches(jax.random.PRNGKey(5), problem, 4, 5)

    def one_step(scheme, state=None, sigma=3.0):
        cfg = GFLConfig(num_servers=P, clients_per_server=8, privacy=scheme,
                        sigma_g=sigma, mu=0.1, topology="ring",
                        grad_bound=10.0, combine_every=2)
        step = gfl.make_gfl_step(A, make_grad_fn(problem.rho), cfg)
        if state is None:
            state = gfl.init_state(jax.random.PRNGKey(0), P, 2)
        return step(state, batch)

    s1_hybrid = one_step("hybrid")
    s1_none = one_step("none")
    # step 0 is a non-combine round: no combine-level noise anywhere
    np.testing.assert_allclose(np.asarray(s1_hybrid.params),
                               np.asarray(s1_none.params), atol=1e-4)
    # step 1 combines: noise appears per-server (but not in the centroid)
    s2_hybrid = one_step("hybrid", state=s1_hybrid)
    s2_none = one_step("none", state=s1_none)
    assert float(jnp.abs(s2_hybrid.params - s2_none.params).max()) > 0.05
    np.testing.assert_allclose(np.asarray(gfl.centroid(s2_hybrid.params)),
                               np.asarray(gfl.centroid(s2_none.params)),
                               atol=1e-4)
