"""Per-arch smoke tests (reduced configs) + decode/forward consistency.

The decode-consistency test is the strongest cache-path check we have: the
logits produced by prefill(prompt) followed by decode_step(tok) must match
the full-sequence forward at the same positions.  For mamba2 it also
validates the chunked SSD algorithm against the step recurrence.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, all_configs, get_config
from repro.models import Model

ARCHS = [a for a in ARCH_IDS if a != "gfl-logreg"]


def _batch_for(cfg, B, S, key):
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            k3, (B, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            k3, (B, cfg.encoder_seq_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_train_step(arch):
    """One forward + one SGD train step on the reduced config: shapes +
    finiteness (deliverable f)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, key)

    logits = jax.jit(model.forward)(params, batch)
    S_out = S + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, model.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    (loss, aux), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    new_params = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                              params, grads)
    loss2, _ = jax.jit(model.loss)(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """prefill + N decode steps == full forward (teacher forcing).

    MoE archs run with drop-free capacity (cf = E): capacity-based routing
    legitimately drops different tokens for different batch shapes, which is
    a semantic property of the router, not a cache bug."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    B, S, n_dec = 2, 24, 4
    batch = _batch_for(cfg, B, S + n_dec, key)
    full_logits = jax.jit(model.forward)(
        params, batch)                         # [B, S_total(+img), V]
    off = cfg.num_image_tokens if cfg.family == "vlm" else 0

    prompt = dict(batch)
    prompt["tokens"] = batch["tokens"][:, :S]
    prompt["labels"] = batch["labels"][:, :S]
    last_logits, cache = jax.jit(model.prefill)(params, prompt)

    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(full_logits[:, off + S - 1], np.float32),
        atol=2e-2, rtol=2e-2)

    decode = jax.jit(model.decode_step)
    for t in range(n_dec):
        tok = batch["tokens"][:, S + t]
        logits, cache = decode(params, tok, cache)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, off + S + t], np.float32),
            atol=2e-2, rtol=2e-2,
            err_msg=f"{arch} decode step {t}")


def test_sliding_window_matches_windowed_reference():
    """SWA chunked attention == naive masked attention."""
    from repro.models import attention as attn
    cfg = get_config("phi3-mini-3.8b").reduced()
    assert cfg.sliding_window > 0
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    p = attn.gqa_init(key, cfg, jnp.float32)
    B, S = 2, 130
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out_chunked = attn.gqa_forward(p, x, pos, cfg, chunk=32)
    # naive reference: full masked attention
    import dataclasses
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = attn._split_heads(x @ p["w_q"], h, dh)
    k = attn._split_heads(x @ p["w_k"], kv, dh)
    v = attn._split_heads(x @ p["w_v"], kv, dh)
    q = attn.apply_rope(q, pos, cfg.rope_theta)
    k = attn.apply_rope(k, pos, cfg.rope_theta)
    q = q.reshape(B, S, kv, h // kv, dh)
    s = attn._gqa_scores(q, k) / np.sqrt(dh)
    i, j = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = (j <= i) & (j > i - cfg.sliding_window)
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, -1)
    exp = jnp.einsum("bkgqs,bskd->bqkgd", w, v).reshape(B, S, h * dh) \
        @ p["w_o"]
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(exp),
                               atol=2e-4, rtol=2e-3)


def test_mamba2_chunked_equals_sequential():
    """Chunked SSD == naive per-step recurrence on random inputs."""
    from repro.models import ssm as ssm_lib
    cfg = get_config("zamba2-1.2b").reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(4)
    p = ssm_lib.mamba2_init(key, cfg, jnp.float32)
    B, S = 2, 37
    x = 0.5 * jax.random.normal(jax.random.fold_in(key, 1),
                                (B, S, cfg.d_model))
    out_chunked, st = ssm_lib.mamba2_forward(p, x, cfg)
    # sequential reference via decode steps
    d_inner, H, N, G = ssm_lib.ssm_dims(cfg)
    h = jnp.zeros((B, H, cfg.ssm.headdim, N), jnp.float32)
    conv = jnp.zeros((B, cfg.ssm.conv_dim - 1, d_inner + 2 * G * N))
    outs = []
    for t in range(S):
        o, h, conv = ssm_lib.mamba2_decode(p, x[:, t:t + 1], h, conv, cfg)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(out_seq),
                               atol=2e-3, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(h),
                               atol=2e-3, rtol=2e-2)


def test_moe_row_dispatch_matches_global():
    """Row-local dispatch (§Perf HC-2) == global dispatch when capacity is
    drop-free (semantic equivalence of the locality optimization)."""
    import dataclasses
    from repro.models import moe as moe_lib
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    E = cfg.moe.num_experts
    base = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(E)))
    row = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(E), dispatch="row"))
    p = moe_lib.moe_init(jax.random.PRNGKey(0), base, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32, cfg.d_model))
    o1, _ = moe_lib.moe_forward(p, x, base)
    o2, _ = moe_lib.moe_forward(p, x, row)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """Router load-balance: with uniform logits, token drop rate stays low."""
    from repro.models import moe as moe_lib
    cfg = get_config("mixtral-8x7b").reduced()
    model = Model(cfg)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    out, aux = moe_lib.moe_forward(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.0


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (deliverable f provenance check)."""
    spec = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch
        assert cfg.source, f"{arch} missing source citation"
    assert get_config("zamba2-1.2b").ssm.state_dim == 64
    assert get_config("deepseek-v2-lite-16b").mla.kv_lora_rank == 512
    assert get_config("deepseek-v2-lite-16b").moe.num_experts == 64
    assert get_config("deepseek-v2-lite-16b").moe.top_k == 6
    assert get_config("mixtral-8x7b").moe.num_experts == 8
    assert get_config("mixtral-8x7b").moe.top_k == 2
