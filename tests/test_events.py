"""Event-driven async engine: sync-limit bit-identity, buffered
staleness-weighted folding, per-server accounting, spec grammar
round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.configs.base import GFLConfig
from repro.core.events import (
    AsyncCohortDriver,
    AsyncSpec,
    EventQueue,
    LatencySpec,
    fold_tick,
    flush,
    init_buffers,
    parse_async_spec,
    parse_latency_spec,
    run_gfl_async,
    staleness_weights,
    trace_intensity_fn,
    weighted_fold,
)
from repro.core.population import (
    AvailabilityTrace,
    cohort_to_spec,
    parse_cohort_spec,
    parse_trace_spec,
    run_gfl_population,
)
from repro.core.privacy.mechanism import mechanism_for
from repro.core.resilience.faults import FaultModel, parse_fault_spec
from repro.core.simulate import generate_problem


@pytest.fixture(scope="module")
def problem():
    return generate_problem(jax.random.PRNGKey(0), P=4, K=6, N=30, M=2)


# --------------------------------------------------- the sync-limit anchor --


@pytest.mark.parametrize("scheme", ["none", "iid_dp", "hybrid"])
def test_sync_limit_bit_identical(problem, scheme):
    """THE anchor: buffer = L, zero latency, max_stale = 0 reproduces the
    population engine's pure path bit-for-bit — every tick is a lockstep
    synchronous round."""
    cfg = GFLConfig(num_servers=4, clients_per_server=6, clients_sampled=3,
                    privacy=scheme, sigma_g=0.3, mu=0.1, topology="ring",
                    grad_bound=10.0, async_spec="async:buffer=3")
    res_a = run_gfl_async(problem, cfg, ticks=6, batch_size=5, seed=3)
    res_p = run_gfl_population(problem, cfg, iters=6, batch_size=5, seed=3)
    assert np.array_equal(res_a.msd, res_p.msd)
    assert np.array_equal(np.asarray(res_a.params), np.asarray(res_p.params))
    # lockstep release schedule: every server flushes every tick at L/K
    assert res_a.flushed.all()
    np.testing.assert_allclose(res_a.q, 0.5)
    assert (res_a.staleness == 0).all() and (res_a.dropped_stale == 0).all()


def test_sync_limit_full_participation_bit_identical(problem):
    """Full participation (buffer = K) is the paper's original dense
    program, through the async executor."""
    cfg = GFLConfig(num_servers=4, clients_per_server=6, privacy="hybrid",
                    sigma_g=0.3, topology="ring",
                    async_spec="async:buffer=6")
    res_a = run_gfl_async(problem, cfg, ticks=5, batch_size=5, seed=7)
    res_p = run_gfl_population(problem, cfg, iters=5, batch_size=5, seed=7)
    assert np.array_equal(res_a.msd, res_p.msd)
    assert np.array_equal(np.asarray(res_a.params),
                          np.asarray(res_p.params))


def test_scan_executor_matches_streaming_loop():
    """The lax.scan event executor and the streaming tick loop agree (same
    realizations, one compiled program vs per-tick jit)."""
    cfg = GFLConfig(num_servers=4, clients_per_server=50, privacy="hybrid",
                    sigma_g=0.2, topology="ring",
                    population="synthetic:hetero",
                    cohort="uniform+trace:diurnal,period=8,min=0.3",
                    async_spec="async:buffer=8,latency=lognorm:0.7,"
                               "max_stale=3,rate=6")
    res_l = run_gfl_async(None, cfg, ticks=10, batch_size=5, seed=0)
    res_s = run_gfl_async(None, cfg, ticks=10, batch_size=5, seed=0,
                          scan=True)
    np.testing.assert_allclose(res_l.msd, res_s.msd, rtol=1e-4, atol=1e-6)
    assert np.array_equal(res_l.flushed, res_s.flushed)
    assert np.array_equal(res_l.events, res_s.events)
    np.testing.assert_allclose(res_l.staleness, res_s.staleness, atol=1e-6)


# ----------------------------------------------------- general async runs --


def test_async_desynchronizes_server_releases():
    """With thinned arrivals and a buffer larger than the per-tick rate,
    servers flush on their own cadences — the release schedule is no
    longer lockstep."""
    cfg = GFLConfig(num_servers=4, clients_per_server=40, privacy="iid_dp",
                    sigma_g=0.1, topology="ring",
                    population="synthetic:hetero",
                    cohort="uniform+trace:devclass,slow=0.6,p=0.3",
                    async_spec="async:buffer=8,latency=exp:1.2,"
                               "max_stale=4,rate=5")
    res = run_gfl_async(None, cfg, ticks=16, batch_size=5, seed=0)
    assert np.isfinite(res.msd).all()
    # not a lockstep schedule: some ticks flush a strict subset of servers
    per_tick = res.flushed.sum(axis=1)
    assert ((per_tick > 0) & (per_tick < 4)).any()
    # realized q recorded exactly on flush ticks
    assert (res.q[res.flushed] > 0).all() and (res.q[~res.flushed] == 0).all()
    # folded ages respect the bound; some contributions actually were stale
    assert (res.staleness <= 4).all() and res.staleness.max() > 0


def test_async_importance_composition():
    """Importance-sampled events compose: with-replacement identity draws,
    1/(K pi) gradient reweighting, per-flush q from the max-pi bound."""
    cfg = GFLConfig(num_servers=3, clients_per_server=30, privacy="iid_dp",
                    sigma_g=0.1, topology="ring",
                    population="synthetic:mixture,clusters=3",
                    cohort="importance,floor=0.2",
                    async_spec="async:buffer=6,latency=exp:1.0,"
                               "max_stale=2,rate=4")
    res = run_gfl_async(None, cfg, ticks=10, batch_size=5, seed=1)
    assert np.isfinite(res.msd).all()
    assert (res.q[res.flushed] <= 1.0).all()


def test_async_link_faults_compose():
    """links: faults realize per-tick effective A_i; the gap trajectory is
    surfaced on the result."""
    cfg = GFLConfig(num_servers=4, clients_per_server=10, privacy="none",
                    topology="ring", population="synthetic:iid",
                    fault="links:0.3", topology_seed=3,
                    async_spec="async:buffer=4,latency=lognorm:0.5,"
                               "max_stale=2")
    res = run_gfl_async(None, cfg, ticks=8, batch_size=5, seed=0)
    assert res.gaps is not None and res.gaps.shape == (8,)
    assert np.isfinite(res.gaps).all() and np.isfinite(res.msd).all()


def test_async_refusals():
    base = dict(population="synthetic:iid", async_spec="async:buffer=4")
    with pytest.raises(ValueError, match="dropout"):
        run_gfl_async(None, GFLConfig(fault="dropout:0.2", **base), ticks=2)
    with pytest.raises(ValueError, match="straggler|dropout"):
        run_gfl_async(None, GFLConfig(fault="straggler:0.2,stale=2",
                                      **base), ticks=2)
    with pytest.raises(ValueError, match="async spec"):
        run_gfl_async(None, GFLConfig(population="synthetic:iid"), ticks=2)
    with pytest.raises(ValueError, match="combine_every"):
        run_gfl_async(None, GFLConfig(combine_every=2, **base), ticks=2)


# ------------------------------------------- staleness-weighted buffering --


def test_staleness_weight_properties():
    ages = jnp.asarray([0, 1, 2, 5, 17])
    for alpha in (0.0, 0.5, 1.0, 2.0):
        s = np.asarray(staleness_weights(ages, alpha))
        assert (s >= 0).all() and (s <= 1.0 + 1e-7).all()
        assert s[0] == pytest.approx(1.0)        # fresh weight is 1
        assert (np.diff(s) <= 1e-9).all()        # nonincreasing in age
    # alpha = 0: no down-weighting at all
    np.testing.assert_allclose(
        np.asarray(staleness_weights(ages, 0.0)), 1.0)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=20), min_size=1,
                max_size=12),
       st.floats(min_value=0.0, max_value=3.0, allow_nan=False))
def test_weighted_fold_is_exact_affine_combination(ages, alpha):
    """Nonnegative weights; fold of a constant is that constant exactly
    (the normalization is exact — the unbiasedness identity E[fold] =
    E[x] for ages independent of x follows by linearity)."""
    s = np.asarray(staleness_weights(jnp.asarray(ages), alpha))
    assert (s >= 0).all()
    x = jnp.full((len(ages), 3), 2.5)
    out = np.asarray(weighted_fold(x, jnp.asarray(s)))
    np.testing.assert_allclose(out, 2.5, rtol=1e-6)


def test_fold_unbiased_in_expectation():
    """Monte-Carlo check of the unbiasedness claim: ages drawn
    independently of the updates leave the folded mean at the update
    mean."""
    rng = np.random.default_rng(0)
    mu = 3.0
    folds = []
    for _ in range(400):
        x = rng.normal(mu, 1.0, size=(8, 2))
        ages = rng.integers(0, 5, size=8)
        s = np.asarray(staleness_weights(jnp.asarray(ages), 0.5))
        folds.append(np.asarray(weighted_fold(jnp.asarray(x),
                                              jnp.asarray(s))))
    err = np.abs(np.mean(folds, axis=0) - mu).max()
    assert err < 0.05, f"fold biased by {err}"


def test_buffer_fold_flush_semantics():
    params = jnp.zeros((3, 2))
    buf = init_buffers(params)
    c = jnp.asarray([[1.0, 1.0], [2.0, 2.0], [3.0, 3.0]])
    # tick 1: servers fold (2, 3, 0) arrivals
    buf = fold_tick(buf, c, jnp.asarray([2.0, 3.0, 0.0]),
                    jnp.asarray([2, 3, 0], jnp.int32))
    did, psi, buf = flush(buf, 3)
    assert np.array_equal(np.asarray(did), [False, True, False])
    np.testing.assert_allclose(np.asarray(psi[1]), [2.0, 2.0])
    # non-flushing servers re-announce psi_cache (init params here)
    np.testing.assert_allclose(np.asarray(psi[0]), 0.0)
    assert np.array_equal(np.asarray(buf.buf_n), [2, 0, 0])
    assert np.array_equal(np.asarray(buf.version), [0, 1, 0])
    # tick 2: server 0 crosses the threshold; its fold spans both ticks
    buf = fold_tick(buf, 2 * c, jnp.asarray([2.0, 0.0, 0.0]),
                    jnp.asarray([2, 0, 0], jnp.int32))
    did, psi, buf = flush(buf, 3)
    assert np.array_equal(np.asarray(did), [True, False, False])
    np.testing.assert_allclose(np.asarray(psi[0]), [1.5, 1.5])  # (2*1+2*2)/4
    assert buf.buf_n[0] == 0 and buf.version[0] == 1


# ------------------------------------------------------ the arrival layer --


def test_event_queue_deterministic_in_seed_and_tick():
    spec = parse_async_spec("async:buffer=4,latency=lognorm:0.8,"
                            "max_stale=6,rate=3")
    q1 = EventQueue(5, spec, seed=11)
    q2 = EventQueue(5, spec, seed=11)
    for t in (0, 3, 17):
        u1, a1 = q1.realize(t)
        u2, a2 = q2.realize(t)
        assert np.array_equal(u1, u2) and np.array_equal(a1, a2)
        assert u1.shape == (5, 3) and a1.dtype == np.int32
    u3, a3 = EventQueue(5, spec, seed=12).realize(0)
    assert not np.array_equal(u3, q1.realize(0)[0])
    us, ages = q1.realize_horizon(4)
    assert us.shape == (4, 5, 3)
    assert np.array_equal(us[3], q1.realize(3)[0])


def test_trace_intensity_matches_host_probs():
    """The in-graph intensity formulas agree with the host-side trace
    probabilities the synchronous scheduler uses."""
    K = 64
    for spec in ("diurnal,period=12,min=0.3", "devclass,slow=0.4,p=0.2"):
        trace = parse_trace_spec(spec)
        fn = trace_intensity_fn(trace, K)
        idx = jnp.arange(K)
        for t in (0, 5, 31):
            np.testing.assert_allclose(np.asarray(fn(t, idx)),
                                       trace.probs(t, K), rtol=1e-6)
    assert trace_intensity_fn(AvailabilityTrace(), K) is None


# ------------------------------------------------- per-server accounting --


def test_async_accountant_lockstep_pin():
    """The synchronous lockstep schedule is a pinned special case: every
    per-server ledger equals the scalar accountant's curve."""
    cfg = GFLConfig(num_servers=3, clients_per_server=10,
                    clients_sampled=4, privacy="hybrid", sigma_g=0.3,
                    topology="ring", population="synthetic:iid",
                    async_spec="async:buffer=4")
    res = run_gfl_async(None, cfg, ticks=6, batch_size=5, seed=0)
    mech = mechanism_for(cfg)
    aacc = mech.async_accountant(3)
    aacc.record_schedule(res.flushed, res.q)
    acc = mech.accountant()
    acc.advance(6, q=0.4)
    assert aacc.releases == [6, 6, 6]
    assert aacc.epsilon() == pytest.approx(acc.epsilon())
    assert aacc.amplified_epsilon() == pytest.approx(
        acc.amplified_epsilon())
    assert all(e == pytest.approx(acc.epsilon())
               for e in aacc.per_server_epsilon())


def test_async_accountant_per_server_cadence():
    """Servers releasing at different cadences spend different budgets;
    the headline epsilon is the worst server's."""
    cfg = GFLConfig(num_servers=2, clients_per_server=10, privacy="hybrid",
                    sigma_g=0.3)
    aacc = mechanism_for(cfg).async_accountant(2)
    flushed = np.asarray([[True, True], [True, False],
                          [True, False], [True, True]])
    q = np.where(flushed, 0.5, 0.0)
    aacc.record_schedule(flushed, q)
    assert aacc.releases == [4, 2]
    eps = aacc.per_server_epsilon()
    assert eps[0] > eps[1] > 0
    assert aacc.epsilon() == pytest.approx(eps[0])
    assert aacc.amplified_epsilon() <= aacc.epsilon()


# ------------------------------------------------- spec grammar roundtrips --


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 100), st.integers(0, 100), st.integers(0, 100),
       st.integers(1, 6), st.integers(0, 100))
def test_fault_spec_roundtrip(links, outage, straggler, stale, dropout):
    fm = FaultModel(link_drop=links / 100, outage=outage / 100,
                    straggler=straggler / 100, staleness=stale,
                    client_dropout=dropout / 100)
    rt = parse_fault_spec(fm.to_spec())
    # canonical form drops the staleness of an inactive straggler
    if fm.straggler == 0:
        fm = FaultModel(fm.link_drop, fm.outage, 0.0, 1, fm.client_dropout)
    assert rt == fm
    assert parse_fault_spec(rt.to_spec()) == rt


@settings(max_examples=50, deadline=None)
@given(st.sampled_from(["always", "diurnal", "devclass"]),
       st.integers(1, 48), st.integers(0, 99), st.integers(1, 100),
       st.integers(0, 100))
def test_trace_and_cohort_spec_roundtrip(kind, period, lo, slow, p):
    trace = AvailabilityTrace(kind=kind, period=period, min_avail=lo / 100,
                              slow_frac=slow / 100, slow_p=p / 100)
    rt = parse_trace_spec(trace.to_spec())
    # canonical form only serializes the kind's own knobs
    assert rt.kind == trace.kind
    if kind == "diurnal":
        assert (rt.period, rt.min_avail) == (trace.period, trace.min_avail)
    if kind == "devclass":
        assert (rt.slow_frac, rt.slow_p) == (trace.slow_frac, trace.slow_p)
    assert parse_trace_spec(rt.to_spec()) == rt
    for sampler, floor in (("uniform", 0.1), ("importance", 0.25)):
        spec = cohort_to_spec(sampler, floor, rt)
        s2, f2, t2 = parse_cohort_spec(spec)
        assert (s2, t2) == (sampler, rt)
        if sampler == "importance":
            assert f2 == floor
        assert cohort_to_spec(s2, f2, t2) == spec


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 64),
       st.sampled_from(["zero", "fixed", "exp", "lognorm"]),
       st.integers(0, 400), st.integers(0, 8), st.integers(0, 300),
       st.integers(0, 64))
def test_async_spec_roundtrip(buffer, lkind, lparam, max_stale, alpha100,
                              rate):
    lat = (LatencySpec() if lkind == "zero"
           else LatencySpec(lkind, lparam / 100))
    spec = AsyncSpec(buffer=buffer, latency=lat, max_stale=max_stale,
                     alpha=alpha100 / 100, rate=rate)
    rt = parse_async_spec(spec.to_spec())
    # canonical form normalizes zero-parameter latencies to "zero"
    if lat.is_zero:
        spec = AsyncSpec(buffer, LatencySpec(), max_stale,
                         alpha100 / 100, rate)
    assert rt == spec
    assert parse_async_spec(rt.to_spec()) == rt


def test_spec_grammar_errors():
    for bad in ("async:buffer=0", "async:nope=3", "fancy:buffer=2",
                "async:buffer=two", "async:buffer=2,buffer=3",
                "async:max_stale=-1", "async:alpha=-0.5"):
        with pytest.raises(ValueError):
            parse_async_spec(bad)
    for bad in ("zero:1", "exp", "lognorm:", "gamma:0.5", "exp:x",
                "fixed:-1"):
        with pytest.raises(ValueError):
            parse_latency_spec(bad)
    assert parse_async_spec("none") is None
    assert parse_async_spec("async").buffer == 8
    with pytest.raises(ValueError):
        cohort_to_spec("fancy", 0.1, AvailabilityTrace())


# -------------------------------------------------------- mesh event layer --


def test_async_cohort_driver_weights_and_cadence():
    spec = parse_async_spec("async:buffer=6,latency=lognorm:0.6,"
                            "max_stale=3")
    drv = AsyncCohortDriver(spec, P=3, L=4, K=100,
                            trace="devclass,slow=0.5,p=0.3", seed=0)
    releases = np.zeros(3, int)
    for t in range(12):
        w, flushed, q = drv.step(t)
        w = np.asarray(w)
        assert w.shape == (3, 4) and (w >= 0).all()
        # release gating: weights are nonzero EXACTLY on flush steps (the
        # steps the ledger is charged for), normalized so the server MEAN
        # is the weighted fold (rows sum to L)
        live = w.sum(axis=1) > 0
        assert np.array_equal(live, flushed)
        np.testing.assert_allclose(w.sum(axis=1)[live], 4.0, rtol=1e-6)
        assert (q[flushed] > 0).all() and (q[~flushed] == 0).all()
        releases += flushed
    assert releases.sum() > 0          # buffers do fill and flush
    # deterministic in (seed, tick)
    drv2 = AsyncCohortDriver(spec, P=3, L=4, K=100,
                             trace="devclass,slow=0.5,p=0.3", seed=0)
    w2, f2, q2 = drv2.step(0)
    w1, f1, q1 = AsyncCohortDriver(
        spec, P=3, L=4, K=100,
        trace="devclass,slow=0.5,p=0.3", seed=0).step(0)
    assert np.array_equal(np.asarray(w1), np.asarray(w2))
