"""The pluggable PrivacyMechanism API: registry round-trip, cancellation
identities driven by noise_profile(), the scheduled accountant schedule,
and kernel-vs-reference backend parity per mechanism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GFLConfig
from repro.core import gfl
from repro.core.privacy.accountant import (
    PrivacyAccountant,
    epsilon_at,
    gaussian_epsilon_at,
    gaussian_sigma_for_epsilon,
    scheduled_epsilon_spent,
    scheduled_sigma_at,
    sensitivity,
    sigma_for_epsilon,
)
from repro.core.privacy.mechanism import (
    PrivacyMechanism,
    RoundContext,
    get_mechanism,
    list_mechanisms,
    mechanism_for,
    register_mechanism,
)
from repro.core.simulate import (
    generate_problem,
    make_grad_fn,
    sample_round_batches,
)
from repro.core.topology import combination_matrix

P_SERVERS = 5


@pytest.fixture(scope="module")
def problem():
    return generate_problem(jax.random.PRNGKey(0), P=P_SERVERS, K=8, N=30,
                            M=2)


def _cfg(scheme, sigma=0.5, **kw):
    base = dict(num_servers=P_SERVERS, clients_per_server=8, privacy=scheme,
                sigma_g=sigma, mu=0.1, topology="ring", grad_bound=10.0,
                epsilon_target=100.0, epsilon_horizon=50)
    base.update(kw)
    return GFLConfig(**base)


def _round_once(prob, cfg, seed=7, step=0):
    A = jnp.asarray(combination_matrix("ring", P_SERVERS))
    grad_fn = make_grad_fn(prob.rho)
    key = jax.random.PRNGKey(seed)
    params = 0.1 * jax.random.normal(jax.random.fold_in(key, 1),
                                     (P_SERVERS, 2))
    batch = sample_round_batches(jax.random.fold_in(key, 2), prob, 4, 5)
    new = gfl.gfl_round(params, batch, jax.random.fold_in(key, 3),
                        A=A, grad_fn=grad_fn, cfg=cfg, step=step)
    return params, new


# ------------------------------------------------------------- registry ---


def test_registry_has_the_required_mechanisms():
    names = list_mechanisms()
    for required in ("none", "iid_dp", "hybrid", "gaussian_dp", "scheduled"):
        assert required in names
    assert len(names) >= 5


def test_unknown_mechanism_raises():
    cfg = _cfg("nope_not_a_scheme")
    with pytest.raises(ValueError, match="unknown privacy mechanism"):
        mechanism_for(cfg)


def test_scheduled_cannot_wrap_itself():
    with pytest.raises(ValueError, match="cannot wrap itself"):
        mechanism_for(_cfg("scheduled:scheduled"))


def test_duplicate_registration_raises():
    with pytest.raises(ValueError, match="already registered"):
        register_mechanism("hybrid")(PrivacyMechanism)


def test_spec_parsing_selects_inner():
    mech = mechanism_for(_cfg("scheduled:iid_dp"))
    assert mech.inner.name == "iid_dp"
    assert mechanism_for(_cfg("scheduled")).inner.name == "hybrid"


@pytest.mark.parametrize("scheme", list_mechanisms())
def test_registry_round_trip(problem, scheme):
    """Every registered mechanism runs one full gfl_round to finite params."""
    _, new = _round_once(problem, _cfg(scheme))
    assert new.shape == (P_SERVERS, 2)
    assert np.isfinite(np.asarray(new)).all()


# ----------------------------------------------- cancellation identities --


@pytest.mark.parametrize("scheme", list_mechanisms())
def test_centroid_identity_follows_noise_profile(problem, scheme):
    """For ANY mechanism whose noise_profile() declares exact server-level
    cancellation, one round's centroid equals the non-private centroid;
    mechanisms that declare no cancellation must visibly perturb it."""
    sigma = 2.0
    cfg = _cfg(scheme, sigma=sigma)
    prof = mechanism_for(cfg).noise_profile()
    _, w_none = _round_once(problem, _cfg("none", sigma=0.0))
    _, w = _round_once(problem, cfg)
    c_none = np.asarray(gfl.centroid(w_none))
    c = np.asarray(gfl.centroid(w))
    if prof.server_cancels_exactly:
        np.testing.assert_allclose(c, c_none, atol=1e-4)
        if prof.server_sigma > 0:
            # individual servers DO see noise (privacy is not free-riding)
            assert float(jnp.abs(w - w_none).max()) > 0.05
    else:
        assert np.abs(c - c_none).max() > 1e-3


# ------------------------------------------------------ scheduled budget --


def test_scheduled_hits_epsilon_target_at_horizon():
    mu, B, H, eps_target = 0.1, 10.0, 40, 8.0
    cfg = _cfg("scheduled", mu=mu, grad_bound=B, epsilon_target=eps_target,
               epsilon_horizon=H)
    mech = mechanism_for(cfg)
    # composing the per-step Laplace releases (eps_i = sqrt(2) Delta(i) /
    # sigma_i) over the schedule spends exactly the target
    spent = sum((2.0 ** 0.5) * sensitivity(i, mu, B) / mech.sigma_at(i - 1)
                for i in range(1, H + 1))
    assert spent == pytest.approx(eps_target)
    assert scheduled_epsilon_spent(H, H, eps_target) == pytest.approx(
        eps_target)
    # cross-check against the fixed-sigma Theorem-2 accountant: the sigma
    # epsilon_at inverts for the same (horizon, target) satisfies the same
    # budget, and the mechanism's accountant agrees at the horizon
    fixed = sigma_for_epsilon(H, mu, B, eps_target)
    assert epsilon_at(H, mu, B, fixed) == pytest.approx(eps_target)
    acc = mech.accountant()
    assert acc.curve == "scheduled"
    assert acc.advance(H) == pytest.approx(eps_target)


def test_scheduled_sigma_grows_linearly_per_step():
    s1 = scheduled_sigma_at(1, 0.1, 10.0, 50, 10.0)
    s10 = scheduled_sigma_at(10, 0.1, 10.0, 50, 10.0)
    assert s10 == pytest.approx(10 * s1)


def test_scheduled_constant_follows_inner_distribution():
    """scheduled:gaussian_dp must draw sqrt(2 ln 1.25/delta)/sqrt(2) times
    MORE noise than scheduled:hybrid for the same per-step epsilon slice —
    the Laplace constant would under-noise the Gaussian ledger ~3.4x."""
    cfg = _cfg("scheduled", epsilon_target=10.0, epsilon_horizon=50)
    lap = mechanism_for(cfg)
    gau = mechanism_for(_cfg("scheduled:gaussian_dp", epsilon_target=10.0,
                             epsilon_horizon=50))
    ratio = float(gau.sigma_at(7)) / float(lap.sigma_at(7))
    expected = (2 * np.log(1.25 / 1e-5)) ** 0.5 / (2.0 ** 0.5)
    assert ratio == pytest.approx(expected, rel=1e-6)
    # and the gaussian ledger then prices each step at exactly its slice
    eps_slice = (gaussian_epsilon_at(8, cfg.mu, cfg.grad_bound,
                                     float(gau.sigma_at(7)))
                 - gaussian_epsilon_at(7, cfg.mu, cfg.grad_bound,
                                       float(gau.sigma_at(7))))
    assert eps_slice == pytest.approx(10.0 / 50, rel=1e-6)


def test_scheduled_noise_actually_scales_with_step(problem):
    """The dead epsilon_target knob now changes behavior: later rounds of
    the scheduled mechanism inject more server noise than early rounds."""
    cfg = _cfg("scheduled", epsilon_target=5000.0, epsilon_horizon=50)
    _, w_none = _round_once(problem, _cfg("none", sigma=0.0))
    _, w_early = _round_once(problem, cfg, step=0)
    _, w_late = _round_once(problem, cfg, step=49)
    dev_early = float(jnp.abs(w_early - w_none).max())
    dev_late = float(jnp.abs(w_late - w_none).max())
    assert dev_late > 5 * dev_early > 0


def test_scheduled_identity_without_target(problem):
    """epsilon_target == 0 -> the wrapper is the inner mechanism."""
    cfg_s = _cfg("scheduled", sigma=0.4, epsilon_target=0.0)
    cfg_h = _cfg("hybrid", sigma=0.4)
    _, w_s = _round_once(problem, cfg_s)
    _, w_h = _round_once(problem, cfg_h)
    np.testing.assert_allclose(np.asarray(w_s), np.asarray(w_h), atol=1e-6)


# ------------------------------------------------- accountant integration --


@pytest.mark.parametrize("scheme", list_mechanisms())
def test_accountant_consumes_noise_profile(scheme):
    cfg = _cfg(scheme)
    mech = mechanism_for(cfg)
    acc = mech.accountant()
    assert isinstance(acc, PrivacyAccountant)
    eps = acc.advance(10)
    if mech.noise_profile().curve == "none":
        assert eps == 0.0
    else:
        assert eps > 0


def test_gaussian_curve_differs_from_laplace():
    cfg_g = _cfg("gaussian_dp", sigma=0.5)
    cfg_h = _cfg("hybrid", sigma=0.5)
    eps_g = mechanism_for(cfg_g).accountant().advance(20)
    eps_h = mechanism_for(cfg_h).accountant().advance(20)
    # sqrt(2 ln(1.25/1e-5)) ≈ 4.84 vs sqrt(2): Gaussian basic composition
    # charges more per release at the default delta
    assert eps_g > 2 * eps_h
    assert eps_g == pytest.approx(
        gaussian_epsilon_at(20, cfg_g.mu, cfg_g.grad_bound, 0.5))


def test_gaussian_sigma_epsilon_inverse():
    mu, B, i, eps = 0.1, 10.0, 50, 2.0
    sig = gaussian_sigma_for_epsilon(i, mu, B, eps)
    assert gaussian_epsilon_at(i, mu, B, sig) == pytest.approx(eps)


def test_gaussian_delta_composes():
    """Basic composition adds the per-release deltas: the ledger must
    report (eps, i*delta), not a fixed delta."""
    for scheme, spends in (("gaussian_dp", True),
                           ("scheduled:gaussian_dp", True),
                           ("hybrid", False), ("none", False)):
        acc = mechanism_for(_cfg(scheme)).accountant()
        acc.advance(30)
        assert acc.delta_spent() == pytest.approx(
            30 * acc.delta if spends else 0.0), scheme


def test_profile_honest_without_secure_agg():
    """secure_agg=False injects NO client noise — the profile must say so
    rather than declare phantom non-cancelling client noise."""
    for scheme in ("hybrid", "gaussian_dp"):
        prof = mechanism_for(_cfg(scheme, secure_agg=False)).noise_profile()
        assert prof.client_sigma == 0.0
        assert prof.client_cancels_exactly


def test_scheduled_profile_honest_about_inner_structure():
    """The scheduled wrapper must not declare noise its inner never
    injects: scheduled:none stays untracked (no finite-epsilon claim for a
    zero-noise run), and a no-mask inner keeps client_sigma 0 — while a
    noisy inner reports the schedule sigma even when cfg.sigma_g == 0."""
    prof = mechanism_for(_cfg("scheduled:none")).noise_profile()
    assert prof.curve == "none" and prof.server_sigma == 0.0
    prof = mechanism_for(
        _cfg("scheduled", secure_agg=False)).noise_profile()
    assert prof.curve == "scheduled"
    assert prof.client_sigma == 0.0 and prof.server_sigma > 0
    prof = mechanism_for(_cfg("scheduled", sigma=0.0)).noise_profile()
    assert prof.server_sigma > 0 and prof.client_sigma > 0


# -------------------------------------------------- kernel backend parity --


@pytest.mark.parametrize("scheme", list_mechanisms())
def test_kernel_vs_reference_zero_noise_exact(problem, scheme):
    """With sigma 0 both backends must agree bit-for-bit up to float
    addition order — the backend choice lives inside the mechanism."""
    base = _cfg(scheme, sigma=0.0, epsilon_target=0.0)
    kern = dataclasses.replace(base, use_kernels=True)
    _, w_ref = _round_once(problem, base)
    _, w_kern = _round_once(problem, kern)
    np.testing.assert_allclose(np.asarray(w_kern), np.asarray(w_ref),
                               atol=1e-5)


@pytest.mark.parametrize("scheme",
                         [s for s in list_mechanisms()
                          if mechanism_for(_cfg(s)).noise_profile()
                          .server_cancels_exactly])
def test_kernel_vs_reference_centroid_parity(problem, scheme):
    """At sigma > 0 the kernel PRG differs from the reference draws, but
    any cancelling mechanism's centroid is noise-free on both backends."""
    base = _cfg(scheme, sigma=0.3)
    kern = dataclasses.replace(base, use_kernels=True)
    _, w_ref = _round_once(problem, base)
    _, w_kern = _round_once(problem, kern)
    np.testing.assert_allclose(np.asarray(gfl.centroid(w_kern)),
                               np.asarray(gfl.centroid(w_ref)), atol=1e-4)


# ----------------------------------------------------------- pytree hooks --


def test_client_noise_tree_variance_equivalent():
    cfg = _cfg("iid_dp", sigma=1.0)
    mech = mechanism_for(cfg)
    tree = {"w": jnp.zeros((4, 20_000))}
    out = mech.client_noise_tree(jax.random.PRNGKey(0), tree, L=16)
    assert float(jnp.std(out["w"])) == pytest.approx(1.0 / 4.0, rel=0.05)


def test_cancelling_mechanisms_have_no_client_tree_noise():
    for scheme in ("none", "hybrid", "gaussian_dp", "scheduled"):
        mech = mechanism_for(_cfg(scheme))
        tree = {"w": jnp.zeros((2, 8))}
        assert mech.client_noise_tree(jax.random.PRNGKey(0), tree, 4) is None


def test_combine_noise_tree_distribution():
    tree = {"w": jnp.zeros((4, 50_000))}
    for scheme, kurtosis_high in (("hybrid", True), ("gaussian_dp", False)):
        mech = mechanism_for(_cfg(scheme, sigma=1.0))
        g = np.asarray(mech.combine_noise_tree(jax.random.PRNGKey(1),
                                               tree)["w"]).ravel()
        assert g.std() == pytest.approx(1.0, rel=0.03)
        excess_kurt = ((g - g.mean()) ** 4).mean() / g.var() ** 2 - 3.0
        assert (excess_kurt > 1.5) == kurtosis_high  # Laplace: 3, Normal: 0
