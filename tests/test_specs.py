"""Registry-driven spec-grammar round-trip tests (gflint GFL005).

Driving :func:`repro.core.specs.all_grammars` means a newly registered
grammar is round-trip tested automatically — and is exactly the evidence
GFL005 looks for.
"""
import pytest

from hypothesis_compat import given, settings, st
from repro.core.population.population import (PopulationSpec,
                                              parse_population_spec,
                                              population_to_spec)
from repro.core.specs import SpecGrammar, all_grammars, get_grammar

EXPECTED = {"async", "cohort", "fault", "fleet", "latency", "population",
            "trace", "watch"}


def test_registry_inventory():
    assert set(all_grammars()) == EXPECTED
    g = get_grammar("fault")
    assert isinstance(g, SpecGrammar) and g.examples
    with pytest.raises(KeyError):
        get_grammar("nope")


def _cases():
    for name, g in sorted(all_grammars().items()):
        assert g.examples, f"grammar {name!r} ships no examples"
        for ex in g.examples:
            yield pytest.param(name, ex, id=f"{name}-{ex}")


@pytest.mark.parametrize("name,example", list(_cases()))
def test_round_trip_law(name, example):
    """parse(to_spec(parse(s))) == parse(s), and canonical forms are
    fixed points of to_spec(parse(.))."""
    g = get_grammar(name)
    parsed = g.parse(example)
    canonical = g.to_spec(parsed)
    reparsed = g.parse(canonical)
    assert reparsed == parsed
    assert g.to_spec(reparsed) == canonical


# ---- population grammar: previously had no serializer at all ----------
def test_population_to_spec_canonical_forms():
    assert population_to_spec(parse_population_spec("dense")) == "dense"
    assert population_to_spec(
        parse_population_spec("synthetic")) == "synthetic:hetero"
    assert population_to_spec(
        parse_population_spec("dirichlet:0.3,pool=4000")) \
        == "dirichlet:0.3,pool=4000"
    # int-typed alpha (keyword form) must stay a keyword to keep its type
    s = population_to_spec(parse_population_spec("dirichlet,alpha=1"))
    assert parse_population_spec(s).args["alpha"] == 1
    assert isinstance(parse_population_spec(s).args["alpha"], int)


def test_population_to_spec_rejects_nothing_parse_accepts():
    for spec in ("dense", "synthetic:iid,sigma=1.0,n=40,dim=8",
                 "synthetic:mixture,clusters=3,drift=0.25,rho=0.1",
                 "dirichlet:0.5,pool=100,sigma=2.0"):
        assert parse_population_spec(population_to_spec(
            parse_population_spec(spec))) == parse_population_spec(spec)


@settings(max_examples=50, deadline=None)
@given(
    kind=st.sampled_from(["iid", "hetero", "mixture", "dirichlet"]),
    sigma=st.floats(0.01, 10.0, allow_nan=False),
    n=st.integers(1, 1000),
)
def test_population_round_trip_property(kind, sigma, n):
    args = {"n": n}
    if kind in ("iid", "mixture", "dirichlet"):
        args["sigma"] = float(sigma)
    if kind == "mixture":
        args["clusters"] = 3
    if kind == "dirichlet":
        args["alpha"] = float(sigma)
    spec = PopulationSpec(kind, args)
    assert parse_population_spec(population_to_spec(spec)) == spec
