"""Beyond-paper extensions: importance sampling [22,23], secure-agg dropout
recovery, additional server-graph topologies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.privacy.secure_agg import (
    masked_client_mean_with_dropout,
    pairwise_masks,
)
from repro.core.sampling import (
    ISState,
    importance_weights,
    init_is_state,
    sample_clients,
    sampling_probs,
    update_norm_estimates,
)
from repro.core.topology import combination_matrix, spectral_gap


# ------------------------------------------------------- secure-agg dropout


@given(L=st.integers(2, 8), seed=st.integers(0, 999),
       drop_mask=st.integers(1, 200))
@settings(max_examples=20, deadline=None)
def test_dropout_recovery_exact(L, seed, drop_mask):
    """Surviving-client mean is recovered exactly whatever the dropout set."""
    key = jax.random.PRNGKey(seed)
    upd = jax.random.normal(jax.random.fold_in(key, 1), (L, 24))
    alive = jnp.asarray([(drop_mask >> i) & 1 for i in range(L)], bool)
    alive = alive.at[0].set(True)  # at least one survivor
    agg = masked_client_mean_with_dropout(upd, key, alive, mask_scale=4.0)
    expected = upd[alive].mean(axis=0)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(expected),
                               atol=1e-4)


def test_dropout_all_alive_equals_plain_mean():
    key = jax.random.PRNGKey(0)
    upd = jax.random.normal(key, (5, 16))
    agg = masked_client_mean_with_dropout(upd, key, jnp.ones(5, bool))
    np.testing.assert_allclose(np.asarray(agg), np.asarray(upd.mean(0)),
                               atol=1e-5)


# ------------------------------------------------------ importance sampling


def test_importance_weights_unbiased():
    """E[ (1/L) sum_k g_k / (K pi_k) ] == mean_k g_k under pi-sampling."""
    P, K, L = 1, 6, 4
    key = jax.random.PRNGKey(0)
    g = jnp.arange(1.0, K + 1)                      # per-client "gradients"
    state = ISState(jnp.asarray([[5, 1, 1, 1, 1, 1.0]]), jnp.zeros((1, 6),
                                                                   jnp.int32))
    probs = sampling_probs(state, floor=0.05)
    est = []
    for s in range(400):
        idx = sample_clients(jax.random.fold_in(key, s), probs, L)
        w = importance_weights(probs, idx)
        est.append(float((g[idx[0]] * w[0]).mean()))
    assert np.mean(est) == pytest.approx(float(g.mean()), rel=0.05)


def test_norm_estimate_updates():
    state = init_is_state(2, 4)
    idx = jnp.asarray([[0, 1], [2, 3]])
    norms = jnp.asarray([[10.0, 10.0], [0.1, 0.1]])
    new = update_norm_estimates(state, idx, norms, decay=0.5)
    assert float(new.norm_est[0, 0]) == pytest.approx(5.5)
    assert float(new.norm_est[1, 2]) == pytest.approx(0.55)
    assert int(new.counts[0, 0]) == 1
    assert int(new.counts[0, 2]) == 0
    probs = sampling_probs(new)
    # heavier-gradient clients get sampled more
    assert float(probs[0, 0]) > float(probs[0, 2])


# ----------------------------------------------------------- new topologies


@pytest.mark.parametrize("topology,P", [("hypercube", 16), ("expander", 12)])
def test_new_topologies_assumption1(topology, P):
    A = combination_matrix(topology, P)
    assert np.allclose(A, A.T)
    assert np.allclose(A.sum(0), 1.0)
    assert spectral_gap(A) < 1.0


def test_hypercube_beats_ring_mixing():
    """Same node count: hypercube's spectral gap is much smaller (faster
    consensus) at degree log2(P) vs the ring's 2."""
    lam_ring = spectral_gap(combination_matrix("ring", 16))
    lam_cube = spectral_gap(combination_matrix("hypercube", 16))
    assert lam_cube < lam_ring - 0.1


def test_hypercube_requires_power_of_two():
    with pytest.raises(ValueError):
        combination_matrix("hypercube", 12)


@pytest.mark.slow
def test_importance_sampling_gfl_converges():
    """IS-GFL ([22,23]) converges on the paper problem, remains private."""
    from repro.configs.base import GFLConfig
    from repro.core.simulate import generate_problem, run_gfl_importance

    prob = generate_problem(jax.random.PRNGKey(0), P=4, K=10, N=60, M=2)
    cfg = GFLConfig(num_servers=4, clients_per_server=10, clients_sampled=4,
                    privacy="hybrid", sigma_g=0.2, mu=0.1, topology="full",
                    grad_bound=10.0)
    msd, params = run_gfl_importance(prob, cfg, iters=120, seed=1)
    assert np.isfinite(msd).all()
    assert msd[-1] < 0.3 * msd[0]
