"""Mini-mesh integration tests for the launch layer.

These spawn SUBPROCESSES with ``XLA_FLAGS=--xla_force_host_platform_device_count``
so the main pytest process keeps the true (1) device count, per the
dry-run isolation requirement.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_mesh_gfl_train_step_runs():
    """2 GFL steps on a 2x4 mini-mesh with real data; finite loss; sparse
    combine preserves the centroid identity vs dense combine."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import GFLConfig
        from repro.configs.registry import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.launch import steps as S
        from repro.models import Model
        from repro.data import TokenStream, federated_token_batches

        mesh = make_test_mesh((2, 4), ("data", "model"))
        cfg = get_config("smollm-135m").reduced()
        model = Model(cfg)
        stream = TokenStream(vocab=cfg.vocab_size, seed=0)

        results = {}
        for impl in ("sparse", "rotate", "dense"):
            gfl = GFLConfig(topology="ring", privacy="hybrid", sigma_g=0.1,
                            grad_bound=10.0, mu=0.05, combine_impl=impl)
            with mesh:
                step = jax.jit(S.make_train_step(model, gfl, mesh))
                state = S.init_train_state(model, gfl, mesh,
                                           jax.random.PRNGKey(0))
                batch = federated_token_batches(stream, 0, 0, P=2, L=2,
                                                per_client=2, seq_len=32)
                state, m = step(state, batch)
                state, m = step(state, batch)
            loss = float(m["loss"])
            assert np.isfinite(loss), impl
            cent = np.mean(np.asarray(
                jax.device_get(state.params["embed"]["table"]),
                np.float32), axis=0)
            results[impl] = (loss, cent)
            print(impl, "loss", loss)

        # same seed => identical noise draws; the three combine impls must
        # agree on the centroid (nullspace identity is impl-independent)
        for impl in ("rotate", "dense"):
            np.testing.assert_allclose(results[impl][1],
                                       results["sparse"][1],
                                       atol=5e-3)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_entrypoint_production_mesh():
    """The real dryrun module on the 16x16 production mesh (512 forced
    devices), smallest arch."""
    out = _run_sub("""
        from repro.launch import dryrun
        rec = dryrun.run_one("smollm-135m", "decode_32k", multi_pod=False,
                             save=False)
        assert rec["bottleneck"] in ("compute", "memory", "collective")
        assert rec["hlo_flops"] > 0 and rec["collective_bytes"] >= 0
        print("OK", rec["bottleneck"])
    """, devices=512, timeout=1200)
    assert "OK" in out


@pytest.mark.slow
def test_serve_prefill_decode_on_mesh():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.models import Model

        mesh = make_test_mesh((2, 4), ("data", "model"))
        cfg = get_config("phi3-mini-3.8b").reduced()
        model = Model(cfg)
        key = jax.random.PRNGKey(0)
        with mesh:
            params = model.init(key)
            batch = {"tokens": jnp.zeros((4, 32), jnp.int32)}
            logits, cache = jax.jit(model.prefill)(params, batch)
            toks = jnp.argmax(logits, -1)
            logits2, cache = jax.jit(model.decode_step)(params, toks, cache)
        assert np.isfinite(np.asarray(logits2, np.float32)).all()
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_client_parallel_matches_scan_path():
    """§Perf HC-3 mode is numerically identical to the reference client
    scan on a real mesh (per-client clipping and combine included)."""
    out = _run_sub("""
        import jax, numpy as np
        from repro.configs.base import GFLConfig
        from repro.configs.registry import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.launch import steps as S
        from repro.models import Model
        from repro.data import TokenStream, federated_token_batches

        mesh = make_test_mesh((2, 4), ("data", "model"))
        cfg = get_config("smollm-135m").reduced()
        model = Model(cfg)
        stream = TokenStream(vocab=cfg.vocab_size, seed=0)
        batch = federated_token_batches(stream, 0, 0, P=2, L=4,
                                        per_client=1, seq_len=32)
        res = {}
        for cp in (False, True):
            gfl = GFLConfig(topology="ring", privacy="none", sigma_g=0.0,
                            grad_bound=1.0, mu=0.05, combine_impl="sparse",
                            client_parallel=cp)
            with mesh:
                step = jax.jit(S.make_train_step(model, gfl, mesh, clients=4))
                state = S.init_train_state(model, gfl, mesh,
                                           jax.random.PRNGKey(0))
                state, m = step(state, batch)
            res[cp] = (float(m["loss"]), np.asarray(jax.device_get(
                state.params["embed"]["table"]), np.float32))
        assert abs(res[False][0] - res[True][0]) < 1e-3
        assert np.abs(res[False][1] - res[True][1]).max() < 5e-3
        print("OK")
    """)
    assert "OK" in out


def test_input_specs_cover_all_shapes():
    """input_specs builds well-formed ShapeDtypeStructs for every arch/shape
    without touching devices (pure metadata)."""
    out = _run_sub("""
        import numpy as np
        from repro.configs.base import INPUT_SHAPES
        from repro.configs.registry import ARCH_IDS, get_config
        from repro.launch.mesh import make_production_mesh
        from repro.launch import steps as S
        from repro.models import Model

        mesh = make_production_mesh()
        n = 0
        for arch in ARCH_IDS:
            if arch == "gfl-logreg":
                continue
            model = Model(get_config(arch))
            for name, shape in INPUT_SHAPES.items():
                specs = S.input_specs(model, shape, mesh)
                assert specs, (arch, name)
                n += 1
        assert n == 40, n
        print("OK", n)
    """, devices=512)
    assert "OK 40" in out
