"""Optimizers, schedules, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import TrainConfig
from repro.data import (
    TokenStream,
    dirichlet_partition,
    federated_token_batches,
    logistic_client_data,
    make_batch,
    uniform_partition,
)
from repro.optim import (
    adam,
    clip_by_global_norm,
    cosine_decay,
    make_optimizer,
    momentum,
    sgd,
    warmup_cosine,
)


# ------------------------------------------------------------------ optim --


def _quadratic_steps(opt, lr=0.1, steps=200):
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.0)}
    state = opt.init(params)
    grad_fn = jax.grad(lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2)
    for t in range(steps):
        g = grad_fn(params)
        upd, state = opt.update(g, state, params, jnp.asarray(t), lr)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    return params


@pytest.mark.parametrize("maker", [sgd, momentum, adam])
def test_optimizers_minimize_quadratic(maker):
    params = _quadratic_steps(maker())
    for leaf in jax.tree.leaves(params):
        assert np.abs(np.asarray(leaf)).max() < 1e-2


def test_make_optimizer_dispatch():
    for name in ("sgd", "momentum", "adam", "adamw"):
        make_optimizer(TrainConfig(optimizer=name))
    with pytest.raises(ValueError):
        make_optimizer(TrainConfig(optimizer="lion"))


def test_schedules():
    s = warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0)
    assert float(s(110)) < float(s(60)) < 1.0
    c = cosine_decay(2.0, 100, final_frac=0.5)
    assert float(c(100)) == pytest.approx(1.0)


def test_global_norm_clip():
    tree = {"a": jnp.ones((100,)), "b": jnp.ones((44,))}
    clipped, nrm = clip_by_global_norm(tree, 1.0)
    assert float(nrm) == pytest.approx(12.0)
    total = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped))
    assert total == pytest.approx(1.0, rel=1e-4)


# ------------------------------------------------------------------- data --


def test_token_stream_deterministic():
    st = TokenStream(vocab=128, seed=1)
    k = jax.random.PRNGKey(0)
    a = st.sample(k, 4, 64)
    b = st.sample(k, 4, 64)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = st.sample(jax.random.PRNGKey(1), 4, 64)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert int(a.max()) < 128 and int(a.min()) >= 0


def test_make_batch_shift():
    st = TokenStream(vocab=64, seed=0)
    b = make_batch(st, jax.random.PRNGKey(0), 2, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_federated_batches_disjoint_and_shaped():
    st = TokenStream(vocab=64, seed=0)
    batch = federated_token_batches(st, seed=0, step=3, P=3, L=2,
                                    per_client=2, seq_len=16)
    assert batch["tokens"].shape == (3, 2, 2, 16)
    # distinct (server, client) streams differ
    flat = np.asarray(batch["tokens"]).reshape(6, -1)
    assert len({tuple(r) for r in flat.tolist()}) > 1


def test_logistic_data_means():
    f, l = logistic_client_data(jax.random.PRNGKey(0), P=2, K=3, N=4000, M=2)
    # class-conditional mean ~ gamma * 1
    pos = np.asarray(f)[np.asarray(l) > 0]
    assert np.abs(pos.mean() - 1.0) < 0.1


def test_uniform_partition_covers():
    idx = uniform_partition(1000, P=4, K=5, seed=0)
    assert idx.shape == (4, 5, 50)
    flat = idx.reshape(-1)
    assert len(np.unique(flat)) == len(flat)


def test_dirichlet_partition_skew():
    labels = np.repeat(np.arange(4), 250)
    parts = dirichlet_partition(labels, P=2, K=2, alpha=0.1, seed=0)
    sizes = [len(parts[p][k]) for p in range(2) for k in range(2)]
    assert sum(sizes) == pytest.approx(1000, abs=4)
    # alpha=0.1 -> strong skew: client class hists far from uniform
    h = np.histogram(labels[parts[0][0]], bins=4)[0]
    assert h.max() > 2 * max(h.min(), 1)


# ------------------------------------------------------------- checkpoint --


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                      "b": jnp.ones((3,), jnp.bfloat16)},
            "head": jnp.full((4,), 2.0)}
    save_checkpoint(str(tmp_path / "ckpt"), tree, step=17)
    restored, step = load_checkpoint(str(tmp_path / "ckpt"), tree)
    assert step == 17
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.ones((2, 2))}
    save_checkpoint(str(tmp_path / "c2"), tree)
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path / "c2"), {"w": jnp.ones((3, 2))})
