"""Importance-sampling substrate: distribution validity under degenerate
norm estimates, and exact unbiasedness of the 1/(K pi) reweighting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import sampling as IS


def _probs(est):
    est = jnp.asarray(est, jnp.float32)
    state = IS.ISState(est, jnp.zeros(est.shape, jnp.int32))
    return np.asarray(IS.sampling_probs(state))


def _assert_valid_rows(probs):
    assert np.isfinite(probs).all()
    assert (probs >= 0).all()
    np.testing.assert_allclose(probs.sum(axis=1), 1.0, atol=1e-5)


# ------------------------------------------------ validity (property) -----


@given(P=st.integers(1, 4), K=st.integers(2, 24),
       seed=st.integers(0, 2**31 - 1),
       degenerate=st.sampled_from(["none", "zeros", "inf", "nan", "mixed"]))
@settings(max_examples=40, deadline=None)
def test_sampling_probs_rows_are_distributions(P, K, seed, degenerate):
    """Rows of sampling_probs are valid distributions for ARBITRARY norm
    estimates — including all-zero rows, infs and NaNs (zeros floor to the
    uniform distribution, infs are clipped, NaNs take the unit prior)."""
    rng = np.random.default_rng(seed)
    est = rng.gamma(1.0, 5.0, size=(P, K)).astype(np.float32)
    if degenerate == "zeros":
        est[rng.integers(0, P)] = 0.0
    elif degenerate == "inf":
        est[rng.integers(0, P), rng.integers(0, K)] = np.inf
    elif degenerate == "nan":
        est[rng.integers(0, P), rng.integers(0, K)] = np.nan
    elif degenerate == "mixed":
        est[:] = rng.choice([0.0, 1.0, np.inf, np.nan, 1e30],
                            size=(P, K))
    _assert_valid_rows(_probs(est))


def test_sampling_probs_degenerate_examples():
    """Example-based pins (run even without hypothesis installed)."""
    # all-zero row -> uniform
    p = _probs(np.zeros((2, 5)))
    _assert_valid_rows(p)
    np.testing.assert_allclose(p, 0.2, atol=1e-6)
    # one inf estimate must not zero everyone else out
    est = np.ones((1, 4))
    est[0, 0] = np.inf
    p = _probs(est)
    _assert_valid_rows(p)
    assert (p[0, 1:] > 0).all()
    # NaN estimates fall back to finite probabilities
    est = np.ones((1, 4))
    est[0, 2] = np.nan
    _assert_valid_rows(_probs(est))
    # healthy estimates keep the proportional behavior
    p = _probs(np.asarray([[1.0, 3.0]]))
    assert p[0, 1] == pytest.approx(0.75, rel=1e-5)


# --------------------------------------------------- unbiasedness ---------


def test_importance_weights_unbiased_exact_expectation():
    """Sum_k pi_k * x_k * w_k == mean(x) EXACTLY (the [23] estimator): the
    expectation identity behind the 1/(K pi) reweighting, evaluated in
    closed form on a toy population."""
    rng = np.random.default_rng(0)
    P, K = 3, 16
    x = rng.normal(size=(P, K))
    est = rng.gamma(1.0, 2.0, size=(P, K)).astype(np.float32)
    state = IS.ISState(jnp.asarray(est), jnp.zeros((P, K), jnp.int32))
    probs = IS.sampling_probs(state)
    idx = jnp.tile(jnp.arange(K)[None], (P, 1))
    w = IS.importance_weights(probs, idx)
    expectation = np.asarray((probs * jnp.asarray(x) * w).sum(axis=1))
    np.testing.assert_allclose(expectation, x.mean(axis=1), rtol=1e-5)


def test_importance_weights_unbiased_monte_carlo():
    """The sampled estimator (1/L) sum_i x_{k_i} w_{k_i} converges to the
    population mean over many cohorts."""
    rng = np.random.default_rng(1)
    K, L, trials = 12, 4, 4000
    x = rng.normal(size=(1, K))
    est = rng.gamma(1.0, 2.0, size=(1, K)).astype(np.float32)
    state = IS.ISState(jnp.asarray(est), jnp.zeros((1, K), jnp.int32))
    probs = IS.sampling_probs(state)

    def one(key):
        idx = IS.sample_clients(key, probs, L)
        w = IS.importance_weights(probs, idx)
        return (jnp.asarray(x)[0, idx[0]] * w[0]).mean()

    keys = jax.random.split(jax.random.PRNGKey(2), trials)
    ests = np.asarray(jax.vmap(one)(keys))
    assert ests.mean() == pytest.approx(float(x.mean()), abs=0.05)


def test_importance_weights_k_norm_targets_available_mean():
    """With an availability mask, k_norm = K_avail makes the estimator
    unbiased for the mean over AVAILABLE clients."""
    rng = np.random.default_rng(2)
    K = 10
    x = rng.normal(size=(1, K))
    avail = np.ones((1, K), bool)
    avail[0, 7:] = False                      # 7 available
    base = jnp.full((1, K), 1.0 / K)
    eff = base * avail
    eff = eff / eff.sum(axis=1, keepdims=True)
    idx = jnp.tile(jnp.arange(K)[None], (1, 1))
    w = IS.importance_weights(eff, idx, k_norm=jnp.asarray([7.0]))
    expectation = float((eff * jnp.asarray(x) * w).sum())
    assert expectation == pytest.approx(float(x[0, :7].mean()), rel=1e-4)


@given(seed=st.integers(0, 2**31 - 1), K=st.integers(2, 20),
       floor=st.floats(0.01, 0.5))
@settings(max_examples=25, deadline=None)
def test_unbiasedness_property(seed, K, floor):
    """The closed-form expectation identity holds for any estimates/floor."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(1, K))
    est = rng.gamma(0.7, 3.0, size=(1, K)).astype(np.float32)
    state = IS.ISState(jnp.asarray(est), jnp.zeros((1, K), jnp.int32))
    probs = IS.sampling_probs(state, floor=floor)
    idx = jnp.arange(K)[None]
    w = IS.importance_weights(probs, idx)
    expectation = float((probs * jnp.asarray(x) * w).sum())
    assert expectation == pytest.approx(float(x.mean()), rel=1e-4, abs=1e-6)


def test_update_norm_estimates_only_touches_sampled():
    state = IS.init_is_state(2, 6)
    idx = jnp.asarray([[0, 2], [5, 5]])
    norms = jnp.asarray([[4.0, 8.0], [2.0, 2.0]])
    new = IS.update_norm_estimates(state, idx, norms)
    est = np.asarray(new.norm_est)
    assert est[0, 0] != 1.0 and est[0, 2] != 1.0
    np.testing.assert_array_equal(est[0, [1, 3, 4, 5]], 1.0)
    assert est[1, 5] != 1.0
