import os

# Tests run on the single real CPU device.  The 512-device production mesh
# is exercised ONLY via subprocess tests (test_dryrun.py) so jax here sees
# the true device count.  Keep threads tame on the 1-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
