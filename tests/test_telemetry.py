"""Telemetry layer tests (docs/observability.md).

The two-sided contract: ``telemetry=off`` is bit-identical to an
uninstrumented run on every engine (the off path never inserts a
callback or changes a carry), and ``telemetry=on`` observes without
perturbing — same msd/params, with schema-valid records flowing to the
sinks.  Plus the building blocks: schema registry, sinks, span tracer,
the mergeable quantile sketch and the inspector CLI.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GFLConfig
from repro.core.events import run_gfl_async
from repro.core.population import SyntheticPopulation, run_gfl_population
from repro.core.simulate import generate_problem, run_gfl
from repro.telemetry import (
    MetricsStream,
    QuantileSketch,
    RunLog,
    SchemaError,
    emit,
    get_schema,
    list_schemas,
    session,
    telemetry_active,
    trace_span,
    validate_record,
)
from tests.hypothesis_compat import given, settings, st

REPO_ROOT = Path(__file__).resolve().parents[1]


# ------------------------------------------------------------------ schema

def test_schemas_registered_and_validate():
    names = set(list_schemas())
    assert {"round", "step", "privacy", "kernel", "mesh"} <= names
    validate_record("round", {"round": 0, "msd": 0.5, "engine": "population"})
    with pytest.raises(SchemaError):
        validate_record("round", {"round": 0, "bogus_field": 1.0})
    with pytest.raises(SchemaError):
        validate_record("round", {"msd": 0.5})      # index missing
    with pytest.raises(SchemaError):
        validate_record("no_such_stream", {"x": 1})
    assert get_schema("privacy").index == "step"


# ---------------------------------------------------------------- sessions

def test_emit_is_noop_without_session():
    assert not telemetry_active()
    emit("round", {"round": 0, "bogus_field": 1.0})  # not even validated


def test_emit_host_and_in_graph():
    with session("memory") as sess:
        assert telemetry_active()
        emit("round", {"round": 0, "msd": 1.0, "engine": "test"})

        @jax.jit
        def f(x):
            emit("step", {"step": 0, "msd": x})
            return x * 2

        def body(c, x):
            emit("step", {"step": c, "msd": x})
            return c + 1, x

        f(jnp.float32(3.0))
        jax.lax.scan(body, jnp.int32(1), jnp.arange(3, dtype=jnp.float32))
        jax.effects_barrier()
        assert len(sess.memory_records("round")) == 1
        steps = sess.memory_records("step")
        assert len(steps) == 4
        assert all(r["stream"] == "step" and "t_wall" in r for r in steps)
    assert not telemetry_active()


def test_nested_session_is_passthrough():
    with session("memory") as outer:
        with session("memory") as inner:
            assert inner is outer
            emit("round", {"round": 0, "msd": 0.0})
        assert telemetry_active()       # inner exit must not close outer
        assert len(outer.memory_records("round")) == 1


def test_metrics_stream_accumulates_in_scan():
    ms = MetricsStream("step", cumulative={"events_total": "events"})
    with session("memory") as sess:
        def body(carry, x):
            c, acc = carry
            acc = ms.tap(acc, {"step": c, "events": x})
            return (c + 1, acc), x

        jax.lax.scan(body, (jnp.int32(0), ms.init()),
                     jnp.array([2, 3, 4], jnp.int32))
        jax.effects_barrier()
        recs = sess.memory_records("step")
    assert [r["events"] for r in recs] == [2, 3, 4]
    assert [r["events_total"] for r in recs] == [2, 5, 9]


def test_trace_span_writes_chrome_json(tmp_path):
    trace = tmp_path / "t.trace.json"
    with session("memory", trace_path=trace):
        with trace_span("outer", detail="x"):
            with trace_span("inner"):
                pass
    doc = json.loads(trace.read_text())
    events = doc["traceEvents"]
    assert {e["name"] for e in events} >= {"outer", "inner"}
    for e in events:
        assert e["ph"] == "X" and e["dur"] >= 0 and "ts" in e
    # no session -> null span, no crash
    with trace_span("nobody"):
        pass


# ------------------------------------------------------------------- sinks

def test_jsonl_and_csv_sinks(tmp_path):
    jl = tmp_path / "run.jsonl"
    cb = tmp_path / "run"
    with session(f"jsonl:{jl}+csv:{cb}"):
        emit("round", {"round": 0, "msd": 0.25, "engine": "test"})
        emit("round", {"round": 1, "msd": 0.125, "engine": "test"})
        emit("privacy", {"step": 1, "eps": float("inf"), "delta": 0.0})
    recs = [json.loads(ln) for ln in jl.read_text().splitlines()]
    assert len(recs) == 3
    for r in recs:
        validate_record(r["stream"],
                        {k: v for k, v in r.items()
                         if k not in ("stream", "run", "t_wall")})
    assert recs[2]["eps"] == float("inf")
    csv_round = tmp_path / "run.round.csv"
    lines = csv_round.read_text().splitlines()
    assert lines[0].startswith("run,t_wall,round,engine")
    assert len(lines) == 3


def test_console_sink_runs(capfd):
    with session("console:1"):
        emit("round", {"round": 0, "msd": 0.5, "q": 0.1, "engine": "t"})
        emit("round", {"round": 1, "msd": 0.25, "q": 0.1, "engine": "t"})
    cap = capfd.readouterr()
    out = cap.out + cap.err        # console sink renders on stderr
    assert "msd" in out and "round" in out


def test_console_every_arg_decimates(capfd):
    from repro.telemetry.sinks import ConsoleSink, sink_from_spec
    sink = sink_from_spec("console:3")
    assert isinstance(sink, ConsoleSink) and sink.every == 3

    with session("console:3"):
        for i in range(7):
            emit("round", {"round": i, "msd": float(100 + i),
                           "engine": "t"})
    cap = capfd.readouterr()
    out = cap.out + cap.err
    # only rounds 2 and 5 (the 3rd and 6th records) render
    assert "102" in out and "105" in out
    assert "101" not in out and "104" not in out and "106" not in out


def test_bad_sink_spec_rejected():
    with pytest.raises(ValueError):
        with session("carrier_pigeon"):
            pass


# ------------------------------------------------- buffered flush / profile

def test_metrics_stream_buffered_matches_per_round(tmp_path):
    """flush_every=3 must deliver record-for-record what flush_every=1
    does (including the drained partial buffer at the tail)."""
    xs = jnp.arange(1, 8, dtype=jnp.int32)        # 7 rows: 2 full + 1 part

    def collect(flush_every):
        ms = MetricsStream("step", cumulative={"events_total": "events"},
                           fields=("step", "events", "events_total"),
                           flush_every=flush_every)
        with session("memory") as sess:
            def body(carry, x):
                c, acc = carry
                acc = ms.tap(acc, {"step": c, "events": x})
                return (c + 1, acc), x

            (_, acc), _ = jax.lax.scan(body, (jnp.int32(0), ms.init()), xs)
            jax.effects_barrier()
            ms.drain(acc)
            recs = sess.memory_records("step")
        return [{k: r[k] for k in ("step", "events", "events_total")}
                for r in recs]

    assert collect(1) == collect(3)


def test_metrics_stream_buffered_requires_fields():
    with pytest.raises(ValueError):
        MetricsStream("step", flush_every=4)


def test_flush_every_env(monkeypatch):
    from repro.telemetry import flush_every_from_env
    monkeypatch.delenv("REPRO_TELEMETRY_FLUSH_EVERY", raising=False)
    assert flush_every_from_env() == 1
    monkeypatch.setenv("REPRO_TELEMETRY_FLUSH_EVERY", "8")
    assert flush_every_from_env() == 8
    monkeypatch.setenv("REPRO_TELEMETRY_FLUSH_EVERY", "junk")
    assert flush_every_from_env() == 1


def test_profile_stream_attributes_compile(tmp_path):
    with session("memory", profile=True) as sess:
        @jax.jit
        def f(x):
            return x * 2 + 1

        with trace_span("fresh_jit", tag="t"):
            jax.block_until_ready(f(jnp.arange(101, dtype=jnp.float32)))
    recs = sess.memory_records("profile")
    assert len(recs) == 1
    r = recs[0]
    validate_record("profile", {k: v for k, v in r.items()
                                if k not in ("stream", "run", "t_wall",
                                             "phase_args")})
    assert r["phase"] == "fresh_jit"
    assert r["compiles"] >= 1 and r["retraces"] >= 1
    assert r["compile_s"] > 0.0
    assert r["wall_s"] >= r["compile_s"]
    assert r["execute_s"] >= 0.0 and r["callback_s"] >= 0.0


def test_profile_off_by_default():
    with session("memory") as sess:
        with trace_span("plain"):
            pass
    assert sess.memory_records("profile") == []


def test_jaxprof_env_passthrough(monkeypatch):
    from repro.telemetry.trace import SpanTracer
    monkeypatch.delenv("REPRO_TELEMETRY_JAXPROF", raising=False)
    assert SpanTracer().annotate is False
    monkeypatch.setenv("REPRO_TELEMETRY_JAXPROF", "1")
    tracer = SpanTracer()
    assert tracer.annotate is True
    # annotated spans still record events (TraceAnnotation wraps cleanly
    # even outside a profiler capture)
    with tracer.span("annotated", k=1):
        pass
    assert [e["name"] for e in tracer.events] == ["annotated"]
    # explicit annotate beats the env var
    assert SpanTracer(annotate=False).annotate is False
    monkeypatch.setenv("REPRO_TELEMETRY_JAXPROF", "0")
    assert SpanTracer().annotate is False


# ------------------------------------------------------------------ sketch

def _rank_error(data, est, q):
    data = np.sort(np.asarray(data))
    rank = np.searchsorted(data, est) / max(len(data) - 1, 1)
    return abs(rank - q)


def test_sketch_rank_error_vs_numpy():
    rng = np.random.default_rng(0)
    data = rng.normal(size=5000)
    sk = QuantileSketch(k=128)
    sk.extend(data)
    for q in (0.1, 0.5, 0.9, 0.99):
        assert _rank_error(data, sk.quantile(q), q) < 0.05, q
    assert sk.min == data.min() and sk.max == data.max()


def test_sketch_merge_invariance():
    rng = np.random.default_rng(1)
    a, b = rng.normal(size=3000), rng.normal(loc=2.0, size=2000)
    both = np.concatenate([a, b])
    sa, sb = QuantileSketch(k=128), QuantileSketch(k=128)
    sa.extend(a)
    sb.extend(b)
    merged = sa.merge(sb)
    for q in (0.25, 0.5, 0.75):
        assert _rank_error(both, merged.quantile(q), q) < 0.08, q


def test_sketch_serialization_roundtrip():
    sk = QuantileSketch(k=16)
    sk.extend(range(100))
    back = QuantileSketch.from_dict(sk.to_dict())
    assert back.quantile(0.5) == sk.quantile(0.5)
    assert back.min == sk.min and back.max == sk.max


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(min_value=-1e6, max_value=1e6,
                          allow_nan=False), min_size=2, max_size=400),
       st.integers(min_value=1, max_value=399))
def test_sketch_merge_matches_bulk(values, cut):
    """Property: splitting a stream at any point and merging the two
    sketches bounds the same quantiles as sketching the whole stream."""
    cut = min(cut, len(values) - 1)
    bulk = QuantileSketch(k=64)
    bulk.extend(values)
    left, right = QuantileSketch(k=64), QuantileSketch(k=64)
    left.extend(values[:cut])
    right.extend(values[cut:])
    merged = left.merge(right)
    for q in (0.0, 0.5, 1.0):
        assert _rank_error(values, merged.quantile(q), q) <= \
            _rank_error(values, bulk.quantile(q), q) + 0.25


# ------------------------------------------------------------------ runlog

def test_runlog_rows_and_stack():
    log = RunLog("test_engine", stream="round")
    log.row(0, msd=1.0, gap=None)           # None values dropped
    log.row(1, msd=0.5, gap=0.3)
    assert log.column("msd") == [1.0, 0.5]
    assert log.stack("gap").shape == (1,)
    assert log.stack("nothing") is None


def test_runlog_extend_arrays_validates_lengths():
    log = RunLog("test_engine")
    with pytest.raises(ValueError):
        log.extend_arrays({"msd": np.zeros(3), "q": np.zeros(4)})


# -------------------------------------------------- engine bit-identity

def _pop_cfg(privacy, **kw):
    return GFLConfig(num_servers=3, clients_per_server=20,
                     clients_sampled=4, topology="ring", privacy=privacy,
                     sigma_g=0.1, mu=0.1, grad_bound=10.0, **kw)


@pytest.mark.parametrize("privacy", ["none", "iid_dp", "hybrid"])
@pytest.mark.parametrize("scan", [False, True])
def test_population_off_identical_and_on_pure(privacy, scan):
    pop = SyntheticPopulation(3, 20, mode="hetero", N=30, M=2, data_seed=0)
    kw = dict(iters=4, batch_size=5, seed=0, scan=scan)
    base = run_gfl_population(pop, _pop_cfg(privacy), **kw)
    off = run_gfl_population(pop, _pop_cfg(privacy, telemetry="off"), **kw)
    with session("memory") as sess:
        on = run_gfl_population(pop, _pop_cfg(privacy, telemetry="memory"),
                                **kw)
        recs = sess.memory_records("round")
    np.testing.assert_array_equal(np.asarray(base.msd), np.asarray(off.msd))
    np.testing.assert_array_equal(np.asarray(base.params),
                                  np.asarray(off.params))
    np.testing.assert_array_equal(np.asarray(base.msd), np.asarray(on.msd))
    np.testing.assert_array_equal(np.asarray(base.params),
                                  np.asarray(on.params))
    # result views and the stream agree row for row
    msd_stream = [r["msd"] for r in recs if "msd" in r]
    np.testing.assert_allclose(np.asarray(on.msd), msd_stream)


@pytest.mark.parametrize("privacy", ["none", "iid_dp", "hybrid"])
def test_dense_engine_off_identical(privacy):
    prob = generate_problem(jax.random.PRNGKey(0), P=3, K=8, N=30, M=2)
    cfg_off = GFLConfig(num_servers=3, clients_per_server=8,
                        topology="ring", privacy=privacy, sigma_g=0.1,
                        mu=0.1, grad_bound=10.0)
    msd0, p0 = run_gfl(prob, cfg_off, iters=3, batch_size=4, seed=0)
    with session("memory"):
        cfg_on = GFLConfig(**{**cfg_off.__dict__, "telemetry": "memory"})
        msd1, p1 = run_gfl(prob, cfg_on, iters=3, batch_size=4, seed=0)
    np.testing.assert_array_equal(np.asarray(msd0), np.asarray(msd1))
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))


@pytest.mark.parametrize("scan", [False, True])
def test_async_engine_off_identical_and_streams(scan):
    pop = SyntheticPopulation(3, 24, mode="hetero", N=30, M=2, data_seed=0)
    spec = "async:buffer=4,rate=4,latency=exp:0.7,max_stale=2"
    kw = dict(ticks=5, batch_size=5, seed=0, scan=scan)
    off = run_gfl_async(pop, _pop_cfg("hybrid", async_spec=spec), **kw)
    with session("memory") as sess:
        on = run_gfl_async(pop, _pop_cfg("hybrid", async_spec=spec,
                                         telemetry="memory"), **kw)
        rounds = sess.memory_records("round")
        privacy = sess.memory_records("privacy")
    np.testing.assert_array_equal(np.asarray(off.msd), np.asarray(on.msd))
    np.testing.assert_array_equal(np.asarray(off.params),
                                  np.asarray(on.params))
    np.testing.assert_array_equal(off.q, on.q)
    np.testing.assert_array_equal(off.staleness, on.staleness)
    np.testing.assert_array_equal(off.flushed, on.flushed)
    assert len(rounds) == 5
    # view satellite: AsyncRunResult fields ARE the stream's rows
    np.testing.assert_allclose(np.asarray(on.msd),
                               [r["msd"] for r in rounds])
    np.testing.assert_array_equal(
        on.flushed.astype(np.int32),
        np.asarray([r["flushed"] for r in rounds], np.int32))
    assert privacy, "async accounting must emit the privacy stream"
    assert {r["server"] for r in privacy} >= {"server0"}
    for r in privacy:
        assert r["eps"] >= 0 or r["eps"] == float("inf")


def test_population_kernels_off_identical():
    pop = SyntheticPopulation(3, 20, mode="hetero", N=30, M=2, data_seed=0)
    kw = dict(iters=3, batch_size=5, seed=0, scan=False)
    off = run_gfl_population(pop, _pop_cfg("hybrid", use_kernels=True), **kw)
    with session("memory"):
        on = run_gfl_population(
            pop, _pop_cfg("hybrid", use_kernels=True, telemetry="memory"),
            **kw)
    np.testing.assert_array_equal(np.asarray(off.msd), np.asarray(on.msd))
    np.testing.assert_array_equal(np.asarray(off.params),
                                  np.asarray(on.params))


# -------------------------------------------------------- inspector CLI

def _run_inspect(args):
    return subprocess.run(
        [sys.executable, "-m", "repro.telemetry.inspect"] + args,
        cwd=REPO_ROOT, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu", "HOME": "/tmp"})


def test_inspector_cli_on_engine_output(tmp_path):
    jl = tmp_path / "run.jsonl"
    trace = tmp_path / "run.trace.json"
    pop = SyntheticPopulation(3, 20, mode="hetero", N=30, M=2, data_seed=0)
    with session(f"jsonl:{jl}", trace_path=trace):
        run_gfl_population(pop, _pop_cfg("hybrid", telemetry="jsonl"),
                           iters=3, batch_size=5, seed=0, scan=True)
    proc = _run_inspect([str(jl), "--trace", str(trace), "--tail", "2"])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "privacy" in proc.stdout and "eps" in proc.stdout
    assert "valid Chrome trace" in proc.stdout


def test_inspector_cli_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"stream": "round", "bogus_field": 3}\nnot json\n')
    proc = _run_inspect([str(bad)])
    assert proc.returncode == 1
