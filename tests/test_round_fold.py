"""Fused round-fold kernel + whole-run ``use_kernels`` switch: parity of the
Pallas backend against the ref-jnp backend across mechanisms x dtypes x
padding edges, engine-level parity (``run_gfl`` / ``run_gfl_population`` /
``run_gfl_async``) of ``use_kernels=True`` vs ``False``, the sync-limit
bit-identity through the events engine under kernels, the block-size /
padding regression for odd D, and the flat-in-L secure-agg compile time."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GFLConfig
from repro.core.simulate import generate_problem, run_gfl
from repro.core.topology import combination_matrix
from repro.kernels import ops, ref

_TOL = {jnp.float32: 3e-5, jnp.bfloat16: 3e-2}


def _inputs(P, L, D, dtype, key=0, per_client_base=False):
    k = jax.random.PRNGKey(key)
    w_shape = (P, L, D) if per_client_base else (P, D)
    w = jax.random.normal(k, w_shape).astype(dtype)
    grads = (jax.random.normal(jax.random.fold_in(k, 1), (P, L, D)) * 3
             ).astype(dtype)
    pre = jax.random.uniform(jax.random.fold_in(k, 2), (P, L),
                             minval=0.3, maxval=2.0)
    fold = jax.random.uniform(jax.random.fold_in(k, 3), (P, L))
    noise = (jax.random.normal(jax.random.fold_in(k, 4), (P, L, D)) * 0.3
             ).astype(dtype)
    seeds = (jnp.arange(P, dtype=jnp.uint32) * 31 + 7)
    return w, grads, pre, fold, noise, seeds


# ------------------------------------------------------- kernel-level parity


@pytest.mark.parametrize("mode", ["none", "mask", "laplace"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("P,L,D", [
    (8, 8, 512),     # aligned everywhere
    (3, 5, 130),     # P % 8 != 0, L % 8 != 0, D % 128 != 0
    (10, 7, 509),    # odd/prime D (the old _block_for pathology)
])
def test_round_fold_backend_parity(mode, dtype, P, L, D):
    w, grads, pre, fold, noise, seeds = _inputs(P, L, D, dtype)
    kw = dict(mu=0.1, bound=2.0, pre_w=pre, fold_w=fold, mode=mode,
              sigma=0.5, seeds=seeds if mode == "mask" else None,
              noise=noise if mode == "laplace" else None)
    psi_p, sq_p = ops.round_fold(w, grads, **kw)
    psi_r, sq_r = ops.round_fold(w, grads, backend="ref", **kw)
    tol = _TOL[dtype]
    np.testing.assert_allclose(np.asarray(psi_p, np.float32),
                               np.asarray(psi_r, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sq_p), np.asarray(sq_r),
                               rtol=1e-3)


def test_round_fold_per_client_base():
    """Per-client stale bases [P, L, D] (the event engine's snapshots)."""
    w, grads, pre, fold, _, _ = _inputs(4, 6, 257, jnp.float32,
                                        per_client_base=True)
    a, _ = ops.round_fold(w, grads, mu=0.1, bound=1.5, pre_w=pre,
                          fold_w=fold)
    b, _ = ops.round_fold(w, grads, mu=0.1, bound=1.5, pre_w=pre,
                          fold_w=fold, backend="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_round_fold_matches_unfused_semantics():
    """The fold equals the hand-written clip -> update -> weighted fold."""
    from repro.core.gfl import clip_to_bound
    P, L, D = 3, 4, 64
    w, grads, pre, fold, _, _ = _inputs(P, L, D, jnp.float32)
    psi, sq = ops.round_fold(w, grads, mu=0.2, bound=1.0, pre_w=pre,
                             fold_w=fold)

    def one(wp, gp, prew, fw):
        upd = jnp.stack([wp - 0.2 * clip_to_bound(prew[k] * gp[k], 1.0)
                         for k in range(L)])
        return (fw[:, None] * upd).sum(0) / fw.sum()

    exp = jax.vmap(one)(w, grads, pre, fold)
    np.testing.assert_allclose(np.asarray(psi), np.asarray(exp), atol=2e-5)
    np.testing.assert_allclose(np.asarray(sq),
                               np.asarray(jnp.sum(grads * grads, -1)),
                               rtol=1e-4)


def test_round_fold_mask_cancellation():
    """Uniform survivor weights: in-kernel mask streams cancel exactly —
    psi equals the mode="none" fold to float dust."""
    w, grads, _, _, _, seeds = _inputs(4, 6, 256, jnp.float32)
    base, _ = ops.round_fold(w, grads, mu=0.1, bound=2.0)
    masked, _ = ops.round_fold(w, grads, mu=0.1, bound=2.0, mode="mask",
                               sigma=1.0, seeds=seeds)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(base),
                               atol=1e-4)


def test_round_fold_zero_fold_weight():
    """Zero total fold weight folds to zero (the empty-buffer contract)."""
    w, grads, _, _, _, _ = _inputs(2, 3, 128, jnp.float32)
    psi, _ = ops.round_fold(w, grads, mu=0.1, bound=1.0,
                            fold_w=jnp.zeros((2, 3)))
    np.testing.assert_array_equal(np.asarray(psi), 0.0)


# -------------------------------------------- block choice / padding (ops)


def test_block_choice_never_degenerate():
    """Odd/prime D pads UP to the 128 tile; blocks stay 128-aligned (the
    old ``_block_for`` heuristic collapsed to 1-wide grids)."""
    for d in (509, 1018, 1021, 130, 2):
        cands, d_pad = ops.block_candidates(d)
        assert d_pad % 128 == 0 and d_pad >= d
        assert all(c % 128 == 0 for c in cands)
        assert all(d_pad % c == 0 for c in cands)


def test_odd_d_509_regression():
    """D=509 through every wrapper: correct vs oracle, no degenerate grid."""
    k = jax.random.PRNGKey(0)
    g = jax.random.normal(k, (3, 509))
    np.testing.assert_allclose(np.asarray(ops.clip_accum(g, 1.0)),
                               np.asarray(ref.clip_accum_ref(g, 1.0)),
                               atol=1e-5)
    A = jnp.asarray(combination_matrix("ring", 5), jnp.float32)
    psi = jax.random.normal(k, (5, 509))
    gg = jax.random.normal(jax.random.fold_in(k, 1), (5, 509))
    np.testing.assert_allclose(np.asarray(ops.graph_combine(A, psi, gg)),
                               np.asarray(ref.graph_combine_ref(A.T, psi,
                                                                gg)),
                               atol=3e-5)


def test_autotune_caches_per_shape():
    ops.clear_autotune_cache()
    u = jax.random.normal(jax.random.PRNGKey(0), (4, 1024))
    ops.laplace_transform(u, 0.5)
    n = len(ops._AUTOTUNE_CACHE)
    assert n >= 1
    ops.laplace_transform(u * 2, 0.5)        # same shape -> cache hit
    assert len(ops._AUTOTUNE_CACHE) == n
    block = next(v for k, v in ops._AUTOTUNE_CACHE.items()
                 if k[0] == "laplace")
    assert block in (128, 256, 512, 1024)


# ------------------------------------------------- gated combine (events)


def test_graph_combine_gate_cache():
    """In-kernel cached-psi re-announce == where() + plain combine."""
    P, D = 6, 384
    k = jax.random.PRNGKey(3)
    A = jnp.asarray(combination_matrix("ring", P), jnp.float32)
    psi = jax.random.normal(k, (P, D))
    g = jax.random.normal(jax.random.fold_in(k, 1), (P, D))
    cache = jax.random.normal(jax.random.fold_in(k, 2), (P, D))
    gate = jnp.asarray([1, 0, 1, 1, 0, 1], jnp.float32)
    out = ops.graph_combine(A, psi, g, cache=cache, gate=gate)
    psi_eff = jnp.where(gate[:, None] > 0, psi, cache)
    exp = ref.graph_combine_ref(A.T, psi_eff, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5)
    # noise-free variant (g=None)
    out = ops.graph_combine(A, psi, None, cache=cache, gate=gate)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(A.T.astype(jnp.float32) @ psi_eff.astype(jnp.float32)),
        atol=3e-5)


# -------------------------------------------------- engine-level parity


@pytest.fixture(scope="module")
def problem():
    return generate_problem(jax.random.PRNGKey(0), P=4, K=6, N=20, M=2)


def _cfg(scheme, **kw):
    base = dict(num_servers=4, clients_per_server=6, privacy=scheme,
                sigma_g=0.3, mu=0.1, topology="ring", grad_bound=5.0)
    base.update(kw)
    return GFLConfig(**base)


@pytest.mark.parametrize("scheme", ["none", "iid_dp", "hybrid"])
def test_run_gfl_kernel_parity(problem, scheme):
    """Whole-run switch on the dense engine: bit-identical draws (iid noise
    comes from the reference sampler on the same keys; masks cancel), so
    trajectories agree to float reordering."""
    base = _cfg(scheme)
    kern = dataclasses.replace(base, use_kernels=True)
    m0, p0 = run_gfl(problem, base, iters=4, batch_size=5, seed=1)
    m1, p1 = run_gfl(problem, kern, iters=4, batch_size=5, seed=1)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p0), atol=1e-5)
    np.testing.assert_allclose(m1, m0, atol=1e-5)


@pytest.mark.parametrize("scheme", ["none", "iid_dp", "hybrid"])
def test_run_gfl_population_weighted_kernel_parity(problem, scheme):
    """Importance-sampled cohorts: pre-clip weights + norms feedback run
    through the kernel's norms pass."""
    from repro.core.population.engine import run_gfl_population
    base = _cfg(scheme, clients_sampled=3, cohort="importance")
    kern = dataclasses.replace(base, use_kernels=True)
    r0 = run_gfl_population(problem, base, iters=4, batch_size=5, seed=1)
    r1 = run_gfl_population(problem, kern, iters=4, batch_size=5, seed=1)
    np.testing.assert_allclose(np.asarray(r1.params), np.asarray(r0.params),
                               atol=1e-5)
    np.testing.assert_allclose(r1.q, r0.q)


@pytest.mark.parametrize("scheme", ["none", "iid_dp", "hybrid"])
def test_run_gfl_async_kernel_parity(problem, scheme):
    """Event engine with stale snapshots + staleness-weighted folds."""
    from repro.core.events.engine import run_gfl_async
    base = _cfg(scheme, async_spec="async:buffer=4,rate=3,"
                                   "latency=lognorm:0.5,max_stale=2")
    kern = dataclasses.replace(base, use_kernels=True)
    r0 = run_gfl_async(problem, base, ticks=5, batch_size=5, seed=1)
    r1 = run_gfl_async(problem, kern, ticks=5, batch_size=5, seed=1)
    np.testing.assert_allclose(np.asarray(r1.params), np.asarray(r0.params),
                               atol=1e-5)
    np.testing.assert_array_equal(r1.flushed, r0.flushed)
    np.testing.assert_allclose(r1.q, r0.q)


@pytest.mark.parametrize("scheme", ["none", "iid_dp", "hybrid"])
def test_async_sync_limit_bit_identity_with_kernels(problem, scheme):
    """use_kernels=True sync limit routes through the population engine's
    EXACT programs: bit-identical trajectories, by construction."""
    from repro.core.events.engine import run_gfl_async
    from repro.core.population.engine import run_gfl_population
    cfg = _cfg(scheme, clients_sampled=3, use_kernels=True,
               async_spec="async:buffer=3,rate=3,max_stale=0")
    ra = run_gfl_async(problem, cfg, ticks=4, batch_size=5, seed=2)
    rp = run_gfl_population(
        problem, dataclasses.replace(cfg, async_spec="none"),
        iters=4, batch_size=5, seed=2)
    assert np.array_equal(np.asarray(ra.params), np.asarray(rp.params))
    np.testing.assert_array_equal(np.asarray(ra.msd),
                                  np.asarray(rp.msd))


def test_scan_executors_accept_kernels(problem):
    """Whole-run lax.scan bodies trace the Pallas calls (population scan +
    async scan) and agree with the streaming loops."""
    from repro.core.events.engine import run_gfl_async
    from repro.core.population.engine import run_gfl_population
    cfg = _cfg("hybrid", clients_sampled=3, use_kernels=True, sigma_g=0.2)
    rs = run_gfl_population(problem, cfg, iters=3, batch_size=5, seed=3,
                            scan=True)
    rl = run_gfl_population(problem, cfg, iters=3, batch_size=5, seed=3)
    np.testing.assert_allclose(np.asarray(rs.params), np.asarray(rl.params),
                               atol=1e-6)
    cfga = dataclasses.replace(
        cfg, clients_sampled=0,
        async_spec="async:buffer=3,rate=3,latency=lognorm:0.4,max_stale=2")
    r2 = run_gfl_async(problem, cfga, ticks=4, batch_size=5, seed=4,
                       scan=True)
    r3 = run_gfl_async(problem, cfga, ticks=4, batch_size=5, seed=4)
    np.testing.assert_allclose(np.asarray(r2.params), np.asarray(r3.params),
                               atol=1e-6)


def test_run_gfl_dropout_kernel_parity(problem):
    """Client dropout: alive masks become fold weights, masks/noise fold at
    the survivor mean — parity against the dropout-safe reference hooks."""
    base = _cfg("hybrid", fault="dropout:0.4")
    kern = dataclasses.replace(base, use_kernels=True)
    m0, p0 = run_gfl(problem, base, iters=4, batch_size=5, seed=5)
    m1, p1 = run_gfl(problem, kern, iters=4, batch_size=5, seed=5)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p0), atol=1e-5)


# ------------------------------------------- secure-agg compile-flat in L


@pytest.mark.parametrize("L", [8, 64])
def test_secure_agg_trace_cost(L, request):
    """The O(L) fori_loop mask accumulation keeps trace/compile time FLAT
    in the cohort size: L=64 must stay within 2x of L=8 (the unrolled pair
    loop was quadratic — 2016 streams at L=64)."""
    from repro.kernels import secure_agg as sagg

    def lower(L):
        upd = jax.ShapeDtypeStruct((L, 256), jnp.float32)
        sd = jax.ShapeDtypeStruct((1,), jnp.uint32)
        fn = jax.jit(lambda u, s: sagg.secure_agg_mean(
            u, s, scale=0.5, block_d=128, interpret=True))
        t0 = time.perf_counter()
        fn.lower(upd, sd)
        return time.perf_counter() - t0

    lower(4)                      # warm the tracing machinery once
    times = {l: min(lower(l) for _ in range(3)) for l in (8, 64)}
    assert times[64] < 2.0 * times[8] + 0.05, times


def test_mesh_kernel_dense_combine_matches_einsum():
    """launch/steps.py routes the mesh's dense combine through the fused
    kernel per leaf (flatten -> graph_combine -> reshape), matching the
    einsum baseline incl. bf16 leaves and the g=None (noise-free) path."""
    from repro.launch.steps import _dense_combine, _kernel_dense_combine
    P = 6
    A = jnp.asarray(combination_matrix("ring", P), jnp.float32)
    k = jax.random.PRNGKey(0)
    psi = {"a": jax.random.normal(k, (P, 3, 7)).astype(jnp.bfloat16),
           "b": jax.random.normal(jax.random.fold_in(k, 1), (P, 11))}
    g = {"a": (jax.random.normal(jax.random.fold_in(k, 2), (P, 3, 7)) * 0.3
               ).astype(jnp.bfloat16),
         "b": jax.random.normal(jax.random.fold_in(k, 3), (P, 11)) * 0.3}
    want = _dense_combine(A, psi, g, cancel=True)
    got = _kernel_dense_combine(A, psi, g)
    for leaf in psi:
        tol = 2e-2 if psi[leaf].dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(got[leaf], np.float32),
                                   np.asarray(want[leaf], np.float32),
                                   atol=tol)
    want0 = _dense_combine(A, psi, None)
    got0 = _kernel_dense_combine(A, psi, None)
    for leaf in psi:
        np.testing.assert_allclose(np.asarray(got0[leaf], np.float32),
                                   np.asarray(want0[leaf], np.float32),
                                   atol=2e-2)


def test_round_pipeline_traffic_halved():
    """The analytic HBM accounting (the BENCH_kernels.json criterion): the
    fused pipeline does <= 1/2 the gradient-scale HBM round trips of the
    reference chain for both privacy modes — and for the paper's hybrid
    (mask) scheme the full byte total is <= 1/2 as well (laplace's
    parity-preserving pre-drawn noise operand is counted honestly on the
    fused side: 4 vs 8 [P, L, D] passes, byte ratio -> 0.5 from above as
    the [P, D] terms vanish)."""
    from repro.launch.roofline import round_pipeline_traffic
    for mode in ("mask", "laplace"):
        for P, L, D in ((10, 8, 4096), (16, 64, 1 << 20)):
            ref_b = round_pipeline_traffic(P, L, D, mode=mode, fused=False)
            fus_b = round_pipeline_traffic(P, L, D, mode=mode, fused=True)
            assert (fus_b["pld_passes"]
                    <= 0.5 * ref_b["pld_passes"]), (mode, P, L, D)
            if mode == "mask":
                assert fus_b["total"] <= 0.5 * ref_b["total"], (P, L, D)


def test_secure_agg_l64_matches_plain_mean():
    """L=64 (previously 2016 unrolled pair streams) now traces instantly
    and still cancels exactly."""
    upd = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
    out = ops.secure_agg_mean(upd, jnp.array([3], jnp.uint32), scale=0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(upd.mean(0)),
                               atol=2e-4)
