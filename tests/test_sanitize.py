"""Runtime sanitizer mode: flag scoping, ledger cross-checks, and the
always-on engine accounting the sanitizer verifies."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import GFLConfig
from repro.core.events.engine import run_gfl_async
from repro.core.population.engine import run_gfl_population
from repro.sanitize import (ENV_FLAG, ReleaseLedger, SanitizerError,
                            sanitize_enabled, sanitizer_scope)

CFG = GFLConfig(num_servers=3, clients_per_server=4, clients_sampled=2,
                population="synthetic:iid,sigma=1.0,n=20,dim=4")


def test_sanitize_enabled_sources(monkeypatch):
    monkeypatch.delenv(ENV_FLAG, raising=False)
    assert not sanitize_enabled()
    assert not sanitize_enabled(CFG)
    assert sanitize_enabled(dataclasses.replace(CFG, sanitize=True))
    monkeypatch.setenv(ENV_FLAG, "1")
    assert sanitize_enabled()
    assert sanitize_enabled(CFG)
    monkeypatch.setenv(ENV_FLAG, "0")
    assert not sanitize_enabled(CFG)


def test_sanitizer_scope_sets_and_restores_flags():
    before = (jax.config.jax_debug_nans, jax.config.jax_debug_key_reuse)
    with sanitizer_scope():
        assert jax.config.jax_debug_nans
        assert jax.config.jax_debug_key_reuse
    after = (jax.config.jax_debug_nans, jax.config.jax_debug_key_reuse)
    assert after == before


def test_sanitizer_scope_catches_nan():
    import jax.numpy as jnp
    with sanitizer_scope():
        with pytest.raises(FloatingPointError):
            jax.jit(lambda x: jnp.log(x))(-1.0).block_until_ready()


def test_ledger_cross_check():
    led = ReleaseLedger()
    led.record_release(4)
    led.record_charge(4)
    led.cross_check()
    led.record_release()
    with pytest.raises(SanitizerError):
        led.cross_check()


def test_ledger_charge_from_accountants():
    class Sync:
        step = 5

    class Async:
        releases = [2, 3, 1]

    led = ReleaseLedger()
    led.charge_from(Sync())
    assert led.charged == 5
    led = ReleaseLedger()
    led.charge_from(Async())
    assert led.charged == 6


# ------------------------------------------------ engine integration
def test_population_run_attaches_charged_accountant():
    res = run_gfl_population(None, CFG, iters=5, batch_size=2)
    assert res.accountant is not None
    assert res.accountant.step == 5
    assert len(res.accountant.q_history) == 5
    np.testing.assert_allclose(res.accountant.q_history, res.q)
    assert res.accountant.epsilon() > 0


def test_population_run_under_sanitize_mode():
    cfg = dataclasses.replace(CFG, sanitize=True)
    res = run_gfl_population(None, cfg, iters=4, batch_size=2)
    assert res.accountant.step == 4
    assert np.all(np.isfinite(res.msd))
    # flags restored after the run
    assert not jax.config.jax_debug_nans


def test_population_sanitize_env_flag(monkeypatch):
    monkeypatch.setenv(ENV_FLAG, "1")
    res = run_gfl_population(None, CFG, iters=3, batch_size=2)
    assert res.accountant.step == 3


def test_async_run_attaches_schedule_charged_accountant():
    cfg = dataclasses.replace(
        CFG, async_spec="async:buffer=2,latency=fixed:1,max_stale=4",
        sanitize=True)
    res = run_gfl_async(None, cfg, ticks=6, batch_size=2)
    assert res.accountant is not None
    # every realized flush is charged to its server's ledger
    np.testing.assert_array_equal(res.accountant.releases, res.releases)
    assert res.accountant.epsilon() > 0


def test_weighted_path_realized_q_matches_accountant():
    cfg = dataclasses.replace(CFG, cohort="importance,floor=0.2",
                              sanitize=True)
    res = run_gfl_population(None, cfg, iters=3, batch_size=2)
    np.testing.assert_allclose(res.accountant.q_history, res.q)
