"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.topology import combination_matrix
from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape)
    return x.astype(dtype)


_TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ----------------------------------------------------------- graph_combine


@pytest.mark.parametrize("P", [4, 10, 16])
@pytest.mark.parametrize("D", [128, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_graph_combine_sweep(P, D, dtype):
    A = jnp.asarray(combination_matrix("ring", P), jnp.float32)
    key = jax.random.PRNGKey(P * D)
    psi = _rand(key, (P, D), dtype)
    g = _rand(jax.random.fold_in(key, 1), (P, D), dtype)
    out = ops.graph_combine(A, psi, g)
    exp = ref.graph_combine_ref(A.T, psi, g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=_TOL[dtype], rtol=_TOL[dtype])


def test_graph_combine_centroid_nullspace():
    """Fused kernel preserves the eq.-25 identity: centroid(out) ==
    centroid(A^T psi) == centroid(psi)."""
    P, D = 8, 512
    A = jnp.asarray(combination_matrix("full", P), jnp.float32)
    key = jax.random.PRNGKey(0)
    psi = jax.random.normal(key, (P, D))
    g = jax.random.normal(jax.random.fold_in(key, 1), (P, D)) * 3.0
    out = ops.graph_combine(A, psi, g)
    np.testing.assert_allclose(np.asarray(out.mean(0)),
                               np.asarray(psi.mean(0)), atol=1e-4)


@given(P=st.integers(2, 12), D=st.sampled_from([64, 384, 777]),
       seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_graph_combine_hypothesis(P, D, seed):
    A = jnp.asarray(combination_matrix("full", P), jnp.float32)
    key = jax.random.PRNGKey(seed)
    psi = jax.random.normal(key, (P, D))
    g = jax.random.normal(jax.random.fold_in(key, 1), (P, D))
    out = ops.graph_combine(A, psi, g)
    exp = ref.graph_combine_ref(A.T, psi, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5)


# ------------------------------------------------------------- secure_agg


@pytest.mark.parametrize("L", [2, 5, 8])
@pytest.mark.parametrize("D", [128, 1000])
def test_secure_agg_sweep(L, D):
    key = jax.random.PRNGKey(L * D)
    upd = jax.random.normal(key, (L, D))
    seed = jnp.array([17], jnp.uint32)
    out = ops.secure_agg_mean(upd, seed, scale=0.7)
    exp = ref.secure_agg_mean_ref(upd, seed, 0.7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)
    # net effect == plain mean (masks cancel)
    np.testing.assert_allclose(np.asarray(out), np.asarray(upd.mean(0)),
                               atol=1e-4)


def test_secure_agg_deterministic_in_seed():
    upd = jnp.ones((4, 256))
    a = ops.secure_agg_mean(upd, jnp.array([1], jnp.uint32))
    b = ops.secure_agg_mean(upd, jnp.array([1], jnp.uint32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------- laplace


@pytest.mark.parametrize("shape", [(4, 128), (10, 1000), (1, 4096)])
@pytest.mark.parametrize("sigma", [0.1, 1.0])
def test_laplace_sweep(shape, sigma):
    key = jax.random.PRNGKey(3)
    u = jax.random.uniform(key, shape, minval=-0.4999, maxval=0.4999)
    out = ops.laplace_transform(u, sigma)
    exp = ref.laplace_transform_ref(u, sigma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6)


def test_laplace_distribution_moments():
    key = jax.random.PRNGKey(11)
    u = jax.random.uniform(key, (64, 8192), minval=-0.4999, maxval=0.4999)
    out = np.asarray(ops.laplace_transform(u, 0.5))
    assert abs(out.mean()) < 0.01
    assert out.std() == pytest.approx(0.5, rel=0.03)


# -------------------------------------------------------------- clip_accum


@pytest.mark.parametrize("L", [2, 6])
@pytest.mark.parametrize("D", [128, 2048])
@pytest.mark.parametrize("bound", [0.5, 100.0])
def test_clip_accum_sweep(L, D, bound):
    key = jax.random.PRNGKey(L + D)
    g = jax.random.normal(key, (L, D)) * 3
    out = ops.clip_accum(g, bound)
    exp = ref.clip_accum_ref(g, bound)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-5, rtol=1e-5)


def test_clip_accum_enforces_bound():
    g = jnp.ones((1, 1024)) * 10.0          # norm = 320
    out = np.asarray(ops.clip_accum(g, 1.0))
    assert np.linalg.norm(out) <= 1.0 + 1e-4


@given(L=st.integers(1, 8), D=st.sampled_from([64, 333, 1024]),
       bound=st.floats(0.1, 50.0), seed=st.integers(0, 9999))
@settings(max_examples=15, deadline=None)
def test_clip_accum_hypothesis(L, D, bound, seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (L, D))
    out = ops.clip_accum(g, bound)
    exp = ref.clip_accum_ref(g, bound)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=2e-5)


# --------------------------------------------------------- swa decode attn


@pytest.mark.parametrize("C", [64, 256, 1000])
@pytest.mark.parametrize("nvalid_frac", [0.3, 1.0])
def test_swa_decode_attention_sweep(C, nvalid_frac):
    B, H, KVH, Dh = 2, 8, 4, 64
    key = jax.random.PRNGKey(C)
    q = jax.random.normal(key, (B, H, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, C, KVH, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, C, KVH, Dh))
    nvalid = jnp.array([max(int(C * nvalid_frac), 1)], jnp.int32)
    out = ops.swa_decode_attention(q, k, v, nvalid)
    kr = jnp.repeat(k, 2, axis=2)
    vr = jnp.repeat(v, 2, axis=2)
    exp = ref.swa_decode_attention_ref(q, kr, vr, nvalid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-4)


@given(C=st.sampled_from([32, 128, 384]), nv=st.integers(1, 384),
       seed=st.integers(0, 999))
@settings(max_examples=10, deadline=None)
def test_swa_decode_attention_hypothesis(C, nv, seed):
    B, H, Dh = 1, 4, 32
    nv = min(nv, C)
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (B, H, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, C, H, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, C, H, Dh))
    nvalid = jnp.array([nv], jnp.int32)
    out = ops.swa_decode_attention(q, k, v, nvalid)
    exp = ref.swa_decode_attention_ref(q, k, v, nvalid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=3e-5, rtol=3e-4)
