"""End-to-end system tests: the paper's full pipeline on CPU."""
import jax
import numpy as np
import pytest

from repro.configs.base import GFLConfig
from repro.core.simulate import generate_problem, run_schemes


@pytest.mark.slow
def test_full_paper_pipeline():
    """Section-V experiment end to end (reduced iterations): data gen ->
    3 privatization schemes -> Fig-2 orderings hold."""
    prob, msd = run_schemes(jax.random.PRNGKey(0), iters=100, sigma_g=0.5,
                            P=6, K=10, L=5, repeats=1, topology="full",
                            batch_size=10)
    for scheme, trace in msd.items():
        assert np.isfinite(trace).all(), scheme
        assert trace[-1] < trace[0], f"{scheme} did not converge"
    tail = {s: float(np.mean(t[-10:])) for s, t in msd.items()}
    assert tail["hybrid"] < tail["iid_dp"], tail
