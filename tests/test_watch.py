"""Live run monitor tests: watch grammar, Watcher rule units, CLI gate.

The grammar round-trip itself is covered registry-wide in
tests/test_specs.py (the ``watch`` grammar is registered like fault /
cohort / async); here we pin the rule *semantics* — each alert kind
fires on a seeded violation and stays quiet on a clean stream — and the
``--once`` CI-gate exit codes end to end via subprocess.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.telemetry.watch import (
    Watcher,
    parse_watch_spec,
    watch_to_spec,
)

REPO_ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------- grammar

def test_parse_canonicalizes_and_round_trips():
    rules = parse_watch_spec("eps:0.9,target=4+nan+gap:0.05")
    assert [r.kind for r in rules] == ["eps", "nan", "gap"]
    assert rules[0].param("frac") == 0.9
    assert rules[0].param("target") == 4.0
    spec = watch_to_spec(rules)
    assert parse_watch_spec(spec) == rules


@pytest.mark.parametrize("bad", [
    "",                      # empty
    "bogus:1",               # unknown kind
    "gap",                   # missing required value
    "nan:0.5",               # nan takes no value
    "gap:0.05,target=4",     # parameter not allowed for this kind
    "eps:0.9,window=3",      # window is a throughput-only parameter
])
def test_parse_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_watch_spec(bad)


def test_throughput_window_defaults():
    (rule,) = parse_watch_spec("throughput:0.5")
    assert rule.param("window") == 20.0
    assert rule.to_spec() == "throughput:0.5,window=20"


# ----------------------------------------------------------- rule units

def _rec(stream, **kv):
    return {"stream": stream, "run": "r0", "t_wall": 0.0, **kv}


def test_eps_rule_fires_at_fraction():
    w = Watcher(parse_watch_spec("eps:0.9,target=4"))
    assert w.feed(_rec("privacy", step=1, eps=3.0, delta=0.0)) == []
    fired = w.feed(_rec("privacy", step=2, eps=3.7, delta=0.0))
    assert len(fired) == 1 and "eps_spent" in fired[0]["message"]
    # eps = inf is a meaningful ledger state, never an eps alert
    assert w.feed(_rec("privacy", step=3, eps=float("inf"),
                       delta=0.0)) == []


def test_eps_rule_uses_cli_target_fallback():
    w = Watcher(parse_watch_spec("eps:0.5"), epsilon_target=2.0)
    assert len(w.feed(_rec("privacy", step=1, eps=1.5, delta=0.0))) == 1
    # no target anywhere -> rule cannot evaluate, stays quiet
    assert Watcher(parse_watch_spec("eps:0.5")).feed(
        _rec("privacy", step=1, eps=1.5, delta=0.0)) == []


def test_gap_and_norm_rules():
    w = Watcher(parse_watch_spec("gap:0.05+norm:100"))
    assert w.feed(_rec("round", round=0, gap=0.2, update_norm=5.0)) == []
    fired = w.feed(_rec("round", round=1, gap=0.01, update_norm=500.0))
    assert {f["rule"].split(":")[0] for f in fired} == {"gap", "norm"}


def test_nan_rule_scans_series_and_exempts_privacy():
    w = Watcher(parse_watch_spec("nan"))
    assert w.feed(_rec("step", step=0, msd=[0.1, 0.2])) == []
    assert len(w.feed(_rec("step", step=1,
                           msd=[0.1, float("nan")]))) == 1
    assert len(w.feed(_rec("round", round=2, msd=float("inf")))) == 1
    assert w.feed(_rec("privacy", step=3, eps=float("inf"),
                       delta=0.0)) == []


def test_stale_rule():
    w = Watcher(parse_watch_spec("stale:4"))
    assert w.feed(_rec("step", step=0, staleness=[1.0, 3.5])) == []
    assert len(w.feed(_rec("step", step=1, staleness=[1.0, 9.0]))) == 1


def test_throughput_rule_needs_full_window_then_fires():
    w = Watcher(parse_watch_spec("throughput:0.5,window=4"))
    for i in range(4):
        assert w.feed(_rec("step", step=i, events=10.0)) == []
    fired = w.feed(_rec("step", step=4, events=2.0))
    assert len(fired) == 1 and "throughput drop" in fired[0]["message"]
    # the drop itself joins the trailing window afterwards
    assert w._events[-1] == 2.0


# ------------------------------------------------------------------ CLI

def _run_watch(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.telemetry.watch", *args],
        capture_output=True, text=True, cwd=REPO_ROOT,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})


def test_cli_once_clean_exits_zero(tmp_path):
    jl = tmp_path / "run.jsonl"
    jl.write_text("\n".join(
        json.dumps(_rec("step", step=i, msd=0.5 / (i + 1), events=8.0))
        for i in range(5)) + "\n")
    proc = _run_watch(str(jl), "--rules", "nan+gap:0.05", "--once")
    assert proc.returncode == 0, proc.stderr
    assert "0 alert(s)" in proc.stdout


def test_cli_once_alerting_exits_one_and_writes_alerts(tmp_path):
    jl = tmp_path / "run.jsonl"
    alerts = tmp_path / "alerts.jsonl"
    recs = [_rec("round", round=0, msd=0.5, gap=0.2),
            _rec("round", round=1, msd=float("nan"), gap=0.01)]
    jl.write_text("\n".join(json.dumps(r) for r in recs) + "\n")
    proc = _run_watch(str(jl), "--rules", "nan+gap:0.05", "--once",
                      "--alerts", str(alerts))
    assert proc.returncode == 1, proc.stderr
    assert "ALERT" in proc.stderr
    lines = [json.loads(ln) for ln in alerts.read_text().splitlines()]
    assert {a["rule"].split(":")[0] for a in lines} == {"nan", "gap"}


def test_cli_bad_spec_and_missing_file_exit_two(tmp_path):
    jl = tmp_path / "run.jsonl"
    jl.write_text("")
    assert _run_watch(str(jl), "--rules", "bogus:1",
                      "--once").returncode == 2
    assert _run_watch(str(tmp_path / "nope.jsonl"),
                      "--once").returncode == 2
