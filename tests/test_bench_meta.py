"""Benchmark provenance + regression-gate tests.

write_bench appends the compact headline record to BENCH_history.jsonl;
compare.py exits 1 on a seeded regression and 0 on in-tolerance runs;
``python -m repro.telemetry.inspect bench`` renders trends from the
history.  All exercised on synthetic payloads under tmp_path — the real
repo-root payloads are never touched.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from benchmarks.compare import (
    compare_payload,
    find_baseline,
    load_history,
    main as compare_main,
)
from benchmarks.meta import normalize_headline, write_bench

REPO_ROOT = Path(__file__).resolve().parents[1]


def _payload(value, *, sha="abc", ts="2026-08-09T00:00:00+0000",
             name="toy_bench", tol=None, direction="higher", abs_tol=None):
    decl = {"value": value, "direction": direction}
    if tol is not None:
        decl["tol"] = tol
    if abs_tol is not None:
        decl["abs_tol"] = abs_tol
    return {
        "benchmark": name, "reduced": True, "repeats": 4,
        "meta": {"git_sha": sha, "timestamp": ts, "backend": "cpu",
                 "host": "h"},
        "headline": normalize_headline({"speed": decl}),
    }


# ----------------------------------------------------------- write_bench

def test_write_bench_appends_history(tmp_path):
    out = tmp_path / "BENCH_toy.json"
    hist = tmp_path / "BENCH_history.jsonl"
    for i in range(2):
        write_bench(out, {"benchmark": "toy_bench", "reduced": True,
                          "repeats": 3},
                    headline={"speed": ("higher", 100.0 + i)},
                    history=hist)
    doc = json.loads(out.read_text())
    assert doc["headline"]["speed"] == {"value": 101.0,
                                        "direction": "higher"}
    assert "meta" in doc and doc["meta"]["git_sha"]
    records = [json.loads(ln) for ln in hist.read_text().splitlines()]
    assert len(records) == 2          # append-only: one record per write
    assert [r["headline"]["speed"]["value"] for r in records] \
        == [100.0, 101.0]
    assert records[0]["benchmark"] == "toy_bench"
    assert records[0]["git_sha"] == doc["meta"]["git_sha"]
    assert records[0]["repeats"] == 3


def test_normalize_headline_forms_and_validation():
    out = normalize_headline({"a": ("lower", 2, 0.1),
                              "b": {"value": 3, "direction": "higher",
                                    "abs_tol": 5}})
    assert out["a"] == {"value": 2.0, "direction": "lower", "tol": 0.1}
    assert out["b"] == {"value": 3.0, "direction": "higher", "abs_tol": 5.0}
    with pytest.raises(ValueError):
        normalize_headline({"x": ("sideways", 1.0)})


# ------------------------------------------------------------- compare

def test_find_baseline_skips_current_run_and_other_backends():
    history = [
        {"benchmark": "toy_bench", "backend": "tpu", "git_sha": "old",
         "timestamp": "t0", "headline": {"speed": {"value": 1.0,
                                                   "direction": "higher"}}},
        {"benchmark": "toy_bench", "backend": "cpu", "git_sha": "old",
         "timestamp": "t1", "headline": {"speed": {"value": 90.0,
                                                   "direction": "higher"}}},
        {"benchmark": "toy_bench", "backend": "cpu", "git_sha": "abc",
         "timestamp": "2026-08-09T00:00:00+0000",
         "headline": {"speed": {"value": 50.0, "direction": "higher"}}},
    ]
    base = find_baseline(history, _payload(50.0))
    assert base is not None and base["git_sha"] == "old" \
        and base["backend"] == "cpu"


def _history_entry(payload):
    return {"benchmark": payload["benchmark"],
            "backend": payload["meta"]["backend"],
            "git_sha": payload["meta"]["git_sha"],
            "timestamp": payload["meta"]["timestamp"],
            "repeats": payload.get("repeats"),
            "headline": payload["headline"]}


def test_compare_flags_regression_and_tolerates_noise():
    prev = _history_entry(_payload(100.0, sha="old", ts="t0"))
    # repeats=4 -> default tol 0.25/sqrt(4) = 12.5%: -10% ok, -50% not
    (row,) = compare_payload(_payload(92.0), [prev], 0.25)
    assert row["status"] == "ok"
    (row,) = compare_payload(_payload(50.0), [prev], 0.25)
    assert row["status"] == "REGRESSION"
    # lower-is-better flips the gate
    prev_l = _history_entry(_payload(100.0, sha="old", ts="t0",
                                     direction="lower"))
    (row,) = compare_payload(_payload(150.0, direction="lower"),
                             [prev_l], 0.25)
    assert row["status"] == "REGRESSION"


def test_compare_abs_tol_handles_near_zero_metrics():
    # a -1% -> +4% overhead swing is a 5-point move on a near-zero base:
    # relative gates explode, abs_tol absorbs it
    prev = _history_entry(_payload(-1.0, sha="old", ts="t0",
                                   direction="lower", abs_tol=10.0))
    (row,) = compare_payload(_payload(4.0, direction="lower",
                                      abs_tol=10.0), [prev], 0.25)
    assert row["status"] == "ok"
    (row,) = compare_payload(_payload(20.0, direction="lower",
                                      abs_tol=10.0), [prev], 0.25)
    assert row["status"] == "REGRESSION"


def test_compare_no_baseline_and_new_metric_pass():
    (row,) = compare_payload(_payload(50.0), [], 0.25)
    assert row["status"] == "no-baseline"
    prev = _history_entry(_payload(100.0, sha="old", ts="t0"))
    prev["headline"] = {"other": {"value": 1.0, "direction": "higher"}}
    (row,) = compare_payload(_payload(50.0), [prev], 0.25)
    assert row["status"] == "new-metric"


def test_compare_main_exit_codes(tmp_path):
    out = tmp_path / "BENCH_toy.json"
    hist = tmp_path / "BENCH_history.jsonl"

    def meta(ts):
        # explicit meta: distinct timestamps regardless of wall clock
        # (write_bench's setdefault keeps a caller-provided block)
        return {"git_sha": "abc", "timestamp": ts, "backend": "cpu",
                "host": "h"}

    write_bench(out, {"benchmark": "toy_bench", "repeats": 4,
                      "meta": meta("t0")},
                headline={"speed": ("higher", 100.0)}, history=hist)
    # same payload re-measured in tolerance -> exit 0
    write_bench(out, {"benchmark": "toy_bench", "repeats": 4,
                      "meta": meta("t1")},
                headline={"speed": ("higher", 97.0)}, history=hist)
    assert compare_main(["--root", str(tmp_path)]) == 0
    # seeded -50% regression -> exit 1
    write_bench(out, {"benchmark": "toy_bench", "repeats": 4,
                      "meta": meta("t2")},
                headline={"speed": ("higher", 48.0)}, history=hist)
    assert compare_main(["--root", str(tmp_path)]) == 1
    # no payloads at all -> usage error
    assert compare_main(["--root", str(tmp_path / "empty")]) == 2


def test_repo_payloads_pass_compare():
    """The committed BENCH payloads + history must gate clean (the CI
    nightly runs exactly this)."""
    assert load_history(REPO_ROOT / "BENCH_history.jsonl"), \
        "BENCH_history.jsonl missing or empty"
    assert compare_main(["--root", str(REPO_ROOT)]) == 0


# -------------------------------------------------------- inspect bench

def test_inspect_bench_cli(tmp_path):
    out = tmp_path / "BENCH_toy.json"
    hist = tmp_path / "BENCH_history.jsonl"
    for v in (100.0, 104.0, 98.0):
        write_bench(out, {"benchmark": "toy_bench", "repeats": 4},
                    headline={"speed": ("higher", v)}, history=hist)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.telemetry.inspect", "bench",
         str(hist)],
        capture_output=True, text=True, cwd=REPO_ROOT,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr
    assert "toy_bench" in proc.stdout and "speed" in proc.stdout
    assert "98" in proc.stdout          # latest value rendered
    missing = subprocess.run(
        [sys.executable, "-m", "repro.telemetry.inspect", "bench",
         str(tmp_path / "nope.jsonl")],
        capture_output=True, text=True, cwd=REPO_ROOT,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert missing.returncode == 1
