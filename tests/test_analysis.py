"""gflint rule + CLI tests: every rule fires on a seeded violation and
stays quiet on the fixed version; the committed baseline reproduces."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis import run_analysis
from repro.analysis.baseline import (diff_against_baseline, load_baseline,
                                     save_baseline)
from repro.analysis.cli import main as cli_main

REPO_ROOT = Path(__file__).resolve().parents[1]

def lint(tmp_path, source, filename="mod.py", extra=None):
    """Write fixture module(s) and run gflint over the tmp tree."""
    (tmp_path / filename).parent.mkdir(parents=True, exist_ok=True)
    (tmp_path / filename).write_text(textwrap.dedent(source))
    for name, text in (extra or {}).items():
        p = tmp_path / name
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return run_analysis([tmp_path], root=tmp_path)

def rules_fired(findings):
    return {f.rule for f in findings}

# --------------------------------------------------------------- GFL001
def test_gfl001_fires_on_key_reuse(tmp_path):
    findings = lint(tmp_path, """
        import jax

        def f(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
    """)
    assert [f for f in findings if f.rule == "GFL001"], findings
    (f,) = [f for f in findings if f.rule == "GFL001"]
    assert "reused" in f.message and f.context == "f"

def test_gfl001_quiet_with_split_or_fold_in(tmp_path):
    findings = lint(tmp_path, """
        import jax

        def f(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (3,))
            b = jax.random.uniform(k2, (3,))
            c = jax.random.normal(jax.random.fold_in(key, 0), (3,))
            d = jax.random.normal(jax.random.fold_in(key, 1), (3,))
            return a + b + c + d
    """)
    assert "GFL001" not in rules_fired(findings), findings

def test_gfl001_rebinding_clears_consumption(tmp_path):
    findings = lint(tmp_path, """
        import jax

        def f(key):
            out = 0.0
            for i in range(3):
                key, sub = jax.random.split(key)
                out += jax.random.normal(sub, ())
            return out
    """)
    assert "GFL001" not in rules_fired(findings), findings

def test_gfl001_loop_invariant_key_caught(tmp_path):
    findings = lint(tmp_path, """
        import jax

        def f(key):
            out = 0.0
            for i in range(3):
                out += jax.random.normal(key, ())
            return out
    """)
    assert "GFL001" in rules_fired(findings), findings

def test_gfl001_lambda_params_are_their_own_scope(tmp_path):
    # two vmapped lambdas both naming their key `k` are NOT reuse
    findings = lint(tmp_path, """
        import jax

        def f(key, probs):
            ka, kb = jax.random.split(key)
            i = jax.vmap(lambda k: jax.random.choice(k, 5, (2,)))(
                jax.random.split(ka, 3))
            j = jax.vmap(lambda k, p: jax.random.choice(k, 5, (2,), p=p))(
                jax.random.split(kb, 3), probs)
            return i, j
    """)
    assert "GFL001" not in rules_fired(findings), findings

def test_gfl001_literal_prngkey_fires_and_factory_is_exempt(tmp_path):
    findings = lint(tmp_path, """
        import jax

        def init():
            return jax.random.PRNGKey(0)
    """)
    assert any(f.rule == "GFL001" and "literal" in f.message
               for f in findings), findings
    # the approved factory file may construct literal keys
    findings = lint(tmp_path / "factory", """
        import jax

        def rng_key(seed=0):
            return jax.random.PRNGKey(0 if seed is None else seed)
    """, filename="repro/__init__.py")
    assert "GFL001" not in rules_fired(findings), findings

def test_gfl001_pragma_suppresses(tmp_path):
    findings = lint(tmp_path, """
        import jax

        def init():
            return jax.random.PRNGKey(0)  # gflint: disable=GFL001
    """)
    assert "GFL001" not in rules_fired(findings), findings

# --------------------------------------------------------------- GFL002
UNCHARGED = """
    def release_round(updates, key, mech):
        return mech.client_protect(updates, key, None)

    def caller(updates, key, mech):
        return release_round(updates, key, mech)
"""

CHARGED = UNCHARGED + """
    def engine(updates, key, mech, acc):
        out = caller(updates, key, mech)
        acc.advance(1)
        return out
"""

def test_gfl002_fires_without_charge_path(tmp_path):
    findings = lint(tmp_path, UNCHARGED)
    assert any(f.rule == "GFL002" and "client_protect" in f.message
               for f in findings), findings

def test_gfl002_quiet_when_transitive_caller_charges(tmp_path):
    findings = lint(tmp_path, CHARGED)
    assert "GFL002" not in rules_fired(findings), findings

def test_gfl002_async_charges_count(tmp_path):
    findings = lint(tmp_path, """
        def engine(flushed, q, mech, acc):
            psi = mech.client_protect_masked(1.0, 2.0, None, None)
            acc.record_schedule(flushed, q)
            return psi
    """)
    assert "GFL002" not in rules_fired(findings), findings

# --------------------------------------------------------------- GFL003
def test_gfl003_fires_on_python_if_in_jit(tmp_path):
    findings = lint(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert any(f.rule == "GFL003" and "`if`" in f.message
               for f in findings), findings

def test_gfl003_fires_on_host_cast_and_numpy(tmp_path):
    findings = lint(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return float(x) + np.sum(x)
    """)
    msgs = [f.message for f in findings if f.rule == "GFL003"]
    assert any("float()" in m for m in msgs), msgs
    assert any("numpy call" in m for m in msgs), msgs

def test_gfl003_fires_on_fn_passed_to_tracer(tmp_path):
    findings = lint(tmp_path, """
        import jax

        def body(carry, x):
            if x > 0:
                carry = carry + x
            return carry, x

        def run(xs):
            return jax.lax.scan(body, 0.0, xs)
    """)
    assert "GFL003" in rules_fired(findings), findings

def test_gfl003_static_argnames_and_structural_reads_exempt(tmp_path):
    findings = lint(tmp_path, """
        from functools import partial

        import jax
        import jax.numpy as jnp

        @partial(jax.jit, static_argnames=("mode", "bound"))
        def f(x, gate, mode, bound):
            if mode == "ref":
                return x
            if bound > 0:
                x = jnp.clip(x, -bound, bound)
            if x.ndim == 3:
                x = x.sum(0)
            if gate is None:
                return x
            return jnp.where(gate, x, 0.0)
    """)
    assert "GFL003" not in rules_fired(findings), findings

def test_gfl003_untraced_function_unflagged(tmp_path):
    findings = lint(tmp_path, """
        def f(x):
            if x > 0:
                return float(x)
            return -x
    """)
    assert "GFL003" not in rules_fired(findings), findings

# --------------------------------------------------------------- GFL004
OP_OK = """
    import jax
    from . import ref as _ref

    def round_op(x, *, backend="pallas"):
        if backend == "ref":
            return _ref.round_op_ref(x)
        return x * 2
"""

def test_gfl004_fires_without_ref_counterpart(tmp_path):
    findings = lint(tmp_path, """
        def round_op(x, *, backend="pallas"):
            return x * 2
    """, extra={"tests/test_ops.py": "from mod import round_op\n"})
    msgs = [f.message for f in findings if f.rule == "GFL004"]
    assert any("no ref counterpart" in m for m in msgs), findings

def test_gfl004_fires_without_parity_test(tmp_path):
    findings = lint(tmp_path, OP_OK)
    msgs = [f.message for f in findings if f.rule == "GFL004"]
    assert any("no parity test" in m for m in msgs), findings

def test_gfl004_quiet_with_ref_and_test(tmp_path):
    findings = lint(tmp_path, OP_OK, extra={
        "tests/test_ops.py": "from mod import round_op\n"})
    assert "GFL004" not in rules_fired(findings), findings

def test_gfl004_private_helpers_exempt(tmp_path):
    findings = lint(tmp_path, """
        def _resolve(backend, interpret):
            return backend == "ref" or interpret
    """)
    assert "GFL004" not in rules_fired(findings), findings

# --------------------------------------------------------------- GFL005
def test_gfl005_fires_on_unregistered_parser(tmp_path):
    findings = lint(tmp_path, """
        def parse_widget_spec(spec):
            return spec.split(":")
    """)
    assert any(f.rule == "GFL005" and "parse_widget_spec" in f.message
               for f in findings), findings

def test_gfl005_quiet_when_registered_and_registry_tested(tmp_path):
    findings = lint(tmp_path, """
        def parse_widget_spec(spec):
            return spec.split(":")

        def widget_to_spec(parts):
            return ":".join(parts)
    """, extra={
        "registry.py": """
            from mod import parse_widget_spec, widget_to_spec

            def register_grammar(name, parse, to_spec):
                return (name, parse, to_spec)

            register_grammar("widget", parse_widget_spec, widget_to_spec)
        """,
        "tests/test_specs.py": """
            def test_round_trips(all_grammars):
                pass
        """,
    })
    assert "GFL005" not in rules_fired(findings), findings

def test_gfl005_fires_on_registered_but_untested_grammar(tmp_path):
    findings = lint(tmp_path, """
        def parse_widget_spec(spec):
            return spec.split(":")

        def register_grammar(name, parse, to_spec):
            return (name, parse, to_spec)

        register_grammar("widget", parse_widget_spec, str)
    """)
    assert any(f.rule == "GFL005" and "widget" in f.message
               and "round-trip" in f.message for f in findings), findings

# --------------------------------------------------------------- GFL006
def test_gfl006_fires_on_raw_io_callback_in_jit(tmp_path):
    findings = lint(tmp_path, """
        import jax
        from jax.experimental import io_callback

        @jax.jit
        def f(x):
            io_callback(print, None, x)
            return x
    """)
    assert any(f.rule == "GFL006" and "io_callback" in f.message
               for f in findings), findings

def test_gfl006_fires_on_debug_callback_in_scan_body(tmp_path):
    findings = lint(tmp_path, """
        import jax

        def body(carry, x):
            jax.debug.callback(print, x)
            return carry + x, x

        def run(xs):
            return jax.lax.scan(body, 0.0, xs)
    """)
    assert any(f.rule == "GFL006" and "jax.debug.callback" in f.message
               for f in findings), findings

def test_gfl006_quiet_on_telemetry_emit_and_untraced(tmp_path):
    findings = lint(tmp_path, """
        import jax
        from repro.telemetry import emit

        @jax.jit
        def f(x):
            emit("step", {"step": 0, "msd": x})
            return x

        def host_only(x):
            from jax.experimental import io_callback
            io_callback(print, None, x)
    """)
    assert "GFL006" not in rules_fired(findings), findings

def test_gfl006_telemetry_package_exempt(tmp_path):
    findings = lint(tmp_path / "pkg", """
        import jax
        from jax.experimental import io_callback

        @jax.jit
        def flush(x):
            io_callback(print, None, x)
            return x
    """, filename="repro/telemetry/stream.py")
    assert "GFL006" not in rules_fired(findings), findings

def test_gfl006_pragma_suppresses(tmp_path):
    findings = lint(tmp_path, """
        import jax
        from jax.experimental import io_callback

        @jax.jit
        def f(x):
            io_callback(print, None, x)  # gflint: disable=GFL006
            return x
    """)
    assert "GFL006" not in rules_fired(findings), findings

# --------------------------------------------------------------- GFL007
def test_gfl007_fires_on_raw_bench_writes(tmp_path):
    findings = lint(tmp_path, """
        import json
        from pathlib import Path

        OUT = Path(".") / "BENCH_speed.json"

        def save(payload):
            OUT.write_text(json.dumps(payload))

        def save2(payload):
            with open("BENCH_other.jsonl", "a") as fh:
                json.dump(payload, fh)
    """)
    hits = [f for f in findings if f.rule == "GFL007"]
    # write_text via the assigned OUT name + the open("a") literal (the
    # dump into the opened handle is covered by flagging the open itself)
    assert len(hits) == 2, findings
    assert all("write_bench" in f.message for f in hits)

def test_gfl007_quiet_on_write_bench_and_unrelated_writes(tmp_path):
    findings = lint(tmp_path, """
        import json
        from pathlib import Path

        def good(payload):
            from benchmarks.meta import write_bench
            write_bench("BENCH_speed.json", payload,
                        headline={"x": ("higher", 1.0)})

        def unrelated(payload):
            Path("notes.json").write_text(json.dumps(payload))
            with open("log.txt", "w") as fh:
                fh.write("hi")

        def reads_only():
            return json.loads(Path("BENCH_speed.json").read_text())
    """)
    assert "GFL007" not in rules_fired(findings), findings

def test_gfl007_meta_module_exempt(tmp_path):
    findings = lint(tmp_path, """
        import json

        def write_bench(path, payload):
            with open("BENCH_history.jsonl", "a") as fh:
                fh.write(json.dumps(payload))
    """, filename="benchmarks/meta.py")
    assert "GFL007" not in rules_fired(findings), findings

def test_gfl007_pragma_suppresses(tmp_path):
    findings = lint(tmp_path, """
        import json
        from pathlib import Path

        def save(payload):
            # one-off debug dump, reviewed  # gflint: disable=GFL007
            Path("BENCH_debug.json").write_text(json.dumps(payload))
    """)
    assert "GFL007" not in rules_fired(findings), findings

# --------------------------------------------------------------- GFL008
def test_gfl008_fires_on_raw_net_imports(tmp_path):
    findings = lint(tmp_path, """
        import socket
        import subprocess as sp
        from subprocess import run

        def shell(cmd):
            return sp.run(cmd)
    """)
    hits = [f for f in findings if f.rule == "GFL008"]
    # import socket + import subprocess + from subprocess import
    assert len(hits) == 3, findings
    assert all("core/fleet" in f.message for f in hits)

def test_gfl008_fleet_package_exempt(tmp_path):
    findings = lint(tmp_path, """
        import socket
        import subprocess
    """, filename="src/repro/core/fleet/transport.py")
    assert "GFL008" not in rules_fired(findings), findings

def test_gfl008_quiet_on_unrelated_imports_and_pragma(tmp_path):
    findings = lint(tmp_path, """
        import os
        import multiprocessing
        import subprocess  # git provenance  # gflint: disable=GFL008
    """)
    assert "GFL008" not in rules_fired(findings), findings

# ---------------------------------------------------------- baseline/CLI
def test_baseline_roundtrip_and_diff(tmp_path):
    findings = lint(tmp_path, """
        import jax

        def init():
            return jax.random.PRNGKey(7)
    """)
    bl = tmp_path / "baseline.json"
    save_baseline(bl, findings)
    loaded = load_baseline(bl)
    new, stale, matched = diff_against_baseline(findings, loaded)
    assert not new and not stale and len(matched) == len(findings)
    # a fixed finding becomes a stale entry
    new, stale, matched = diff_against_baseline([], loaded)
    assert not new and len(stale) == len(findings)
    # line moves don't churn the match
    import dataclasses
    moved = [dataclasses.replace(f, line=f.line + 40) for f in findings]
    new, stale, matched = diff_against_baseline(moved, loaded)
    assert not new and not stale

def test_cli_exit_codes(tmp_path):
    (tmp_path / "mod.py").write_text(
        "import jax\n\ndef f():\n    return jax.random.PRNGKey(3)\n")
    bl = tmp_path / "baseline.json"
    assert cli_main([str(tmp_path), "--root", str(tmp_path),
                     "--no-baseline"]) == 1
    assert cli_main([str(tmp_path), "--root", str(tmp_path),
                     "--baseline", str(bl), "--write-baseline"]) == 0
    assert cli_main([str(tmp_path), "--root", str(tmp_path),
                     "--baseline", str(bl)]) == 0
    # fixing the finding leaves a stale entry -> nonzero until refreshed
    (tmp_path / "mod.py").write_text("def f():\n    return 3\n")
    assert cli_main([str(tmp_path), "--root", str(tmp_path),
                     "--baseline", str(bl)]) == 1
    assert cli_main([str(tmp_path), "--root", str(tmp_path),
                     "--baseline", str(bl), "--write-baseline"]) == 0
    assert cli_main([str(tmp_path), "--root", str(tmp_path),
                     "--baseline", str(bl)]) == 0

def test_cli_json_format(tmp_path, capsys):
    (tmp_path / "mod.py").write_text(
        "import jax\nK = jax.random.PRNGKey(3)\n")
    code = cli_main([str(tmp_path), "--root", str(tmp_path),
                     "--no-baseline", "--format", "json"])
    out = json.loads(capsys.readouterr().out)
    assert code == 1 and out["new"] and out["stale"] == []

def test_parse_error_reported_not_crashing(tmp_path):
    findings = lint(tmp_path, "def broken(:\n")
    assert any(f.rule == "GFL000" for f in findings), findings

# ----------------------------------------------------------- self-check
def test_committed_baseline_exactly_reproduced():
    """gflint over the real src/ must match analysis/baseline.json with
    zero new findings and zero stale entries."""
    findings = run_analysis([REPO_ROOT / "src"], root=REPO_ROOT)
    baseline = load_baseline(REPO_ROOT / "analysis" / "baseline.json")
    new, stale, matched = diff_against_baseline(findings, baseline)
    assert not new, [f.render() for f in new]
    assert not stale, stale
    for entry in baseline.values():
        assert entry["justification"].strip() and \
            "TODO" not in entry["justification"]

def test_cli_runs_as_module():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--baseline",
         "analysis/baseline.json", "src"],
        cwd=REPO_ROOT, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 new" in proc.stdout
