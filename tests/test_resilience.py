"""Resilience runtime: fault specs, time-varying topologies (Assumption 1
per realized round), dropout-safe secure aggregation, straggler staleness,
and the fault=none bit-identity regression on all combine impls."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.configs.base import GFLConfig
from repro.core import gfl
from repro.core.privacy.mechanism import NoiseProfile, mechanism_for
from repro.core.privacy.secure_agg import (
    masked_client_mean_dropout_vec,
    masked_client_mean_with_dropout,
    pairwise_masks,
    pairwise_masks_vec,
)
from repro.core.resilience import (
    FaultModel,
    TopologyProcess,
    ensure_dropout_safe,
    fold_dropped_links,
    init_resilient_state,
    make_resilient_gfl_step,
    parse_fault_spec,
)
from repro.core.simulate import generate_problem, make_grad_fn, run_gfl, \
    sample_round_batches
from repro.core.topology import (
    combination_matrix,
    spectral_gap,
    validate_combination_matrix,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def problem():
    return generate_problem(jax.random.PRNGKey(0), P=5, K=8, N=30, M=2)


# ------------------------------------------------------------ fault specs --


def test_fault_spec_round_trip():
    spec = "links:0.1+outage:0.02+straggler:0.2,stale=3+dropout:0.25"
    f = parse_fault_spec(spec)
    assert f == FaultModel(link_drop=0.1, outage=0.02, straggler=0.2,
                           staleness=3, client_dropout=0.25)
    assert parse_fault_spec(f.to_spec()) == f
    assert parse_fault_spec("none").is_null
    assert parse_fault_spec("links:0.0+dropout:0").is_null
    assert FaultModel().to_spec() == "none"


@pytest.mark.parametrize("bad", [
    "links", "links:xyz", "frobnicate:0.1", "links:0.1+links:0.2",
    "links:1.5", "dropout:-0.1", "straggler:0.1,wat=3",
    "straggler:0.1,stale=0",
])
def test_fault_spec_rejects_garbage(bad):
    with pytest.raises(ValueError):
        parse_fault_spec(bad)


# ------------------------------------------- realized-A_i invariants ------

FAMILIES = ("ring", "torus", "full", "erdos", "hypercube", "expander")


def _family_P(topology, P):
    if topology == "hypercube":           # needs a power of two
        return 1 << max(P.bit_length() - 1, 2)
    return P


@pytest.mark.parametrize("topology", FAMILIES)
@pytest.mark.parametrize("spec", ["links:0.3", "outage:0.2",
                                  "links:0.5+outage:0.2"])
def test_realized_rounds_satisfy_assumption1(topology, spec):
    P = _family_P(topology, 12)
    proc = TopologyProcess(combination_matrix(topology, P, seed=3), spec,
                           seed=1, validate=False)
    for i in range(25):
        r = proc.realize(i)
        A = r.A
        assert np.allclose(A, A.T), (topology, i)
        assert np.allclose(A.sum(0), 1.0), (topology, i)
        assert np.allclose(A.sum(1), 1.0), (topology, i)
        assert (A >= 0).all(), (topology, i)
        assert spectral_gap(A) < 1.0, (topology, i)
        # the validator agrees with the by-hand checks
        validate_combination_matrix(A)


@given(topology=st.sampled_from(FAMILIES), P=st.integers(4, 20),
       drop=st.floats(0.0, 0.7), outage=st.floats(0.0, 0.4),
       round_idx=st.integers(0, 500), seed=st.integers(0, 99))
@settings(max_examples=40, deadline=None)
def test_realized_A_property(topology, P, drop, outage, round_idx, seed):
    """Every fault-realized A_i over every family satisfies Assumption 1."""
    P = _family_P(topology, P)
    fault = FaultModel(link_drop=drop, outage=outage)
    proc = TopologyProcess(combination_matrix(topology, P, seed=seed),
                           fault, seed=seed, validate=False)
    A = proc.realize(round_idx).A
    validate_combination_matrix(A)   # symmetric + doubly stochastic + gap<1
    assert spectral_gap(A) < 1.0


def test_fold_dropped_links_exact():
    A = combination_matrix("torus", 9)
    full_mask = ~np.eye(9, dtype=bool)
    # all-alive fold is a bit-exact no-op
    assert np.array_equal(fold_dropped_links(A, full_mask), A)
    # dropping one edge moves its weight onto both diagonals, exactly
    j, k = map(int, np.argwhere(np.triu(A, 1) > 0)[0])
    mask = full_mask.copy()
    mask[j, k] = mask[k, j] = False
    Ad = fold_dropped_links(A, mask)
    assert Ad[j, k] == 0.0 and Ad[k, j] == 0.0
    assert Ad[j, j] == A[j, j] + A[j, k]
    assert Ad[k, k] == A[k, k] + A[k, j]
    validate_combination_matrix(Ad)


def test_process_is_deterministic_and_null_is_base():
    A = combination_matrix("ring", 8)
    proc = TopologyProcess(A, "links:0.4", seed=5)
    r1, r2 = proc.realize(7), proc.realize(7)
    assert np.array_equal(r1.A, r2.A)
    assert np.array_equal(r1.link_mask, r2.link_mask)
    # different rounds realize different topologies (p=0.4 on 8 edges)
    assert any(not np.array_equal(proc.realize(i).A, proc.realize(i + 1).A)
               for i in range(10))
    null = TopologyProcess(A, "links:0.0+dropout:0.0")
    assert null.static
    assert np.array_equal(null.realize(3).A, np.asarray(A))


def test_gap_trajectory_degrades_with_drop_probability():
    A = combination_matrix("hypercube", 16)
    base = spectral_gap(A)
    proc = TopologyProcess(A, "links:0.3", seed=0)
    gaps = proc.gap_trajectory(20)
    assert gaps.shape == (20,)
    assert (gaps < 1.0).all()
    assert gaps.mean() > base   # failures slow mixing, never break it


def test_client_alive_always_has_a_survivor():
    proc = TopologyProcess(combination_matrix("ring", 6), "dropout:0.95",
                           seed=2)
    for i in range(30):
        alive = proc.client_alive(i, 4)
        assert alive.shape == (6, 4)
        assert alive.any(axis=1).all()
    # deterministic too
    assert np.array_equal(proc.client_alive(3, 4), proc.client_alive(3, 4))


# ------------------------------------ dropout-safe secure aggregation -----


def test_dropout_vec_matches_loop_reference_and_exact_mean():
    key = jax.random.PRNGKey(3)
    upd = jax.random.normal(jax.random.fold_in(key, 1), (6, 16))
    alive = jnp.asarray([True, False, True, True, False, True])
    vec = masked_client_mean_dropout_vec(upd, key, alive, mask_scale=4.0)
    loop = masked_client_mean_with_dropout(upd, key, alive, mask_scale=4.0)
    np.testing.assert_allclose(np.asarray(vec), np.asarray(loop), atol=1e-4)
    np.testing.assert_allclose(np.asarray(vec),
                               np.asarray(upd[alive].mean(0)), atol=1e-4)


@given(L=st.integers(2, 8), seed=st.integers(0, 999),
       drop_mask=st.integers(1, 200))
@settings(max_examples=20, deadline=None)
def test_dropout_vec_recovery_property(L, seed, drop_mask):
    """Vectorized survivor renormalization recovers the exact alive mean
    for every dropout set (the production path of the loop reference)."""
    key = jax.random.PRNGKey(seed)
    upd = jax.random.normal(jax.random.fold_in(key, 1), (L, 24))
    alive = jnp.asarray([(drop_mask >> i) & 1 for i in range(L)], bool)
    alive = alive.at[0].set(True)
    agg = masked_client_mean_dropout_vec(upd, key, alive, mask_scale=4.0)
    np.testing.assert_allclose(np.asarray(agg),
                               np.asarray(upd[alive].mean(0)), atol=1e-4)


@given(L=st.integers(2, 10), seed=st.integers(0, 999))
@settings(max_examples=15, deadline=None)
def test_pairwise_masks_loop_vs_vec(L, seed):
    """The O(L^2) python-loop masks are the REFERENCE; the vectorized
    version must reproduce them (same PRG streams, float addition order)."""
    key = jax.random.PRNGKey(seed)
    ref = pairwise_masks(key, L, 12, 3.0)
    vec = pairwise_masks_vec(key, L, 12, 3.0)
    np.testing.assert_allclose(np.asarray(vec), np.asarray(ref), atol=1e-4)


def test_pairwise_masks_loop_vs_vec_deterministic():
    key = jax.random.PRNGKey(0)
    np.testing.assert_allclose(np.asarray(pairwise_masks_vec(key, 7, 9, 2.0)),
                               np.asarray(pairwise_masks(key, 7, 9, 2.0)),
                               atol=1e-4)


def test_mechanism_masked_hooks_exact_under_dropout(problem):
    """Every dropout-safe mechanism's client_protect_masked recovers the
    survivor mean (hybrid-family masks cancel; iid noise averages out only
    in expectation, so it is checked at sigma=0)."""
    for scheme in ("none", "hybrid", "gaussian_dp", "scheduled", "iid_dp"):
        cfg = GFLConfig(num_servers=5, clients_per_server=8, privacy=scheme,
                        sigma_g=0.0 if scheme == "iid_dp" else 3.0,
                        mu=0.1, epsilon_target=0.0)
        mech = mechanism_for(cfg)
        assert mech.noise_profile().client_dropout_safe, scheme
        key = jax.random.PRNGKey(1)
        upd = jax.random.normal(jax.random.fold_in(key, 2), (5, 7))
        alive = jnp.asarray([True, True, False, True, False])
        out = mech.client_protect_masked(upd, key, alive)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(upd[alive].mean(0)),
                                   atol=1e-4, err_msg=scheme)


def test_ensure_dropout_safe_requires_declaration():
    """ANY undeclared profile is refused: cancelling mechanisms would leave
    orphaned masks, and noisy non-cancelling mechanisms without a
    client_protect_masked override would silently fall back to the
    noise-free base survivor mean."""
    unsafe_cancelling = NoiseProfile(
        distribution="laplace", client_sigma=1.0, server_sigma=1.0,
        client_cancels_exactly=True, server_cancels_exactly=True,
        client_dropout_safe=False)
    with pytest.raises(ValueError, match="client_dropout_safe"):
        ensure_dropout_safe(unsafe_cancelling)
    with pytest.raises(ValueError, match="client_dropout_safe"):
        ensure_dropout_safe(NoiseProfile("laplace", 1.0, 1.0, False, False))
    # declared-safe profiles pass
    ensure_dropout_safe(NoiseProfile("laplace", 1.0, 1.0, True, True,
                                     client_dropout_safe=True))


def test_client_noise_tree_per_server_survivor_scaling():
    """Under dropout each server's variance-equivalent draw scales with
    ITS survivor count, not the fleet average."""
    cfg = GFLConfig(num_servers=2, clients_per_server=8, privacy="iid_dp",
                    sigma_g=1.0, mu=0.1)
    mech = mechanism_for(cfg)
    tree = {"w": jnp.zeros((2, 40_000))}
    n_p = jnp.asarray([1.0, 16.0])           # heterogeneous survivors
    out = np.asarray(mech.client_noise_tree(jax.random.PRNGKey(0), tree,
                                            n_p)["w"])
    assert out[0].std() == pytest.approx(1.0, rel=0.05)
    assert out[1].std() == pytest.approx(0.25, rel=0.05)


# --------------------------------------------------- resilient execution --


def _cfg(fault, scheme="hybrid", **kw):
    base = dict(num_servers=5, clients_per_server=8, clients_sampled=4,
                privacy=scheme, sigma_g=0.3, mu=0.1, topology="ring",
                grad_bound=10.0, fault=fault)
    base.update(kw)
    return GFLConfig(**base)


@pytest.mark.parametrize("scheme", ["hybrid", "iid_dp", "none"])
def test_fault_none_bit_identical_to_static(problem, scheme):
    """Regression: a zero-probability fault spec routes through the full
    resilience runtime (traced per-round A_i) yet reproduces the static
    path BIT-FOR-BIT."""
    kw = dict(iters=8, batch_size=5, seed=11)
    msd_s, p_s = run_gfl(problem, _cfg("none", scheme), **kw)
    msd_r, p_r = run_gfl(problem, _cfg("links:0.0+dropout:0.0", scheme), **kw)
    assert np.array_equal(np.asarray(p_s), np.asarray(p_r))
    assert msd_s.tolist() == msd_r.tolist()


def test_fault_none_bit_identical_with_combine_every(problem):
    kw = dict(iters=6, batch_size=5, seed=4)
    msd_s, p_s = run_gfl(problem, _cfg("none", combine_every=2), **kw)
    msd_r, p_r = run_gfl(problem, _cfg("links:0.0", combine_every=2), **kw)
    assert np.array_equal(np.asarray(p_s), np.asarray(p_r))
    assert msd_s.tolist() == msd_r.tolist()


def test_faulted_run_converges_and_records_gaps(problem):
    cfg = _cfg("links:0.2+outage:0.1+straggler:0.3,stale=2+dropout:0.3")
    msd, params, gaps = run_gfl(problem, cfg, iters=40, batch_size=5,
                                seed=1, record_gaps=True)
    assert np.isfinite(msd).all()
    assert msd[-1] < msd[0]
    assert gaps.shape == (40,) and (gaps < 1.0).all()


def test_straggler_staleness_bound(problem):
    """With straggler prob 1 and stale=2, ages cycle 1, 2, 0 (forced
    refresh at the bound) and params only move on refresh rounds."""
    cfg = _cfg("straggler:1.0,stale=2", scheme="none")
    A = combination_matrix("ring", 5)
    proc = TopologyProcess(A, cfg.fault, seed=0)
    step = make_resilient_gfl_step(proc, make_grad_fn(problem.rho), cfg)
    state = init_resilient_state(jax.random.PRNGKey(0), 5, 2,
                                 init_scale=0.5)
    batch = sample_round_batches(jax.random.PRNGKey(5), problem, 4, 5)
    ages, moved = [], []
    for _ in range(6):
        prev_psi = np.asarray(state.psi_cache)
        state = step(state, batch)
        ages.append(np.asarray(state.psi_age).tolist())
        moved.append(not np.array_equal(prev_psi,
                                        np.asarray(state.psi_cache)))
    assert ages == [[1] * 5, [2] * 5, [0] * 5] * 2
    # psi only refreshes when the staleness bound forces it
    assert moved == [False, False, True] * 2


def test_gfl_round_accepts_topology_process(problem):
    cfg = _cfg("links:0.3+dropout:0.4")
    proc = TopologyProcess(combination_matrix("ring", 5), cfg.fault, seed=3)
    grad_fn = make_grad_fn(problem.rho)
    key = jax.random.PRNGKey(7)
    params = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (5, 2))
    batch = sample_round_batches(jax.random.fold_in(key, 2), problem, 4, 5)
    out = gfl.gfl_round(params, batch, jax.random.fold_in(key, 3), A=proc,
                        grad_fn=grad_fn, cfg=cfg, step=2)
    assert out.shape == (5, 2)
    assert np.isfinite(np.asarray(out)).all()


def test_dropout_faults_refused_for_unsafe_mechanism(problem):
    """A mechanism declaring exact client cancellation WITHOUT dropout
    safety must be rejected by the resilience runtime."""
    from repro.core.privacy.mechanism import (
        PrivacyMechanism,
        _REGISTRY,
        register_mechanism,
    )

    name = "_test_unsafe_masks"
    if name not in _REGISTRY:
        @register_mechanism(name)
        class UnsafeMasks(PrivacyMechanism):
            def noise_profile(self):
                return NoiseProfile(distribution="laplace", client_sigma=1.0,
                                    server_sigma=0.0,
                                    client_cancels_exactly=True,
                                    server_cancels_exactly=True,
                                    client_dropout_safe=False)

    cfg = _cfg("dropout:0.3", scheme=name)
    proc = TopologyProcess(combination_matrix("ring", 5), cfg.fault)
    with pytest.raises(ValueError, match="client_dropout_safe"):
        make_resilient_gfl_step(proc, make_grad_fn(problem.rho), cfg)
    # without the dropout component the same mechanism is fine
    make_resilient_gfl_step(
        TopologyProcess(combination_matrix("ring", 5), "links:0.2"),
        make_grad_fn(problem.rho), _cfg("links:0.2", scheme=name))


def test_mesh_train_step_guards():
    """make_train_step rejects simulator-only straggler specs up front."""
    pytest.importorskip("jax")
    from repro.configs.registry import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.launch import steps as S
    from repro.models import Model

    mesh = make_test_mesh((1, 1), ("data", "model"))
    model = Model(get_config("smollm-135m").reduced())
    with pytest.raises(ValueError, match="straggler"):
        S.make_train_step(model, GFLConfig(fault="straggler:0.2"), mesh)


@pytest.mark.slow
def test_multipod_sparse_combine_matches_dense():
    """3-pod product-graph sparse combine == dense kron(A_pod, A_data)
    combine.  Regression for two sparse-path bugs: the pod-ring backward
    permute must carry the data-mixed value (not the partial pod mix), and
    a 2-ring data axis must not double-count its single neighbour."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import GFLConfig
        from repro.configs.registry import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.launch import steps as S
        from repro.models import Model
        from repro.data import TokenStream, federated_token_batches

        mesh = make_test_mesh((3, 2, 1), ("pod", "data", "model"))
        cfg = get_config("smollm-135m").reduced()
        model = Model(cfg)
        stream = TokenStream(vocab=cfg.vocab_size, seed=0)
        batch = federated_token_batches(stream, 0, 0, P=6, L=2,
                                        per_client=2, seq_len=32)
        outs = {}
        for impl in ("dense", "sparse"):
            gfl = GFLConfig(topology="ring", privacy="none", mu=0.05,
                            grad_bound=10.0, combine_impl=impl)
            with mesh:
                step = jax.jit(S.make_train_step(model, gfl, mesh))
                state = S.init_train_state(model, gfl, mesh,
                                           jax.random.PRNGKey(0))
                state, _ = step(state, batch)
                outs[impl] = jax.device_get(state.params)
        for (pa, la), (pb, lb) in zip(
                jax.tree_util.tree_leaves_with_path(outs["dense"]),
                jax.tree_util.tree_leaves_with_path(outs["sparse"])):
            np.testing.assert_allclose(
                np.asarray(la, np.float32), np.asarray(lb, np.float32),
                atol=1e-5, err_msg=str(pa))
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout


# ------------------------------------------------- mesh bit-identity ------


@pytest.mark.slow
def test_mesh_fault_none_bit_identical_all_combine_impls():
    """fault=none resilience inputs (explicit base A + all-alive mask)
    reproduce the static mesh path bit-for-bit on dense/rotate/sparse."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import GFLConfig
        from repro.configs.registry import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.launch import steps as S
        from repro.models import Model
        from repro.data import TokenStream, federated_token_batches

        mesh = make_test_mesh((2, 4), ("data", "model"))
        cfg = get_config("smollm-135m").reduced()
        model = Model(cfg)
        stream = TokenStream(vocab=cfg.vocab_size, seed=0)
        batch = federated_token_batches(stream, 0, 0, P=2, L=2,
                                        per_client=2, seq_len=32)
        for impl in ("dense", "rotate", "sparse"):
            kw = dict(topology="ring", privacy="hybrid", sigma_g=0.1,
                      grad_bound=10.0, mu=0.05, combine_impl=impl)
            with mesh:
                g0 = GFLConfig(**kw)
                step0 = jax.jit(S.make_train_step(model, g0, mesh))
                s0 = S.init_train_state(model, g0, mesh,
                                        jax.random.PRNGKey(0))
                g1 = GFLConfig(fault="links:0.0+dropout:0.0", **kw)
                step1 = jax.jit(S.make_train_step(model, g1, mesh))
                proc = S.make_topology_process(mesh, g1)
                s1 = S.init_train_state(model, g1, mesh,
                                        jax.random.PRNGKey(0))
                for i in range(2):
                    s0, _ = step0(s0, batch)
                    real = proc.realize(i)
                    s1, _ = step1(s1, batch, real.A,
                                  proc.client_alive(i, 2))
                same = all(bool(jnp.array_equal(a, b)) for a, b in
                           zip(jax.tree.leaves(s0.params),
                               jax.tree.leaves(s1.params)))
                assert same, impl
                print(impl, "bit-identical")
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout
