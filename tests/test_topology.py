"""Combination-matrix properties (Assumption 1) across graph families."""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.topology import (
    combination_matrix,
    neighbor_lists,
    permute_schedule,
    ring_adjacency,
    spectral_gap,
    torus_adjacency,
    validate_combination_matrix,
)


@pytest.mark.parametrize("topology", ["ring", "torus", "full", "erdos",
                                      "expander"])
@pytest.mark.parametrize("P", [4, 10, 16])
def test_assumption1(topology, P):
    A = combination_matrix(topology, P)
    assert np.allclose(A, A.T)
    assert np.allclose(A.sum(0), 1.0)
    assert np.allclose(A.sum(1), 1.0)
    assert (A >= 0).all()
    assert spectral_gap(A) < 1.0


@pytest.mark.parametrize("P", [4, 8, 16])
def test_assumption1_hypercube(P):
    A = combination_matrix("hypercube", P)
    validate_combination_matrix(A)
    assert spectral_gap(A) < 1.0


@given(topology=st.sampled_from(["ring", "torus", "full", "erdos",
                                 "expander", "hypercube"]),
       P=st.integers(3, 24), seed=st.integers(0, 50))
@settings(max_examples=40, deadline=None)
def test_every_family_satisfies_assumption1(topology, P, seed):
    """Property: EVERY graph family (including hypercube and expander)
    yields a symmetric, doubly-stochastic matrix with spectral gap < 1."""
    if topology == "hypercube":
        P = 1 << max(P.bit_length() - 1, 2)   # nearest power of two
    A = combination_matrix(topology, P, seed=seed)
    assert np.allclose(A, A.T)
    assert np.allclose(A.sum(0), 1.0)
    assert np.allclose(A.sum(1), 1.0)
    assert (A >= 0).all()
    assert spectral_gap(A) < 1.0
    validate_combination_matrix(A)


@given(P=st.integers(4, 16), drop=st.floats(0.0, 0.6),
       round_idx=st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_fault_realized_matrices_satisfy_assumption1(P, drop, round_idx):
    """Property: per-round fault realizations keep Assumption 1 (the
    resilience subsystem's core contract; see also test_resilience)."""
    from repro.core.resilience import TopologyProcess

    proc = TopologyProcess(combination_matrix("torus", P),
                           f"links:{drop}", seed=1, validate=False)
    A = proc.realize(round_idx).A
    validate_combination_matrix(A)
    assert spectral_gap(A) < 1.0


@given(P=st.integers(3, 24))
@settings(max_examples=20, deadline=None)
def test_ring_gap_hypothesis(P):
    A = combination_matrix("ring", P)
    lam = spectral_gap(A)
    assert 0 <= lam < 1
    # ring gap worsens with P (monotone family property)
    if P >= 6:
        assert lam > spectral_gap(combination_matrix("ring", P - 2)) - 1e-9


def test_full_graph_gap_zero():
    A = combination_matrix("full", 8)
    assert spectral_gap(A) < 1e-8  # uniform weights: exact consensus


def test_torus_adjacency_degree():
    adj = torus_adjacency(4, 4)
    assert (adj.sum(1) == 4).all()
    adj = torus_adjacency(2, 8)
    # rows wrap to the same node when rows=2: up == down neighbour
    assert (adj.sum(1) >= 3).all()


def test_validate_rejects_disconnected():
    A = np.eye(4)
    with pytest.raises(ValueError):
        validate_combination_matrix(A)


def test_neighbor_lists_ring():
    A = combination_matrix("ring", 6)
    nbrs = neighbor_lists(A)
    for p, ns in enumerate(nbrs):
        assert sorted(ns) == sorted([(p - 1) % 6, (p + 1) % 6])


def test_permute_schedule_ring_is_permutation():
    rounds = permute_schedule("ring", 8)
    assert len(rounds) == 2
    for rd in rounds:
        srcs = [s for s, _ in rd]
        dsts = [d for _, d in rd]
        assert sorted(srcs) == list(range(8))
        assert sorted(dsts) == list(range(8))


def test_permute_schedule_torus():
    rounds = permute_schedule("torus", 16, rows=4)
    assert 2 <= len(rounds) <= 4
    for rd in rounds:
        assert sorted(d for _, d in rd) == list(range(16))
