"""Combination-matrix properties (Assumption 1) across graph families."""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.topology import (
    combination_matrix,
    neighbor_lists,
    permute_schedule,
    ring_adjacency,
    spectral_gap,
    torus_adjacency,
    validate_combination_matrix,
)


@pytest.mark.parametrize("topology", ["ring", "torus", "full", "erdos"])
@pytest.mark.parametrize("P", [4, 10, 16])
def test_assumption1(topology, P):
    A = combination_matrix(topology, P)
    assert np.allclose(A, A.T)
    assert np.allclose(A.sum(0), 1.0)
    assert np.allclose(A.sum(1), 1.0)
    assert (A >= 0).all()
    assert spectral_gap(A) < 1.0


@given(P=st.integers(3, 24))
@settings(max_examples=20, deadline=None)
def test_ring_gap_hypothesis(P):
    A = combination_matrix("ring", P)
    lam = spectral_gap(A)
    assert 0 <= lam < 1
    # ring gap worsens with P (monotone family property)
    if P >= 6:
        assert lam > spectral_gap(combination_matrix("ring", P - 2)) - 1e-9


def test_full_graph_gap_zero():
    A = combination_matrix("full", 8)
    assert spectral_gap(A) < 1e-8  # uniform weights: exact consensus


def test_torus_adjacency_degree():
    adj = torus_adjacency(4, 4)
    assert (adj.sum(1) == 4).all()
    adj = torus_adjacency(2, 8)
    # rows wrap to the same node when rows=2: up == down neighbour
    assert (adj.sum(1) >= 3).all()


def test_validate_rejects_disconnected():
    A = np.eye(4)
    with pytest.raises(ValueError):
        validate_combination_matrix(A)


def test_neighbor_lists_ring():
    A = combination_matrix("ring", 6)
    nbrs = neighbor_lists(A)
    for p, ns in enumerate(nbrs):
        assert sorted(ns) == sorted([(p - 1) % 6, (p + 1) % 6])


def test_permute_schedule_ring_is_permutation():
    rounds = permute_schedule("ring", 8)
    assert len(rounds) == 2
    for rd in rounds:
        srcs = [s for s, _ in rd]
        dsts = [d for _, d in rd]
        assert sorted(srcs) == list(range(8))
        assert sorted(dsts) == list(range(8))


def test_permute_schedule_torus():
    rounds = permute_schedule("torus", 16, rows=4)
    assert 2 <= len(rounds) <= 4
    for rd in rounds:
        assert sorted(d for _, d in rd) == list(range(16))
