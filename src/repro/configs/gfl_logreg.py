"""gfl-logreg: the paper's own Section-V experiment configuration.

P=10 servers x K=50 clients, M=2 logistic regression, mu=0.1, rho=0.01,
sigma_g=0.2 (Fig. 2)."""
from repro.configs.base import GFLConfig, ModelConfig

CONFIG = ModelConfig(
    name="gfl-logreg",
    family="dense",
    num_layers=0,
    d_model=2,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=2,
    source="Rizk & Sayed 2021, Section V",
)

GFL = GFLConfig(num_servers=10, clients_per_server=50, privacy="hybrid",
                sigma_g=0.2, mu=0.1, topology="full", grad_bound=10.0)
RHO = 0.01
