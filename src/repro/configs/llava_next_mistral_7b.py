"""llava-next-mistral-7b: anyres VLM on Mistral-7B (SWA 4096) backbone
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision tower (CLIP ViT-L/336 + 2-layer MLP projector) is a STUB per the
assignment: input_specs provides precomputed patch embeddings. anyres tiling
yields up to 5 tiles x 576 patches = 2880 image tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,   # Mistral-7B-v0.1 backbone SWA
    num_image_tokens=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (Mistral-7B backbone, "
           "anyres 2880 img tokens)",
)
