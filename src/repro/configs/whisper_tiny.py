"""whisper-tiny: enc-dec audio [arXiv:2212.04356].

Mel-spectrogram + conv frontend is a STUB: input_specs provides the 1500
frame embeddings the conv stack would produce for 30s of audio.  Real
whisper decodes <=448 tokens; the assigned decode shapes exercise the
decoder mechanically far beyond that (noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,            # decoder layers
    encoder_layers=4,
    encoder_seq_len=1500,    # 30s @ 50Hz after conv stride 2
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    tie_embeddings=True,
    attention="gqa",
    source="arXiv:2212.04356 (Whisper tiny: 4+4L d384 6H ff1536 vocab 51865)",
)
