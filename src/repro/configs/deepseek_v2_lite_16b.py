"""deepseek-v2-lite-16b: MLA + fine-grained MoE [arXiv:2405.04434].

MLA kv_lora_rank=512; MoE: 2 shared + 64 routed experts, top-6, expert
d_ff=1408; first layer dense (d_ff 10944).  27 layers, d_model 2048.
"""
from repro.configs.base import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6,
                  capacity_factor=1.25, expert_d_ff=1408,
                  first_dense_layers=1, first_dense_d_ff=10944),
    source="arXiv:2405.04434 (DeepSeek-V2-Lite: 27L d2048, MLA kv_lora 512, "
           "2 shared + 64 routed top-6)",
)
