"""mixtral-8x7b: 8-expert top-2 MoE with SWA [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, num_shared_experts=0, top_k=2,
                  capacity_factor=1.25, expert_d_ff=14336),
    source="arXiv:2401.04088 (Mixtral 8x7B: 32L d4096 8e top-2, SWA 4096)",
)
