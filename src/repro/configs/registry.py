"""Registry mapping --arch ids to ModelConfigs."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCH_IDS = [
    "zamba2-1.2b",
    "rwkv6-3b",
    "yi-6b",
    "llava-next-mistral-7b",
    "whisper-tiny",
    "deepseek-v2-lite-16b",
    "smollm-135m",
    "mixtral-8x7b",
    "minicpm3-4b",
    "phi3-mini-3.8b",
    # the paper's own experiment model
    "gfl-logreg",
]


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_module_name(arch_id))
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS if a != "gfl-logreg"}
