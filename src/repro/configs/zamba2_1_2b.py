"""zamba2-1.2b: Mamba2 backbone + shared attention block [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, conv_dim=4, expand=2, headdim=64, ngroups=1,
                  chunk=256),
    hybrid_attn_every=6,
    source="arXiv:2411.15242 (Zamba2: 38 Mamba2 layers, shared attn block "
           "applied periodically; ssm_state=64)",
)
