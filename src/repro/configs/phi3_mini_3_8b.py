"""phi3-mini-3.8b: RoPE SwiGLU GQA with sliding window [arXiv:2404.14219].

phi3-mini-4k ships sliding_window=2047, which is what makes the `long_500k`
decode shape feasible (ring KV cache of 2047 slots).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    sliding_window=2047,
    source="arXiv:2404.14219 (phi-3-mini: 32L d3072 32H ff8192 vocab 32064, "
           "sliding window 2047)",
)
