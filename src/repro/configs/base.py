"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the GFL
protocol itself is configured by :class:`GFLConfig`; input shapes come from
the fixed :data:`INPUT_SHAPES` registry.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (fixed, assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts
    num_shared_experts: int = 0   # always-on experts (DeepSeek style)
    top_k: int = 2
    capacity_factor: float = 1.25
    expert_d_ff: int = 0          # d_ff of each routed expert
    router_aux_coef: float = 0.01
    first_dense_layers: int = 0   # leading layers that stay dense (DeepSeek)
    first_dense_d_ff: int = 0
    dispatch: str = "global"      # global: capacity over all tokens (t5x);
                                  # row: per-batch-row dispatch — scatter
                                  # stays local to the data shard (§Perf)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 -> no q compression
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block config."""
    state_dim: int = 64
    conv_dim: int = 4
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1
    chunk: int = 256


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 (Finch) block config."""
    head_size: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    gate_lora: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    max_seq_len: int = 1 << 20
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 0       # 0 -> full attention
    attention: str = "gqa"        # gqa | mla | none
    mlp: str = "swiglu"           # swiglu | gelu
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # hybrid (zamba2): indices (mod pattern) at which the shared attn block fires
    hybrid_attn_every: int = 0    # 0 -> not hybrid; else attn after every N ssm blocks
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq_len: int = 0      # fixed encoder frames (whisper: 1500)
    # vlm
    num_image_tokens: int = 0     # prepended stub patch embeddings
    # citation for provenance
    source: str = ""
    param_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """True if the arch supports O(window)/O(1)-state 500k decode."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window > 0
        )

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        head_dim = max(d_model // num_heads, 32)
        kv = max(1, min(self.num_kv_heads, num_heads))
        while num_heads % kv:  # kv must divide heads (GQA grouping)
            kv -= 1
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=min(self.moe.expert_d_ff or 128, 128),
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                first_dense_d_ff=min(self.moe.first_dense_d_ff or 256, 256),
            )
        mla = None
        if self.mla is not None:
            mla = dataclasses.replace(
                self.mla,
                kv_lora_rank=min(self.mla.kv_lora_rank, 64),
                q_lora_rank=min(self.mla.q_lora_rank, 64),
                qk_nope_head_dim=32,
                qk_rope_head_dim=16,
                v_head_dim=32,
            )
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, state_dim=16, headdim=32, chunk=32)
        rwkv = None
        if self.rwkv is not None:
            rwkv = dataclasses.replace(
                self.rwkv, head_size=32, decay_lora=16, mix_lora=8, gate_lora=16)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=kv,
            head_dim=head_dim if self.head_dim else 0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            moe=moe,
            mla=mla,
            ssm=ssm,
            rwkv=rwkv,
            hybrid_attn_every=min(self.hybrid_attn_every, 1) if self.hybrid_attn_every else 0,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            encoder_seq_len=min(self.encoder_seq_len, 64) if self.encoder_seq_len else 0,
            num_image_tokens=min(self.num_image_tokens, 16) if self.num_image_tokens else 0,
            param_dtype="float32",
        )


# ---------------------------------------------------------------------------
# GFL protocol configuration (the paper's technique)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GFLConfig:
    """Graph-federated-learning protocol knobs (Rizk & Sayed 2021)."""
    num_servers: int = 10            # P
    clients_per_server: int = 50     # K
    clients_sampled: int = 0         # L; 0 -> full participation
    topology: str = "ring"           # ring | torus | full | erdos |
                                     # hypercube | expander
                                     # (see repro.core.topology)
    topology_seed: int = 0           # seed for randomized graph families
                                     # (erdos, expander) AND the per-round
                                     # fault realizations of `fault`
    torus_rows: int = 0              # torus row count; 0 -> near-square auto
    fault: str = "none"              # resilience fault spec, e.g.
                                     # "links:0.1+dropout:0.2" — see
                                     # repro.core.resilience and
                                     # docs/resilience.md for the grammar
    population: str = "dense"        # client-population spec: dense |
                                     # synthetic:iid|hetero|mixture[,...] |
                                     # dirichlet:<alpha>[,...] — see
                                     # repro.core.population and
                                     # docs/population.md for the grammar
    cohort: str = "uniform"          # cohort-scheduler spec: uniform |
                                     # importance[,floor=..] with optional
                                     # "+trace:always|diurnal|devclass[,..]"
                                     # — see docs/population.md
    async_spec: str = "none"         # event-driven executor spec: none |
                                     # async[:buffer=..,latency=..,
                                     # max_stale=..,alpha=..,rate=..] — see
                                     # repro.core.events and docs/async.md
    data_seed: int = 0               # seed of the lazy population generator
                                     # (client k's shard is a pure function
                                     # of (data_seed, server, client))
    privacy: str = "hybrid"          # registry key into
                                     # repro.core.privacy.mechanism: none |
                                     # iid_dp | hybrid | gaussian_dp |
                                     # scheduled[:inner] | any registered name
    sigma_g: float = 0.2             # server-level noise std
    grad_bound: float = 10.0         # B in Assumption 3 (clipping threshold)
    mu: float = 0.1                  # step size
    epsilon_target: float = 0.0      # scheduled mechanism: total eps budget
                                     # to spend by epsilon_horizon (0 -> off)
    epsilon_horizon: int = 0         # scheduled mechanism: step at which the
                                     # budget is exhausted (0 -> default 100)
    secure_agg: bool = True          # pairwise-mask SMC at client level
    combine_impl: str = "dense"      # dense (einsum/all-gather) | rotate | sparse
    combine_every: int = 1           # beyond-paper: combine every tau steps
    use_kernels: bool = False        # whole-run switch: route the round
                                     # (fused clip->update->privatize->fold
                                     # + graph combine) through the Pallas
                                     # kernel layer in every engine — see
                                     # repro.kernels.ops / docs/kernels.md
    # --- beyond-paper perf knobs (see EXPERIMENTS.md §Perf) ---
    combine_wire: str = "bf16"       # bf16: barrier pins the permute buffer to
                                     # param dtype; f32: let XLA hoist converts
    grad_acc_dtype: str = "float32"  # client-grad accumulator dtype
    client_parallel: bool = False    # small-model mode: clients sharded over
                                     # the "model" axis, params replicated
    sanitize: bool = False           # runtime sanitizer mode: run engines
                                     # under jax key-reuse/NaN debugging and
                                     # cross-check the release/charge ledger
                                     # (repro.sanitize; REPRO_SANITIZE=1
                                     # enables it process-wide)
    telemetry: str = "off"           # telemetry sink spec for engine runs:
                                     # "off" (default; bit-identical to an
                                     # uninstrumented run) or a "+"-joined
                                     # jsonl[:path]|csv[:base]|memory|
                                     # console[:every] spec (repro.telemetry;
                                     # REPRO_TELEMETRY overrides "off")

    @property
    def effective_clients(self) -> int:
        return self.clients_sampled or self.clients_per_server


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "sgd"           # sgd | momentum | adam | adamw
    learning_rate: float = 0.1
    warmup_steps: int = 0
    total_steps: int = 1000
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.999
    grad_clip: float = 0.0           # global-norm clip; 0 -> off
    microbatch: int = 0              # 0 -> no grad accumulation
    remat: bool = True
    seed: int = 0
