"""GFL repro package.

One package-wide PRNG policy: the partitionable threefry implementation.
With the legacy non-partitionable threefry, the values drawn for a
tensor-parallel-sharded leaf can depend on the downstream program's
sharding, so the same key yields DIFFERENT privacy noise under dense vs
rotate/sparse mesh combine — breaking cross-impl noise reproducibility and
making results depend on which repro modules happen to be imported.
Setting it here (the root of every repro import path) makes the choice
deterministic for the whole process; an explicit JAX_THREEFRY_PARTITIONABLE
environment setting wins.
"""
import os as _os

import jax as _jax

if "JAX_THREEFRY_PARTITIONABLE" not in _os.environ:
    _jax.config.update("jax_threefry_partitionable", True)

#: environment override for the package-wide base seed (see rng_key)
SEED_ENV = "REPRO_SEED"


def rng_key(seed=None) -> "_jax.Array":
    """The approved seed factory (gflint GFL001).

    Launchers and demos must not hard-code ``PRNGKey(0)`` at the call
    site — a sweep that forgets to thread its seed then silently shares
    randomness across runs.  ``rng_key()`` draws the base key from one
    place: an explicit ``seed`` argument wins, else the ``REPRO_SEED``
    environment variable, else 0 (bit-identical to the historical
    ``PRNGKey(0)`` default, so existing goldens are unchanged).
    Derive per-use keys with ``jax.random.fold_in``/``split`` as usual.
    """
    if seed is None:
        seed = int(_os.environ.get(SEED_ENV, "0"))
    return _jax.random.PRNGKey(seed)
