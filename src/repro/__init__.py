"""GFL repro package.

One package-wide PRNG policy: the partitionable threefry implementation.
With the legacy non-partitionable threefry, the values drawn for a
tensor-parallel-sharded leaf can depend on the downstream program's
sharding, so the same key yields DIFFERENT privacy noise under dense vs
rotate/sparse mesh combine — breaking cross-impl noise reproducibility and
making results depend on which repro modules happen to be imported.
Setting it here (the root of every repro import path) makes the choice
deterministic for the whole process; an explicit JAX_THREEFRY_PARTITIONABLE
environment setting wins.
"""
import os as _os

import jax as _jax

if "JAX_THREEFRY_PARTITIONABLE" not in _os.environ:
    _jax.config.update("jax_threefry_partitionable", True)
