from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    momentum,
    sgd,
    make_optimizer,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine
from repro.optim.clip import clip_by_global_norm, per_leaf_clip

__all__ = [
    "Optimizer", "sgd", "momentum", "adam", "adamw", "make_optimizer",
    "constant", "cosine_decay", "warmup_cosine",
    "clip_by_global_norm", "per_leaf_clip",
]
