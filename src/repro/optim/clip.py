"""Gradient clipping: global-norm (training stability) and per-client
B-ball projection (Assumption 3 enforcement / DP sensitivity control)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    nrm = global_norm(tree)
    coef = jnp.minimum(1.0, max_norm / jnp.maximum(nrm, 1e-12))
    return jax.tree.map(lambda x: (x * coef).astype(x.dtype), tree), nrm


def per_leaf_clip(tree, max_norm: float):
    def clip(x):
        nrm = jnp.linalg.norm(x.astype(jnp.float32))
        coef = jnp.minimum(1.0, max_norm / jnp.maximum(nrm, 1e-12))
        return (x * coef).astype(x.dtype)
    return jax.tree.map(clip, tree)
