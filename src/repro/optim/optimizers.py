"""Pure-JAX optimizers (optax-style init/update pairs, pytree-generic).

The GFL path uses plain SGD (the paper's algorithm has no optimizer state,
and per-server Adam moments at multi-B scale would not fit HBM); Adam/AdamW
are provided for the small-scale trainers and examples.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (updates, state)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step, lr):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, step, lr):
        new_m = jax.tree.map(lambda m, g: beta * m + g, state, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -lr * (beta * m + g), new_m, grads)
        else:
            upd = jax.tree.map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, step, lr):
        t = step + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m, v, p):
            step_ = m / bc1 / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (-lr * step_).astype(p.dtype)

        return jax.tree.map(upd, m, v, params), {"m": m, "v": v}

    return Optimizer(init, update)


def adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    return adam(b1, b2, eps, weight_decay)


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    if cfg.optimizer == "sgd":
        return sgd()
    if cfg.optimizer == "momentum":
        return momentum(cfg.beta1)
    if cfg.optimizer == "adam":
        return adam(cfg.beta1, cfg.beta2)
    if cfg.optimizer == "adamw":
        return adamw(cfg.beta1, cfg.beta2, weight_decay=cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")
