"""RunLog: the per-round record list behind engine run results.

Engines used to keep ad-hoc parallel lists (``gaps``, ``msd_rounds``,
``flush history``...) and stack them into NamedTuple fields at the end.
A :class:`RunLog` replaces those lists with one list of per-round record
dicts: each appended row is simultaneously (a) forwarded to the active
telemetry session's ``round`` stream (no-op when telemetry is off) and
(b) kept for the legacy result fields, which become :meth:`column` /
:meth:`stack` views over the same rows — so ``PopulationRunResult.gaps``
and the telemetry JSONL can never disagree.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.telemetry.stream import emit


class RunLog:
    """Ordered per-round records of one engine run."""

    def __init__(self, engine: str, stream: str = "round"):
        self.engine = engine
        self.stream = stream
        self.rows: List[Dict] = []

    def __len__(self) -> int:
        return len(self.rows)

    def row(self, round: int, **values) -> Dict:
        """Append one per-round record and forward it to telemetry.

        ``None`` values are dropped (a field the execution mode didn't
        realize); everything else must be a host value (engines log from
        the host loop or post-scan)."""
        rec: Dict = {"round": int(round), "engine": self.engine}
        for k, v in values.items():
            if v is not None:
                rec[k] = v
        self.rows.append(rec)
        emit(self.stream, rec)
        return rec

    def extend_arrays(self, arrays: Mapping[str, Sequence], *,
                      start: int = 0) -> None:
        """Bulk-append rows from stacked per-round arrays (the scan
        paths produce whole-run arrays, not a host loop).  All arrays
        must share their leading length; row ``i`` gets round
        ``start + i``."""
        lengths = {len(a) for a in arrays.values()}
        if len(lengths) > 1:
            raise ValueError(f"ragged run-log arrays: lengths {lengths}")
        n = lengths.pop() if lengths else 0
        for i in range(n):
            self.row(start + i, **{k: _host(a[i]) for k, a in arrays.items()})

    # -- legacy-field views ------------------------------------------------

    def column(self, field: str, default=None) -> List:
        return [r.get(field, default) for r in self.rows]

    def stack(self, field: str) -> Optional[np.ndarray]:
        """Rows' ``field`` stacked into one array (None when no row has
        it — the legacy 'history not recorded' value)."""
        vals = [r[field] for r in self.rows if field in r]
        if not vals:
            return None
        return np.stack([np.asarray(v) for v in vals])


def _host(value):
    """Per-element coercion for extend_arrays rows: 0-d -> python
    scalar, 1-d stays an array (series fields)."""
    arr = np.asarray(value)
    if arr.ndim == 0:
        return arr.item()
    return arr
