"""Pluggable telemetry sinks: where flushed records land.

Every sink consumes the same enveloped record dict (``stream``, ``run``,
``t_wall`` plus the schema'd fields) — the JSONL sink is the canonical
on-disk format the inspector CLI reads; CSV writes one file per stream
(records of different streams have different columns); the memory sink
backs tests and the run-result views; the console sink renders a live
table (rich when available, aligned plain text otherwise).
"""
from __future__ import annotations

import csv
import json
import os
import sys
from pathlib import Path
from typing import Dict, List, Optional


def _jsonable(value):
    """Record values -> JSON-ready python (lists for series, floats for
    numpy scalars).  Non-finite floats stay as-is: ``json`` round-trips
    them as Infinity/NaN literals, and eps = inf is a meaningful ledger
    state (a zero-noise mechanism), not an error."""
    if hasattr(value, "tolist"):
        return value.tolist()
    if hasattr(value, "item"):
        return value.item()
    return value


class Sink:
    """Base sink: ``write`` one enveloped record, ``close`` when the
    session ends."""

    def write(self, record: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """Keeps records in a list — tests and the inspector's tail mode."""

    def __init__(self):
        self.records: List[dict] = []

    def write(self, record: dict) -> None:
        self.records.append(record)

    def by_stream(self, stream: str) -> List[dict]:
        return [r for r in self.records if r.get("stream") == stream]


class JsonlSink(Sink):
    """One JSON object per line — the canonical run-record format
    (``python -m repro.telemetry.inspect`` reads it)."""

    def __init__(self, path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = open(self.path, "w", encoding="utf-8")

    def write(self, record: dict) -> None:
        self._fh.write(json.dumps(
            {k: _jsonable(v) for k, v in record.items()}) + "\n")

    def close(self) -> None:
        self._fh.flush()
        self._fh.close()


class CsvSink(Sink):
    """One CSV file per stream (``<base>.<stream>.csv``): streams have
    different columns, so a single flat file would be mostly holes.
    Columns are fixed by the stream's registered schema order."""

    def __init__(self, base_path):
        self.base = Path(base_path)
        self.base.parent.mkdir(parents=True, exist_ok=True)
        self._writers: Dict[str, tuple] = {}

    def _writer(self, stream: str):
        if stream not in self._writers:
            from repro.telemetry.schema import get_schema
            cols = (["run", "t_wall"]
                    + [f.name for f in get_schema(stream).fields])
            path = self.base.with_name(
                f"{self.base.stem}.{stream}.csv")
            fh = open(path, "w", newline="", encoding="utf-8")
            w = csv.DictWriter(fh, fieldnames=cols, extrasaction="ignore")
            w.writeheader()
            self._writers[stream] = (fh, w)
        return self._writers[stream][1]

    def write(self, record: dict) -> None:
        stream = record.get("stream", "")
        row = {k: _jsonable(v) for k, v in record.items() if k != "stream"}
        for k, v in row.items():
            if isinstance(v, list):
                row[k] = json.dumps(v)
        self._writer(stream).writerow(row)

    def close(self) -> None:
        for fh, _ in self._writers.values():
            fh.flush()
            fh.close()


class ConsoleSink(Sink):
    """Live run table on stderr: one line per ``every`` records of the
    watched stream (default: every record of ``round``).  Uses rich when
    importable, column-aligned plain text otherwise — never a hard dep."""

    _COLS = ("round", "engine", "msd", "q", "gap", "cohort")

    def __init__(self, every: int = 1, stream: str = "round", file=None):
        self.every = max(1, int(every))
        self.stream = stream
        self.file = file or sys.stderr
        self._seen = 0
        self._header_done = False
        try:                                     # optional pretty renderer
            from rich.console import Console
            self._console: Optional[object] = Console(
                file=self.file, force_terminal=False)
        except ImportError:
            self._console = None

    def _fmt(self, record: dict) -> str:
        parts = []
        for col in self._COLS:
            v = record.get(col, "")
            if isinstance(v, float):
                v = f"{v:.4g}"
            parts.append(f"{str(v):>10.10}")
        return "  ".join(parts)

    def write(self, record: dict) -> None:
        if record.get("stream") != self.stream:
            return
        self._seen += 1
        if self._seen % self.every:
            return
        if not self._header_done:
            header = "  ".join(f"{c:>10.10}" for c in self._COLS)
            self._emit_line(header)
            self._emit_line("-" * len(header))
            self._header_done = True
        self._emit_line(self._fmt(record))

    def _emit_line(self, line: str) -> None:
        if self._console is not None:
            self._console.print(line, highlight=False)
        else:
            print(line, file=self.file)


def sink_from_spec(spec: str) -> Sink:
    """Build one sink from a ``kind[:arg]`` spec component.

    ``jsonl[:path]`` | ``csv[:base]`` | ``memory`` | ``console[:every]``.
    Default paths land under ``$REPRO_TELEMETRY_DIR`` (default
    ``telemetry_out/``) so bare ``--telemetry jsonl`` works out of the
    box.
    """
    kind, _, arg = spec.partition(":")
    outdir = Path(os.environ.get("REPRO_TELEMETRY_DIR", "telemetry_out"))
    if kind == "jsonl":
        return JsonlSink(arg or outdir / "run.jsonl")
    if kind == "csv":
        return CsvSink(arg or outdir / "run.csv")
    if kind == "memory":
        return MemorySink()
    if kind == "console":
        return ConsoleSink(every=int(arg) if arg else 1)
    raise ValueError(f"unknown telemetry sink spec {spec!r}; expected "
                     "jsonl[:path] | csv[:base] | memory | console[:every]")
