"""Telemetry session + the in-graph metrics tap.

One process-wide :class:`TelemetrySession` (opened by an engine from
``GFLConfig.telemetry``, by ``launch/train.py --telemetry``, or
explicitly via :func:`session`) owns the sinks and the span tracer.
:func:`emit` is THE emission primitive everywhere:

* host-side values -> ingested directly (validation + envelope + sinks);
* traced values (inside jit / ``lax.scan`` bodies) -> flushed through
  ``jax.experimental.io_callback``, so the instrumented program stays
  fused and the tap is read-only (no RNG consumption, no change to any
  engine value — regression-tested in tests/test_telemetry.py).

Hard contract: with no session active, :func:`emit` returns before
touching jax — the traced program is IDENTICAL to the uninstrumented
one (``telemetry=off`` is bit-identical by construction).  Because the
on/off decision is taken at trace time, modules with process-lifetime
``@jax.jit`` caches (the kernel layer) must not call :func:`emit` on
traced values — they emit host-side at dispatch time instead
(:mod:`repro.kernels.ops`); gflint GFL006 enforces that raw
``io_callback`` use routes through this module.
"""
from __future__ import annotations

import os
import time
from contextlib import contextmanager, nullcontext
from typing import Dict, List, Mapping, Optional, Tuple

from repro.telemetry.schema import validate_record
from repro.telemetry.sinks import MemorySink, Sink, sink_from_spec
from repro.telemetry.trace import SpanTracer

ENV_FLAG = "REPRO_TELEMETRY"
ENV_FLUSH_EVERY = "REPRO_TELEMETRY_FLUSH_EVERY"
ENV_PROFILE = "REPRO_TELEMETRY_PROFILE"
_OFF = ("", "off", "none", "0")

_SESSION: Optional["TelemetrySession"] = None


class TelemetrySession:
    """Owns the sinks + tracer of one telemetry-enabled run scope."""

    def __init__(self, sinks: List[Sink], tracer: Optional[SpanTracer] = None,
                 run_id: Optional[str] = None,
                 profile: Optional[bool] = None):
        self.sinks = list(sinks)
        self.tracer = tracer
        self.run_id = run_id or f"run-{os.getpid()}-{int(time.time())}"
        self.records = 0
        self._seq = 0
        # host seconds spent inside telemetry io_callback flushes — the
        # phase profiler subtracts this from phase wall time
        self.callback_seconds = 0.0
        if profile is None:
            profile = os.environ.get(ENV_PROFILE, "0") \
                not in ("", "0", "false")
        self.profile = bool(profile)

    def next_seq(self) -> int:
        """Monotone per-session sequence number (the ``kernel`` stream's
        index — dispatch events have no natural round)."""
        self._seq += 1
        return self._seq

    def ingest(self, stream: str, record: Mapping) -> None:
        rec = {"stream": stream, "run": self.run_id,
               "t_wall": time.time(), **record}
        for sink in self.sinks:
            sink.write(rec)
        self.records += 1

    def memory_records(self, stream: Optional[str] = None) -> List[dict]:
        """Records captured by any MemorySink (tests / tail views)."""
        out: List[dict] = []
        for sink in self.sinks:
            if isinstance(sink, MemorySink):
                out.extend(sink.records if stream is None
                           else sink.by_stream(stream))
        return out

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()
        if self.tracer is not None:
            self.tracer.save()


def current_session() -> Optional[TelemetrySession]:
    return _SESSION


def telemetry_active() -> bool:
    return _SESSION is not None


def _is_traced(value) -> bool:
    import jax
    return isinstance(value, jax.core.Tracer)


def _to_py(value):
    """numpy/jax host value -> plain python for the record envelope."""
    if hasattr(value, "ndim") and getattr(value, "ndim", 0) > 0:
        return [_to_py(v) for v in value.tolist()] \
            if hasattr(value, "tolist") else list(value)
    if hasattr(value, "item"):
        value = value.item()
    if isinstance(value, bool):
        return int(value)
    return value


def emit(stream: str, values: Mapping, *, ordered: bool = True) -> None:
    """Emit one record to the active session (no-op when none).

    Works from host code and from inside traced bodies: traced values are
    flushed via ``jax.experimental.io_callback`` (``ordered=True`` keeps
    the JSONL record order deterministic inside ``lax.scan``).  Keys are
    validated against the stream's registered schema at the call site —
    trace time for in-graph taps.
    """
    sess = _SESSION
    if sess is None:
        return
    vals = dict(values)
    validate_record(stream, vals)
    if not any(_is_traced(v) for v in vals.values()):
        sess.ingest(stream, {k: _to_py(v) for k, v in vals.items()})
        return

    import jax.numpy as jnp
    from jax.experimental import io_callback

    keys = tuple(sorted(vals))

    def _flush(*arrays):
        live = _SESSION            # looked up at RUN time: a program traced
        if live is None:           # under a session stays safe after close
            return
        t0 = time.perf_counter()
        live.ingest(stream, {k: _to_py(a) for k, a in zip(keys, arrays)})
        live.callback_seconds += time.perf_counter() - t0

    io_callback(_flush, None, *[jnp.asarray(vals[k]) for k in keys],
                ordered=ordered)


def flush_every_from_env(default: int = 1) -> int:
    """The ``REPRO_TELEMETRY_FLUSH_EVERY`` buffering knob (>= 1)."""
    try:
        n = int(os.environ.get(ENV_FLUSH_EVERY, "") or default)
    except ValueError:
        n = default
    return max(1, n)


class MetricsStream:
    """In-graph metric accumulator for scanned whole-run executors.

    The carry is a tiny f32 pytree threaded alongside the engine state
    (so the scan stays fused); :meth:`tap` folds the round's values into
    the declared cumulative fields and flushes schema'd records via
    :func:`emit`'s ``io_callback`` path.

    Engines construct one only when telemetry is active — the off-path
    scan carries exactly the uninstrumented state pytree::

        ms = MetricsStream("step", cumulative={"events_total": "events"})
        carry0 = (key, state) + ((ms.init(),) if ms else ())
        # inside the body:
        acc = ms.tap(acc, {"step": i, "events": n_valid, ...})
        # after the scan (buffered mode only; no-op at flush_every=1):
        ms.drain(final_carry[2])

    ``cumulative`` maps running-total field -> the per-tap source field
    it sums (a bare tuple of names sums each field into itself).

    ``flush_every`` buffers N rows per ordered ``io_callback`` flush
    (default 1 — one callback per row, the exact pre-buffering program;
    the env knob ``REPRO_TELEMETRY_FLUSH_EVERY`` overrides the default).
    Buffered mode needs the full per-row field set declared up front
    (``fields``; scalar/int kinds only — buffer dtypes derive from the
    schema), rides ``[N]``-shaped ring buffers in the carry, flushes
    inside a ``lax.cond`` when the buffer fills, and :meth:`drain`
    emits the partial tail after the scan.
    """

    def __init__(self, stream: str,
                 cumulative: Mapping[str, str] | Tuple[str, ...] = (),
                 *, fields: Tuple[str, ...] = (),
                 flush_every: Optional[int] = None):
        from repro.telemetry.schema import get_schema
        self.stream = stream
        if not isinstance(cumulative, Mapping):
            cumulative = {name: name for name in cumulative}
        self.cumulative = dict(cumulative)
        schema = get_schema(stream)
        allowed = schema.field_map()
        for total in self.cumulative:
            if total not in allowed:
                raise KeyError(f"cumulative field {total!r} not in stream "
                               f"{stream!r} schema")
        if flush_every is None:
            flush_every = flush_every_from_env()
        self.flush_every = max(1, int(flush_every))
        self.fields = tuple(fields)
        if self.flush_every > 1:
            if not self.fields:
                raise ValueError(
                    "flush_every > 1 needs the per-row field set declared "
                    "up front (fields=...) so buffer dtypes are known")
            kinds = {}
            for name in self.fields:
                if name not in allowed:
                    raise KeyError(f"field {name!r} not in stream "
                                   f"{stream!r} schema")
                if allowed[name].kind not in ("scalar", "int"):
                    raise ValueError(
                        f"buffered field {name!r} has kind "
                        f"{allowed[name].kind!r}; only scalar/int rows "
                        f"can ride the flush buffer")
                kinds[name] = allowed[name].kind
            self._kinds = kinds

    # -- carry construction --------------------------------------------

    def init(self) -> Dict[str, object]:
        import jax.numpy as jnp
        totals = {f: jnp.zeros((), jnp.float32) for f in self.cumulative}
        if self.flush_every == 1:
            return totals
        n = self.flush_every
        buf = {name: jnp.zeros(
                   (n,), jnp.int32 if self._kinds[name] == "int"
                   else jnp.float32)
               for name in self.fields}
        return {"totals": totals, "buf": buf,
                "pos": jnp.zeros((), jnp.int32)}

    # -- per-row tap ----------------------------------------------------

    def tap(self, carry: Dict, values: Mapping, *, flush: bool = True,
            ordered: bool = True) -> Dict:
        """Fold ``values`` into the running totals and (by default) flush
        one record combining the instantaneous values with the totals.
        Returns the new carry."""
        import jax.numpy as jnp
        vals = dict(values)
        totals = carry["totals"] if self.flush_every > 1 else carry
        new_totals = dict(totals)
        for total, source in self.cumulative.items():
            if source in vals:
                new_totals[total] = (totals[total]
                                     + jnp.asarray(vals[source],
                                                   jnp.float32))
        row = {**vals, **new_totals}
        if self.flush_every == 1:
            if flush:
                emit(self.stream, row, ordered=ordered)
            return new_totals
        return self._tap_buffered(carry, row, new_totals, ordered=ordered)

    def _tap_buffered(self, carry, row, new_totals, *, ordered) -> Dict:
        import jax.numpy as jnp
        from jax import lax
        from jax.experimental import io_callback
        from repro.telemetry.schema import validate_record

        validate_record(self.stream, row)
        if set(row) != set(self.fields):
            raise ValueError(
                f"buffered tap row fields {sorted(row)} != declared "
                f"fields {sorted(self.fields)} — the buffer layout is "
                f"fixed at construction")
        pos = carry["pos"]
        buf = {name: carry["buf"][name].at[pos].set(
                   jnp.asarray(row[name]).astype(carry["buf"][name].dtype))
               for name in self.fields}
        filled = pos + 1

        def _flush(count):
            io_callback(self._flush_rows, None,
                        *[buf[name] for name in self.fields], count,
                        ordered=ordered)
            return jnp.zeros((), jnp.int32)

        new_pos = lax.cond(filled >= self.flush_every, _flush,
                           lambda count: filled.astype(jnp.int32), filled)
        return {"totals": new_totals, "buf": buf, "pos": new_pos}

    def _flush_rows(self, *arrays) -> None:
        """Host side of the buffered flush: re-emit ``count`` buffered
        rows in order (looked up at RUN time, like :func:`emit`)."""
        live = _SESSION
        if live is None:
            return
        t0 = time.perf_counter()
        *cols, count = arrays
        for i in range(int(count)):
            live.ingest(self.stream,
                        {name: _to_py(col[i])
                         for name, col in zip(self.fields, cols)})
        live.callback_seconds += time.perf_counter() - t0

    # -- post-scan tail -------------------------------------------------

    def drain(self, carry: Optional[Dict]) -> None:
        """Emit the partial buffer tail after the scan (host side).  A
        no-op at ``flush_every=1`` (nothing is ever buffered) and with
        no active session."""
        if carry is None or self.flush_every == 1 or _SESSION is None:
            return
        import numpy as np
        self._flush_rows(*[np.asarray(carry["buf"][name])
                           for name in self.fields],
                         np.asarray(carry["pos"]))


# ---------------------------------------------------------------------------
# session lifecycle
# ---------------------------------------------------------------------------


def _trace_path_for(sinks: List[Sink]):
    """Default trace-JSON path: beside the first file-backed sink, else
    under the default telemetry dir."""
    from pathlib import Path

    from repro.telemetry.sinks import CsvSink, JsonlSink
    for sink in sinks:
        if isinstance(sink, JsonlSink):
            return sink.path.with_suffix(".trace.json")
        if isinstance(sink, CsvSink):
            return sink.base.with_suffix(".trace.json")
    return Path(os.environ.get("REPRO_TELEMETRY_DIR",
                               "telemetry_out")) / "run.trace.json"


@contextmanager
def session(spec_or_sinks="memory", *, trace_path=None,
            run_id: Optional[str] = None,
            profile: Optional[bool] = None):
    """Open a telemetry session for a ``with`` scope.

    ``spec_or_sinks``: a ``+``-separated sink spec string
    (``"jsonl:runs/a.jsonl+console"``) or an explicit list of
    :class:`~repro.telemetry.sinks.Sink` objects.  Nesting is a no-op
    passthrough: an inner engine-opened session never shadows an outer
    CLI-opened one, so records from nested executors land in one stream.
    """
    global _SESSION
    if _SESSION is not None:           # outer session wins; reuse it
        yield _SESSION
        return
    if isinstance(spec_or_sinks, str):
        sinks = [sink_from_spec(part)
                 for part in spec_or_sinks.split("+") if part]
    else:
        sinks = list(spec_or_sinks)
    tracer = SpanTracer(trace_path if trace_path is not None
                        else _trace_path_for(sinks))
    sess = TelemetrySession(sinks, tracer, run_id, profile=profile)
    _SESSION = sess
    try:
        yield sess
    finally:
        _SESSION = None
        sess.close()


def config_spec(cfg=None) -> str:
    """The effective telemetry spec of a run: the config field when set,
    else the ``REPRO_TELEMETRY`` env override, else ``"off"``."""
    spec = getattr(cfg, "telemetry", "off") if cfg is not None else "off"
    if spec in _OFF:
        spec = os.environ.get(ENV_FLAG, "off")
    return spec or "off"


def session_from_config(cfg=None):
    """Context manager for an engine run: opens a session per
    ``cfg.telemetry`` / ``REPRO_TELEMETRY`` — or a passthrough
    nullcontext when telemetry is off or an outer session is already
    active (the bit-identity off path)."""
    spec = config_spec(cfg)
    if spec in _OFF or _SESSION is not None:
        return nullcontext(_SESSION)
    return session(spec)
