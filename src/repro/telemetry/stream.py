"""Telemetry session + the in-graph metrics tap.

One process-wide :class:`TelemetrySession` (opened by an engine from
``GFLConfig.telemetry``, by ``launch/train.py --telemetry``, or
explicitly via :func:`session`) owns the sinks and the span tracer.
:func:`emit` is THE emission primitive everywhere:

* host-side values -> ingested directly (validation + envelope + sinks);
* traced values (inside jit / ``lax.scan`` bodies) -> flushed through
  ``jax.experimental.io_callback``, so the instrumented program stays
  fused and the tap is read-only (no RNG consumption, no change to any
  engine value — regression-tested in tests/test_telemetry.py).

Hard contract: with no session active, :func:`emit` returns before
touching jax — the traced program is IDENTICAL to the uninstrumented
one (``telemetry=off`` is bit-identical by construction).  Because the
on/off decision is taken at trace time, modules with process-lifetime
``@jax.jit`` caches (the kernel layer) must not call :func:`emit` on
traced values — they emit host-side at dispatch time instead
(:mod:`repro.kernels.ops`); gflint GFL006 enforces that raw
``io_callback`` use routes through this module.
"""
from __future__ import annotations

import os
import time
from contextlib import contextmanager, nullcontext
from typing import Dict, List, Mapping, Optional, Tuple

from repro.telemetry.schema import validate_record
from repro.telemetry.sinks import MemorySink, Sink, sink_from_spec
from repro.telemetry.trace import SpanTracer

ENV_FLAG = "REPRO_TELEMETRY"
_OFF = ("", "off", "none", "0")

_SESSION: Optional["TelemetrySession"] = None


class TelemetrySession:
    """Owns the sinks + tracer of one telemetry-enabled run scope."""

    def __init__(self, sinks: List[Sink], tracer: Optional[SpanTracer] = None,
                 run_id: Optional[str] = None):
        self.sinks = list(sinks)
        self.tracer = tracer
        self.run_id = run_id or f"run-{os.getpid()}-{int(time.time())}"
        self.records = 0
        self._seq = 0

    def next_seq(self) -> int:
        """Monotone per-session sequence number (the ``kernel`` stream's
        index — dispatch events have no natural round)."""
        self._seq += 1
        return self._seq

    def ingest(self, stream: str, record: Mapping) -> None:
        rec = {"stream": stream, "run": self.run_id,
               "t_wall": time.time(), **record}
        for sink in self.sinks:
            sink.write(rec)
        self.records += 1

    def memory_records(self, stream: Optional[str] = None) -> List[dict]:
        """Records captured by any MemorySink (tests / tail views)."""
        out: List[dict] = []
        for sink in self.sinks:
            if isinstance(sink, MemorySink):
                out.extend(sink.records if stream is None
                           else sink.by_stream(stream))
        return out

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()
        if self.tracer is not None:
            self.tracer.save()


def current_session() -> Optional[TelemetrySession]:
    return _SESSION


def telemetry_active() -> bool:
    return _SESSION is not None


def _is_traced(value) -> bool:
    import jax
    return isinstance(value, jax.core.Tracer)


def _to_py(value):
    """numpy/jax host value -> plain python for the record envelope."""
    if hasattr(value, "ndim") and getattr(value, "ndim", 0) > 0:
        return [_to_py(v) for v in value.tolist()] \
            if hasattr(value, "tolist") else list(value)
    if hasattr(value, "item"):
        value = value.item()
    if isinstance(value, bool):
        return int(value)
    return value


def emit(stream: str, values: Mapping, *, ordered: bool = True) -> None:
    """Emit one record to the active session (no-op when none).

    Works from host code and from inside traced bodies: traced values are
    flushed via ``jax.experimental.io_callback`` (``ordered=True`` keeps
    the JSONL record order deterministic inside ``lax.scan``).  Keys are
    validated against the stream's registered schema at the call site —
    trace time for in-graph taps.
    """
    sess = _SESSION
    if sess is None:
        return
    vals = dict(values)
    validate_record(stream, vals)
    if not any(_is_traced(v) for v in vals.values()):
        sess.ingest(stream, {k: _to_py(v) for k, v in vals.items()})
        return

    import jax.numpy as jnp
    from jax.experimental import io_callback

    keys = tuple(sorted(vals))

    def _flush(*arrays):
        live = _SESSION            # looked up at RUN time: a program traced
        if live is None:           # under a session stays safe after close
            return
        live.ingest(stream, {k: _to_py(a) for k, a in zip(keys, arrays)})

    io_callback(_flush, None, *[jnp.asarray(vals[k]) for k in keys],
                ordered=ordered)


class MetricsStream:
    """In-graph metric accumulator for scanned whole-run executors.

    The carry is a tiny f32 pytree threaded alongside the engine state
    (so the scan stays fused); :meth:`tap` folds the round's values into
    the declared cumulative fields and flushes one schema'd record per
    round via :func:`emit`'s ``io_callback`` path.

    Engines construct one only when telemetry is active — the off-path
    scan carries exactly the uninstrumented state pytree::

        ms = MetricsStream("step", cumulative={"events_total": "events"})
        carry0 = (key, state) + ((ms.init(),) if ms else ())
        # inside the body:
        acc = ms.tap(acc, {"step": i, "events": n_valid, ...})

    ``cumulative`` maps running-total field -> the per-tap source field
    it sums (a bare tuple of names sums each field into itself).
    """

    def __init__(self, stream: str,
                 cumulative: Mapping[str, str] | Tuple[str, ...] = ()):
        from repro.telemetry.schema import get_schema
        self.stream = stream
        if not isinstance(cumulative, Mapping):
            cumulative = {name: name for name in cumulative}
        self.cumulative = dict(cumulative)
        allowed = get_schema(stream).field_map()
        for total in self.cumulative:
            if total not in allowed:
                raise KeyError(f"cumulative field {total!r} not in stream "
                               f"{stream!r} schema")

    def init(self) -> Dict[str, object]:
        import jax.numpy as jnp
        return {f: jnp.zeros((), jnp.float32) for f in self.cumulative}

    def tap(self, carry: Dict, values: Mapping, *, flush: bool = True,
            ordered: bool = True) -> Dict:
        """Fold ``values`` into the running totals and (by default) flush
        one record combining the instantaneous values with the totals.
        Returns the new carry."""
        import jax.numpy as jnp
        vals = dict(values)
        new_carry = dict(carry)
        for total, source in self.cumulative.items():
            if source in vals:
                new_carry[total] = (carry[total]
                                    + jnp.asarray(vals[source], jnp.float32))
        if flush:
            emit(self.stream, {**vals, **new_carry}, ordered=ordered)
        return new_carry


# ---------------------------------------------------------------------------
# session lifecycle
# ---------------------------------------------------------------------------


def _trace_path_for(sinks: List[Sink]):
    """Default trace-JSON path: beside the first file-backed sink, else
    under the default telemetry dir."""
    from pathlib import Path

    from repro.telemetry.sinks import CsvSink, JsonlSink
    for sink in sinks:
        if isinstance(sink, JsonlSink):
            return sink.path.with_suffix(".trace.json")
        if isinstance(sink, CsvSink):
            return sink.base.with_suffix(".trace.json")
    return Path(os.environ.get("REPRO_TELEMETRY_DIR",
                               "telemetry_out")) / "run.trace.json"


@contextmanager
def session(spec_or_sinks="memory", *, trace_path=None,
            run_id: Optional[str] = None):
    """Open a telemetry session for a ``with`` scope.

    ``spec_or_sinks``: a ``+``-separated sink spec string
    (``"jsonl:runs/a.jsonl+console"``) or an explicit list of
    :class:`~repro.telemetry.sinks.Sink` objects.  Nesting is a no-op
    passthrough: an inner engine-opened session never shadows an outer
    CLI-opened one, so records from nested executors land in one stream.
    """
    global _SESSION
    if _SESSION is not None:           # outer session wins; reuse it
        yield _SESSION
        return
    if isinstance(spec_or_sinks, str):
        sinks = [sink_from_spec(part)
                 for part in spec_or_sinks.split("+") if part]
    else:
        sinks = list(spec_or_sinks)
    tracer = SpanTracer(trace_path if trace_path is not None
                        else _trace_path_for(sinks))
    sess = TelemetrySession(sinks, tracer, run_id)
    _SESSION = sess
    try:
        yield sess
    finally:
        _SESSION = None
        sess.close()


def config_spec(cfg=None) -> str:
    """The effective telemetry spec of a run: the config field when set,
    else the ``REPRO_TELEMETRY`` env override, else ``"off"``."""
    spec = getattr(cfg, "telemetry", "off") if cfg is not None else "off"
    if spec in _OFF:
        spec = os.environ.get(ENV_FLAG, "off")
    return spec or "off"


def session_from_config(cfg=None):
    """Context manager for an engine run: opens a session per
    ``cfg.telemetry`` / ``REPRO_TELEMETRY`` — or a passthrough
    nullcontext when telemetry is off or an outer session is already
    active (the bit-identity off path)."""
    spec = config_spec(cfg)
    if spec in _OFF or _SESSION is not None:
        return nullcontext(_SESSION)
    return session(spec)
