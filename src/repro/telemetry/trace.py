"""Span tracer: Chrome/Perfetto trace-event JSON for engine phases.

``trace_span("round_fold")`` wraps any host-side phase — engine setup,
kernel dispatch/autotune, mesh step construction, per-step driver loops —
and records a complete ("ph": "X") trace event with microsecond
timestamps.  The resulting file loads directly in ``chrome://tracing`` /
Perfetto (``{"traceEvents": [...]}`` format).

Spans wrapped around *jitted* bodies measure trace/compile/autotune
time (the body runs once per compilation) — that is the intended
semantics: dispatch-time attribution, not per-execution device timing.
For device-side profiling every span can also pass through to
``jax.profiler.TraceAnnotation`` (``annotate=True`` on the tracer, or
``REPRO_TELEMETRY_JAXPROF=1``), so spans show up in a jax profiler
capture under the same names.

With no active telemetry session ``trace_span`` is a reusable no-op
context manager — zero allocation on the off path.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from pathlib import Path
from typing import List, Optional


class SpanTracer:
    """Collects trace events; ``save`` writes Chrome trace JSON."""

    def __init__(self, path=None, *, annotate: Optional[bool] = None):
        self.path = Path(path) if path else None
        self.events: List[dict] = []
        if annotate is None:
            annotate = os.environ.get(
                "REPRO_TELEMETRY_JAXPROF", "0") not in ("", "0", "false")
        self.annotate = annotate
        self._t0 = time.perf_counter()
        self._pid = os.getpid()

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, **args):
        ann = None
        if self.annotate:
            try:
                import jax
                ann = jax.profiler.TraceAnnotation(name)
                ann.__enter__()
            except Exception:       # profiler unavailable: spans still work
                ann = None
        ts = self._now_us()
        try:
            yield
        finally:
            dur = self._now_us() - ts
            self.events.append({
                "name": name, "ph": "X", "ts": ts, "dur": dur,
                "pid": self._pid, "tid": threading.get_ident() & 0xFFFF,
                "args": {k: _arg(v) for k, v in args.items()},
            })
            if ann is not None:
                ann.__exit__(None, None, None)

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker event."""
        self.events.append({
            "name": name, "ph": "i", "ts": self._now_us(), "s": "p",
            "pid": self._pid, "tid": threading.get_ident() & 0xFFFF,
            "args": {k: _arg(v) for k, v in args.items()},
        })

    def save(self, path=None) -> Optional[Path]:
        """Write ``{"traceEvents": [...]}``; returns the path (None when
        the tracer has nowhere to write)."""
        out = Path(path) if path else self.path
        if out is None:
            return None
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(
            {"traceEvents": self.events,
             "displayTimeUnit": "ms"}) + "\n", encoding="utf-8")
        return out


def _arg(value):
    if hasattr(value, "item"):
        return value.item()
    if isinstance(value, (str, int, float, bool, type(None))):
        return value
    return str(value)


@contextmanager
def _null_span():
    yield


def trace_span(name: str, **args):
    """Span against the active session's tracer (no-op when telemetry is
    off).  Usage: ``with trace_span("round_fold", P=P, D=D): ...``

    Profiling sessions (``session(profile=True)`` /
    ``REPRO_TELEMETRY_PROFILE=1``) additionally attribute every span's
    wall time to compile/execute/callback via the ``profile`` stream
    (:mod:`repro.telemetry.profile`)."""
    from repro.telemetry.stream import current_session
    sess = current_session()
    if sess is None:
        return _null_span()
    if sess.profile:
        from repro.telemetry.profile import profile_phase
        return profile_phase(name, **args)
    if sess.tracer is None:
        return _null_span()
    return sess.tracer.span(name, **args)
