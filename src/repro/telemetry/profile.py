"""Phase-level profiler: compile vs execute vs host-callback attribution.

PR 7's span tracer answers *where host wall time went* per phase; this
module answers *what the phase spent it on*.  :func:`profile_phase`
wraps a phase (the same names ``trace_span`` uses — with
``session(profile=True)`` or ``REPRO_TELEMETRY_PROFILE=1`` every
``trace_span`` becomes a ``profile_phase`` automatically) and emits one
``profile`` stream record attributing the phase's wall clock:

``compile_s``    jaxpr tracing + MLIR lowering + XLA backend compile
                 seconds inside the phase, measured via the
                 ``jax.monitoring`` duration events — so a *silent
                 recompile* (shape drift, weak-type flapping, cache
                 key bugs) shows up as nonzero ``compile_s`` +
                 ``retraces``/``compiles`` counts long after warmup;
``callback_s``   host seconds spent inside telemetry ``io_callback``
                 flushes (``TelemetrySession.callback_seconds``) — the
                 live cost of observation itself;
``execute_s``    the remainder (device execute + host driver).

It also records the device ``peak_bytes_in_use`` watermark when the
backend exposes ``memory_stats()`` (TPU/GPU; CPU returns none — the
field is simply absent, the schema keeps it optional).

Ordered callbacks can land slightly after the dispatching phase
returns, so ``callback_s`` attribution is per-phase *approximate*; the
per-session total is exact.

With no active telemetry session everything here is a no-op.
"""
from __future__ import annotations

import time
from contextlib import contextmanager, nullcontext
from typing import Dict, Optional

# jax.monitoring duration events that constitute "compile" time.  The
# mapped name is the counter a firing increments (None = seconds only).
_COMPILE_EVENTS: Dict[str, Optional[str]] = {
    "/jax/core/compile/jaxpr_trace_duration": "retraces",
    "/jax/core/compile/jaxpr_to_mlir_module_duration": None,
    "/jax/core/compile/backend_compile_duration": "compiles",
}

# process-lifetime accumulators; phases snapshot + diff them
_COUNTERS = {"compile_s": 0.0, "retraces": 0, "compiles": 0}
_LISTENING = False


def _on_event_duration(event: str, duration: float, **kwargs) -> None:
    if event in _COMPILE_EVENTS:
        _COUNTERS["compile_s"] += float(duration)
        counter = _COMPILE_EVENTS[event]
        if counter is not None:
            _COUNTERS[counter] += 1


def ensure_listener() -> bool:
    """Register the jax.monitoring duration listener once per process.
    Returns False when the monitoring API is unavailable (profiler then
    reports wall/callback attribution only)."""
    global _LISTENING
    if _LISTENING:
        return True
    try:
        from jax import monitoring
        monitoring.register_event_duration_secs_listener(_on_event_duration)
    except Exception:
        return False
    _LISTENING = True
    return True


def compile_counters() -> Dict[str, float]:
    """A snapshot of the process-lifetime compile accumulators."""
    return dict(_COUNTERS)


def device_peak_bytes() -> Optional[int]:
    """``peak_bytes_in_use`` of the first local device, when the backend
    tracks it (TPU/GPU; CPU ``memory_stats()`` is None)."""
    try:
        import jax
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        return None
    if not stats:
        return None
    peak = stats.get("peak_bytes_in_use")
    return int(peak) if peak is not None else None


@contextmanager
def profile_phase(name: str, **args):
    """Wrap one host-side phase: span-trace it AND emit a ``profile``
    stream record attributing its wall time.  No-op without a session."""
    from repro.telemetry.stream import current_session, emit
    sess = current_session()
    if sess is None:
        yield
        return
    listening = ensure_listener()
    before = compile_counters()
    cb_before = sess.callback_seconds
    t0 = time.perf_counter()
    span = (sess.tracer.span(name, **args) if sess.tracer is not None
            else nullcontext())
    try:
        with span:
            yield
    finally:
        wall = time.perf_counter() - t0
        after = compile_counters()
        compile_s = (after["compile_s"] - before["compile_s"]
                     if listening else 0.0)
        callback_s = sess.callback_seconds - cb_before
        rec = {
            "seq": sess.next_seq(), "phase": name,
            "wall_s": wall, "compile_s": compile_s,
            "execute_s": max(0.0, wall - compile_s - callback_s),
            "callback_s": callback_s,
            "retraces": int(after["retraces"] - before["retraces"]),
            "compiles": int(after["compiles"] - before["compiles"]),
        }
        peak = device_peak_bytes()
        if peak is not None:
            rec["peak_bytes"] = float(peak)
        emit("profile", rec)
