"""Run inspector CLI: summarize a telemetry JSONL stream.

    python -m repro.telemetry.inspect RUN.jsonl
    python -m repro.telemetry.inspect RUN.jsonl --stream round --tail 5
    python -m repro.telemetry.inspect RUN.jsonl --trace RUN.trace.json
    python -m repro.telemetry.inspect bench [BENCH_history.jsonl]

Reads the canonical JSONL sink output, re-validates every record against
the schema registry, and prints per-metric summaries (count / min / p50 /
p99 / max via the mergeable :class:`~repro.telemetry.sketch.QuantileSketch`),
the eps-vs-round table from the ``privacy`` stream, and a spectral-gap
sparkline from the ``round`` stream.  Exit code 0 when every record
parses and validates, 1 otherwise — CI uses that as the artifact
sanity gate.

The ``bench`` subcommand renders per-metric trend tables + sparklines
from the append-only ``BENCH_history.jsonl`` that
``benchmarks/meta.write_bench`` maintains (see ``benchmarks/compare.py``
for the gating half).
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from collections import defaultdict
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.telemetry.schema import SchemaError, validate_record
from repro.telemetry.sketch import QuantileSketch

_ENVELOPE = ("stream", "run", "t_wall")
_SPARK = "▁▂▃▄▅▆▇█"


def load_records(path: Path, *, strict: bool = True
                 ) -> Tuple[List[dict], List[str]]:
    """Parse + schema-validate a JSONL file.  Returns (records, errors);
    with ``strict`` every malformed line is an error, otherwise it is
    skipped silently."""
    records: List[dict] = []
    errors: List[str] = []
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{path}:{lineno}: bad JSON ({e.msg})")
                continue
            stream = rec.get("stream")
            body = {k: v for k, v in rec.items() if k not in _ENVELOPE}
            try:
                if stream is None:
                    raise SchemaError("record has no 'stream' field")
                validate_record(stream, body)
            except SchemaError as e:
                errors.append(f"{path}:{lineno}: {e}")
                continue
            records.append(rec)
    if not strict:
        errors = []
    return records, errors


def _is_number(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def metric_sketches(records: List[dict]) -> Dict[Tuple[str, str],
                                                 QuantileSketch]:
    """One sketch per (stream, numeric field); series fields contribute
    every element."""
    sketches: Dict[Tuple[str, str], QuantileSketch] = defaultdict(
        QuantileSketch)
    for rec in records:
        stream = rec.get("stream", "?")
        for k, v in rec.items():
            if k in _ENVELOPE:
                continue
            if _is_number(v) and math.isfinite(v):
                sketches[(stream, k)].add(v)
            elif isinstance(v, list):
                for item in v:
                    if _is_number(item) and math.isfinite(item):
                        sketches[(stream, k)].add(item)
    return dict(sketches)


def _fmt(v: float) -> str:
    return f"{v:.6g}"


def summary_table(records: List[dict]) -> str:
    sketches = metric_sketches(records)
    lines = [f"{'stream':<9} {'metric':<18} {'count':>7} {'min':>11} "
             f"{'p50':>11} {'p99':>11} {'max':>11}"]
    lines.append("-" * len(lines[0]))
    for (stream, field), sk in sorted(sketches.items()):
        p50, p99 = sk.quantiles([0.5, 0.99])
        lines.append(f"{stream:<9} {field:<18} {sk.count:>7} "
                     f"{_fmt(sk.min):>11} {_fmt(p50):>11} "
                     f"{_fmt(p99):>11} {_fmt(sk.max):>11}")
    return "\n".join(lines)


def eps_table(records: List[dict], *, max_rows: int = 12) -> Optional[str]:
    rows = [r for r in records if r.get("stream") == "privacy"]
    if not rows:
        return None
    lines = [f"{'step':>6} {'server':<9} {'eps':>11} {'delta':>11} "
             f"{'q':>8}"]
    lines.append("-" * len(lines[0]))
    shown = rows if len(rows) <= max_rows else (
        rows[: max_rows // 2] + [None] + rows[-max_rows // 2:])
    for r in shown:
        if r is None:
            lines.append(f"{'...':>6}")
            continue
        lines.append(
            f"{r.get('step', ''):>6} {str(r.get('server', '')):<9} "
            f"{_fmt(float(r.get('eps', float('nan')))):>11} "
            f"{_fmt(float(r.get('delta', float('nan')))):>11} "
            f"{_fmt(float(r.get('q', float('nan')))):>8}")
    return "\n".join(lines)


def sparkline(values: List[float], width: int = 60) -> str:
    vals = [v for v in values if _is_number(v) and math.isfinite(v)]
    if not vals:
        return "(no data)"
    if len(vals) > width:        # bucket-mean downsample to terminal width
        step = len(vals) / width
        vals = [sum(vals[int(i * step):max(int(i * step) + 1,
                                           int((i + 1) * step))])
                / max(1, int((i + 1) * step) - int(i * step))
                for i in range(width)]
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    return "".join(_SPARK[min(len(_SPARK) - 1,
                              int((v - lo) / span * (len(_SPARK) - 1)))]
                   for v in vals)


def tail_lines(records: List[dict], stream: Optional[str],
               n: int) -> List[str]:
    rows = [r for r in records
            if stream is None or r.get("stream") == stream]
    return [json.dumps({k: v for k, v in r.items() if k != "run"})
            for r in rows[-n:]]


def check_trace(path: Path) -> List[str]:
    """Validate a Chrome trace-event JSON file; returns error strings."""
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable trace JSON ({e})"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return [f"{path}: no traceEvents list"]
    errs = []
    for i, ev in enumerate(events):
        for key in ("name", "ph", "ts"):
            if key not in ev:
                errs.append(f"{path}: event {i} missing {key!r}")
                break
    return errs


# ---------------------------------------------------------------------------
# `inspect bench`: per-metric trends from BENCH_history.jsonl
# ---------------------------------------------------------------------------


def load_history(path: Path) -> List[dict]:
    entries: List[dict] = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return entries


# not a dispatched kernel op: `backend` here is a history-entry filter,
# not a backend= dispatch switch  # gflint: disable=GFL004
def bench_trends(entries: List[dict], *, benchmark: Optional[str] = None,
                 backend: Optional[str] = None, last: int = 30
                 ) -> Dict[Tuple[str, str], dict]:
    """(benchmark, metric) -> trend dict with the value series (history
    order), direction, and the identifying shas/backends."""
    trends: Dict[Tuple[str, str], dict] = {}
    for e in entries:
        name = e.get("benchmark", "?")
        if benchmark and name != benchmark:
            continue
        if backend and e.get("backend") != backend:
            continue
        for metric, decl in (e.get("headline") or {}).items():
            v = decl.get("value")
            if not _is_number(v):
                continue
            t = trends.setdefault((name, metric), {
                "values": [], "shas": [],
                "direction": decl.get("direction", "?"),
                "backend": e.get("backend")})
            t["values"].append(float(v))
            t["shas"].append((e.get("git_sha") or "unknown")[:9])
    for t in trends.values():
        t["values"] = t["values"][-last:]
        t["shas"] = t["shas"][-last:]
    return trends


def bench_table(trends: Dict[Tuple[str, str], dict]) -> str:
    lines = [f"{'benchmark':<22} {'metric':<26} {'dir':<6} {'n':>3} "
             f"{'first':>11} {'last':>11} {'delta%':>8}  trend"]
    lines.append("-" * len(lines[0]))
    for (name, metric), t in sorted(trends.items()):
        vals = t["values"]
        first, lastv = vals[0], vals[-1]
        delta = ("-" if first == 0 or not math.isfinite(first)
                 else f"{100.0 * (lastv - first) / abs(first):+.1f}")
        lines.append(
            f"{name:<22} {metric:<26} {t['direction']:<6} {len(vals):>3} "
            f"{_fmt(first):>11} {_fmt(lastv):>11} {delta:>8}  "
            f"{sparkline(vals, width=24)}")
    return "\n".join(lines)


def bench_main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.inspect bench",
        description="Render per-metric benchmark trends from "
                    "BENCH_history.jsonl.")
    ap.add_argument("history", type=Path, nargs="?",
                    default=Path("BENCH_history.jsonl"),
                    help="history JSONL (benchmarks/meta.write_bench "
                         "appends it)")
    ap.add_argument("--benchmark", default=None,
                    help="restrict to one benchmark")
    ap.add_argument("--backend", default=None,
                    help="restrict to one backend (cpu/tpu/gpu)")
    ap.add_argument("--last", type=int, default=30, metavar="N",
                    help="plot the last N history points (default 30)")
    args = ap.parse_args(argv)

    if not args.history.exists():
        print(f"error: {args.history} does not exist", file=sys.stderr)
        return 1
    entries = load_history(args.history)
    trends = bench_trends(entries, benchmark=args.benchmark,
                          backend=args.backend, last=args.last)
    print(f"{args.history}: {len(entries)} history entries, "
          f"{len(trends)} metric trend(s)")
    if trends:
        print()
        print(bench_table(trends))
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "bench":
        return bench_main(argv[1:])
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.inspect",
        description="Summarize a telemetry run's JSONL record stream.")
    ap.add_argument("jsonl", type=Path, help="run JSONL (JsonlSink output)")
    ap.add_argument("--stream", default=None,
                    help="restrict the summary to one stream")
    ap.add_argument("--tail", type=int, default=0, metavar="N",
                    help="also print the last N raw records")
    ap.add_argument("--trace", type=Path, default=None,
                    help="validate a Chrome trace JSON alongside")
    args = ap.parse_args(argv)

    if not args.jsonl.exists():
        print(f"error: {args.jsonl} does not exist", file=sys.stderr)
        return 1
    records, errors = load_records(args.jsonl)
    if args.stream:
        records = [r for r in records if r.get("stream") == args.stream]

    by_stream: Dict[str, int] = defaultdict(int)
    for r in records:
        by_stream[r.get("stream", "?")] += 1
    counts = ", ".join(f"{s}={n}" for s, n in sorted(by_stream.items()))
    print(f"{args.jsonl}: {len(records)} records ({counts or 'none'})")

    if records:
        print()
        print(summary_table(records))
        eps = eps_table(records)
        if eps:
            print()
            print("privacy ledger (eps vs step):")
            print(eps)
        gaps = [r["gap"] for r in records
                if r.get("stream") == "round" and "gap" in r]
        if gaps:
            print()
            print(f"spectral gap  [{_fmt(min(gaps))}, {_fmt(max(gaps))}]:")
            print("  " + sparkline(gaps))
    if args.tail:
        print()
        print(f"last {args.tail} records:")
        for line in tail_lines(records, args.stream, args.tail):
            print("  " + line)

    if args.trace is not None:
        errors.extend(check_trace(args.trace))
        if not errors:
            n_ev = len(json.loads(args.trace.read_text())["traceEvents"])
            print(f"\n{args.trace}: valid Chrome trace ({n_ev} events)")

    if errors:
        print(f"\n{len(errors)} error(s):", file=sys.stderr)
        for e in errors:
            print("  " + e, file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
