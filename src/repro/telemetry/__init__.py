"""Unified telemetry: schema'd metric streams, span tracing, run logs.

Public surface:

* :func:`emit` / :class:`MetricsStream` — record emission from host code
  and from inside jitted/scanned engine bodies (io_callback flush).
* :func:`session` / :func:`session_from_config` — open the process-wide
  telemetry session; ``telemetry=off`` (the default) is bit-identical to
  an uninstrumented run.
* :func:`trace_span` — Chrome/Perfetto span tracing of host-side phases.
* :class:`RunLog` — per-round record list engines expose their legacy
  result fields as views over.
* :class:`Schema` registry — every stream's fields, validated at emit.
* :class:`QuantileSketch` — mergeable quantile summaries (inspector).

``python -m repro.telemetry.inspect RUN.jsonl`` summarizes a run.
"""
from repro.telemetry.profile import profile_phase
from repro.telemetry.runlog import RunLog
from repro.telemetry.schema import (Field, Schema, SchemaError, get_schema,
                                    list_schemas, register_schema,
                                    validate_record)
from repro.telemetry.sinks import (ConsoleSink, CsvSink, JsonlSink,
                                   MemorySink, Sink, sink_from_spec)
from repro.telemetry.sketch import QuantileSketch
from repro.telemetry.stream import (MetricsStream, TelemetrySession,
                                    current_session, emit,
                                    flush_every_from_env, session,
                                    session_from_config, telemetry_active)
from repro.telemetry.trace import SpanTracer, trace_span
from repro.telemetry.watch import WatchRule, Watcher, parse_watch_spec

__all__ = [
    "ConsoleSink", "CsvSink", "Field", "JsonlSink", "MemorySink",
    "MetricsStream", "QuantileSketch", "RunLog", "Schema", "SchemaError",
    "Sink", "SpanTracer", "TelemetrySession", "WatchRule", "Watcher",
    "current_session", "emit", "flush_every_from_env", "get_schema",
    "list_schemas", "parse_watch_spec", "profile_phase", "register_schema",
    "session", "session_from_config", "sink_from_spec", "telemetry_active",
    "trace_span", "validate_record",
]
