"""Live run monitor: tail telemetry JSONL, evaluate alert rules.

    python -m repro.telemetry.watch RUN.jsonl --rules "eps:0.9,target=4+nan"
    python -m repro.telemetry.watch RUN.jsonl --rules nan+gap:0.05 --once

The recorder half of observability (PR 7) writes the streams; this is
the *judging* half for a run in flight: it follows the canonical JSONL
sink output and evaluates a rule set over the ``round`` / ``step`` /
``privacy`` / ``mesh`` records as they land.  Rules are a spec-string
grammar (registered as ``watch`` in :mod:`repro.core.specs`, round-trip
tested like fault/cohort/async):

``eps:FRAC[,target=EPS]``   privacy-budget exhaustion: composed ``eps``
                            >= FRAC * epsilon_target (``target=`` in the
                            rule, else ``--epsilon-target``)
``gap:MIN``                 spectral-gap collapse: ``gap`` < MIN
                            (round/mesh streams — mixing dying is the
                            paper's convergence killer)
``nan``                     any non-finite numeric in round/step/mesh
                            records (NaN/exploding trajectories; the
                            privacy stream is exempt — eps = inf is a
                            meaningful ledger state)
``norm:MAX``                exploding updates: ``update_norm`` /
                            ``grad_norm_max`` > MAX
``stale:BOUND``             staleness above the declared bound
``throughput:FRAC[,window=N]``  events-per-record drops below FRAC of
                            the trailing-window mean (default N=20)
``restart:N``               fleet churn: cumulative elastic worker
                            restarts (``fleet`` stream, core/fleet)
                            exceed N — a fleet that keeps losing workers
                            is failing even if every tick recovers

Alerts go to the console (stderr) and optionally an alerts JSONL
(``--alerts``); ``--once`` reads the whole file, prints a summary and
exits 1 iff any alert fired — the CI nightly smokes assert exit 0 over
the instrumented population/async runs.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from collections import deque
from pathlib import Path
from typing import Dict, List, NamedTuple, Optional, Tuple

# streams the nan rule scans (privacy exempt: eps=inf is meaningful)
_NAN_STREAMS = ("round", "step", "mesh")
_RULE_KINDS = ("eps", "gap", "nan", "norm", "stale", "throughput",
               "restart")


class WatchRule(NamedTuple):
    """One alert rule: a kind plus its (sorted, canonical) parameters."""
    kind: str
    params: Tuple[Tuple[str, float], ...] = ()

    def param(self, name: str, default=None):
        for k, v in self.params:
            if k == name:
                return v
        return default

    def to_spec(self) -> str:
        primary = {"eps": "frac", "gap": "min", "norm": "max",
                   "stale": "bound", "throughput": "frac",
                   "restart": "max"}.get(self.kind)
        head = self.kind
        rest = []
        for k, v in self.params:
            if k == primary:
                head = f"{self.kind}:{_fmt_num(v)}"
            else:
                rest.append(f"{k}={_fmt_num(v)}")
        return ",".join([head] + sorted(rest))


def _fmt_num(v: float) -> str:
    if float(v) == int(v):
        return str(int(v))
    return format(float(v), "g")


def parse_watch_spec(spec: str) -> Tuple[WatchRule, ...]:
    """``+``-separated watch rules -> canonical :class:`WatchRule` tuple.

    Grammar: ``kind[:value][,key=value,...]`` per rule; see the module
    docstring for the rule kinds.  Raises ``ValueError`` on unknown
    kinds, missing required values, or unknown parameters.
    """
    rules: List[WatchRule] = []
    for part in (p.strip() for p in spec.split("+")):
        if not part:
            continue
        head, *kvs = part.split(",")
        kind, _, value = head.partition(":")
        if kind not in _RULE_KINDS:
            raise ValueError(f"unknown watch rule {kind!r}; expected one "
                             f"of {_RULE_KINDS}")
        primary = {"eps": "frac", "gap": "min", "norm": "max",
                   "stale": "bound", "throughput": "frac",
                   "restart": "max"}.get(kind)
        params: Dict[str, float] = {}
        if value:
            if primary is None:
                raise ValueError(f"watch rule {kind!r} takes no value")
            params[primary] = float(value)
        elif primary is not None:
            raise ValueError(f"watch rule {kind!r} needs a value "
                             f"({kind}:<{primary}>)")
        allowed_kw = {"eps": ("target",),
                      "throughput": ("window",)}.get(kind, ())
        for kv in kvs:
            k, eq, v = kv.partition("=")
            if not eq or k not in allowed_kw:
                raise ValueError(f"watch rule {kind!r} does not take "
                                 f"parameter {kv!r}")
            params[k] = float(v)
        if kind == "throughput":
            params.setdefault("window", 20.0)
        rules.append(WatchRule(kind, tuple(sorted(params.items()))))
    if not rules:
        raise ValueError("empty watch spec")
    return tuple(rules)


def watch_to_spec(rules: Tuple[WatchRule, ...]) -> str:
    return "+".join(r.to_spec() for r in rules)


class Watcher:
    """Evaluates a watch rule set over a stream of enveloped records."""

    def __init__(self, rules: Tuple[WatchRule, ...],
                 epsilon_target: Optional[float] = None):
        self.rules = tuple(rules)
        self.epsilon_target = epsilon_target
        self.alerts: List[dict] = []
        self.records_seen = 0
        windows = [int(r.param("window", 20)) for r in self.rules
                   if r.kind == "throughput"]
        self._events: deque = deque(maxlen=max(windows) if windows else 1)

    # -- per-rule predicates -------------------------------------------

    def _check_eps(self, rule, rec) -> Optional[dict]:
        if rec.get("stream") != "privacy":
            return None
        eps = rec.get("eps")
        target = rule.param("target", self.epsilon_target)
        if (not _num(eps) or not math.isfinite(eps)
                or target is None or not math.isfinite(target)):
            return None
        frac = rule.param("frac")
        if eps >= frac * target:
            return {"message": f"eps_spent {eps:.4g} >= {frac:g} * "
                               f"epsilon_target {target:g}",
                    "value": eps}
        return None

    def _check_gap(self, rule, rec) -> Optional[dict]:
        if rec.get("stream") not in ("round", "mesh"):
            return None
        gap = rec.get("gap")
        if _num(gap) and math.isfinite(gap) and gap < rule.param("min"):
            return {"message": f"spectral gap {gap:.4g} < collapse "
                               f"threshold {rule.param('min'):g}",
                    "value": gap}
        return None

    def _check_nan(self, rule, rec) -> Optional[dict]:
        if rec.get("stream") not in _NAN_STREAMS:
            return None
        for k, v in rec.items():
            if k in ("stream", "run", "t_wall"):
                continue
            vals = v if isinstance(v, list) else [v]
            for item in vals:
                if _num(item) and not math.isfinite(item):
                    return {"message": f"non-finite {k} = {item!r}",
                            "value": item}
        return None

    def _check_norm(self, rule, rec) -> Optional[dict]:
        bound = rule.param("max")
        for field in ("update_norm", "grad_norm_max"):
            v = rec.get(field)
            if _num(v) and math.isfinite(v) and v > bound:
                return {"message": f"{field} {v:.4g} > {bound:g} "
                                   f"(exploding update)",
                        "value": v}
        return None

    def _check_stale(self, rule, rec) -> Optional[dict]:
        bound = rule.param("bound")
        v = rec.get("staleness")
        vals = v if isinstance(v, list) else [v]
        worst = max((x for x in vals if _num(x) and math.isfinite(x)),
                    default=None)
        if worst is not None and worst > bound:
            return {"message": f"staleness {worst:.4g} > declared bound "
                               f"{bound:g}",
                    "value": worst}
        return None

    def _check_restart(self, rule, rec) -> Optional[dict]:
        if rec.get("stream") != "fleet":
            return None
        v = rec.get("restarts")
        bound = rule.param("max")
        if _num(v) and v > bound:
            return {"message": f"fleet restarts {v:g} > {bound:g} "
                               f"(worker churn)",
                    "value": v}
        return None

    def _check_throughput(self, rule, rec) -> Optional[dict]:
        v = _events_value(rec)
        if v is None:
            return None
        window = int(rule.param("window", 20))
        if len(self._events) < window:
            return None
        trailing = list(self._events)[-window:]
        mean = sum(trailing) / len(trailing)
        frac = rule.param("frac")
        if mean > 0 and v < frac * mean:
            return {"message": f"events {v:.4g} < {frac:g} * trailing-"
                               f"{window} mean {mean:.4g} "
                               f"(throughput drop)",
                    "value": v}
        return None

    # -- record feed ----------------------------------------------------

    def feed(self, rec: dict) -> List[dict]:
        """Evaluate every rule against one record; returns (and retains)
        the alerts it fired."""
        self.records_seen += 1
        fired = []
        checks = {"eps": self._check_eps, "gap": self._check_gap,
                  "nan": self._check_nan, "norm": self._check_norm,
                  "stale": self._check_stale,
                  "throughput": self._check_throughput,
                  "restart": self._check_restart}
        for rule in self.rules:
            hit = checks[rule.kind](rule, rec)
            if hit is not None:
                schema_index = {"round": "round",
                                "fleet": "tick"}.get(rec.get("stream"),
                                                     "step")
                fired.append({"rule": rule.to_spec(),
                              "stream": rec.get("stream"),
                              "index": rec.get(schema_index),
                              **hit})
        ev = _events_value(rec)      # trailing window fed once per record
        if ev is not None:
            self._events.append(float(ev))
        self.alerts.extend(fired)
        return fired


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _events_value(rec: dict) -> Optional[float]:
    """The throughput proxy of one ``step`` record: events folded this
    tick (series records sum across servers)."""
    if rec.get("stream") != "step":
        return None
    v = rec.get("events")
    if isinstance(v, list):
        v = sum(x for x in v if _num(x))
    return float(v) if _num(v) else None


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _iter_jsonl_lines(path: Path, *, follow: bool, interval: float,
                      max_seconds: Optional[float]):
    """Yield parsed records; in follow mode, poll for appended lines."""
    t0 = time.monotonic()
    with open(path, encoding="utf-8") as fh:
        while True:
            line = fh.readline()
            if line:
                line = line.strip()
                if line:
                    try:
                        yield json.loads(line)
                    except json.JSONDecodeError:
                        print(f"watch: skipping malformed line: "
                              f"{line[:80]}", file=sys.stderr)
                continue
            if not follow:
                return
            if (max_seconds is not None
                    and time.monotonic() - t0 > max_seconds):
                return
            time.sleep(interval)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.watch",
        description="Tail a telemetry JSONL and evaluate alert rules.")
    ap.add_argument("jsonl", type=Path, help="run JSONL (JsonlSink output)")
    ap.add_argument("--rules", default="nan",
                    help="watch rule spec (default: nan); see "
                         "docs/observability.md for the grammar")
    ap.add_argument("--epsilon-target", type=float, default=None,
                    help="epsilon_target for eps: rules without target=")
    ap.add_argument("--alerts", type=Path, default=None,
                    help="also append alerts to this JSONL file")
    ap.add_argument("--once", action="store_true",
                    help="read the whole file once; exit 1 iff any "
                         "alert fired (CI gate mode)")
    ap.add_argument("--interval", type=float, default=0.5,
                    help="poll interval in follow mode (seconds)")
    ap.add_argument("--max-seconds", type=float, default=None,
                    help="stop following after this many seconds")
    args = ap.parse_args(argv)

    try:
        rules = parse_watch_spec(args.rules)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if not args.jsonl.exists():
        print(f"error: {args.jsonl} does not exist", file=sys.stderr)
        return 2

    watcher = Watcher(rules, epsilon_target=args.epsilon_target)
    alerts_fh = None
    if args.alerts is not None:
        args.alerts.parent.mkdir(parents=True, exist_ok=True)
        alerts_fh = open(args.alerts, "a", encoding="utf-8")
    try:
        for rec in _iter_jsonl_lines(args.jsonl, follow=not args.once,
                                     interval=args.interval,
                                     max_seconds=args.max_seconds):
            for alert in watcher.feed(rec):
                line = (f"ALERT [{alert['rule']}] {alert['stream']}"
                        f"@{alert['index']}: {alert['message']}")
                print(line, file=sys.stderr)
                if alerts_fh is not None:
                    alerts_fh.write(json.dumps(alert) + "\n")
                    alerts_fh.flush()
    except KeyboardInterrupt:
        pass
    finally:
        if alerts_fh is not None:
            alerts_fh.close()

    n = len(watcher.alerts)
    print(f"{args.jsonl}: {watcher.records_seen} records, {n} alert(s) "
          f"[{watch_to_spec(rules)}]")
    return 1 if (args.once and n) else 0


if __name__ == "__main__":
    raise SystemExit(main())
