"""Telemetry schema registry: every metrics stream declares its fields.

A :class:`Schema` names one record stream (``round``, ``step``,
``privacy``, ``kernel``, ``mesh``) and the fields records of that stream
may carry.  Emission validates against the registry at the emit site —
at *trace* time for in-graph taps, so a typo'd field name fails loudly
the first time the instrumented program is traced rather than producing
a silently malformed JSONL — and the inspector CLI validates again on
read, so a run's record stream is self-describing end to end
(docs/observability.md has the full schema table).

Field kinds:

``scalar``   one float (jnp/np scalars accepted, serialized as float)
``int``      one integer (counters, indices; bools serialize as 0/1)
``str``      a short tag (engine name, op name, backend)
``series``   a small 1-D array (per-server vectors), serialized as a list

Every stream declares exactly one required ``index`` field (the round /
tick / step the record belongs to); all other fields are optional so the
three engines can share one ``round`` schema while emitting only what
their execution mode realizes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Tuple

KINDS = ("scalar", "int", "str", "series")


class SchemaError(ValueError):
    """A record does not match its stream's registered schema."""


@dataclass(frozen=True)
class Field:
    name: str
    kind: str = "scalar"
    description: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise SchemaError(f"unknown field kind {self.kind!r} for "
                              f"{self.name!r}; expected one of {KINDS}")


@dataclass(frozen=True)
class Schema:
    """One record stream: a name, an index field and the allowed fields."""
    stream: str
    index: str                  # required per-record position field
    fields: Tuple[Field, ...]
    description: str = ""

    def field_map(self) -> Dict[str, Field]:
        return {f.name: f for f in self.fields}

    def validate(self, record: Mapping) -> None:
        """Raise :class:`SchemaError` on unknown fields or a missing
        index.  Values are NOT type-coerced here — in-graph emission
        validates keys at trace time when values are still tracers."""
        allowed = self.field_map()
        for key in record:
            if key not in allowed:
                raise SchemaError(
                    f"stream {self.stream!r} has no field {key!r}; "
                    f"registered fields: {sorted(allowed)}")
        if self.index not in record:
            raise SchemaError(f"stream {self.stream!r} record is missing "
                              f"its index field {self.index!r}")


_REGISTRY: Dict[str, Schema] = {}


def register_schema(schema: Schema) -> Schema:
    """Register (or deliberately replace) a stream schema."""
    _REGISTRY[schema.stream] = schema
    return schema


def get_schema(stream: str) -> Schema:
    try:
        return _REGISTRY[stream]
    except KeyError:
        raise SchemaError(f"unknown telemetry stream {stream!r}; "
                          f"registered: {sorted(_REGISTRY)}") from None


def list_schemas() -> Dict[str, Schema]:
    return dict(_REGISTRY)


def validate_record(stream: str, record: Mapping) -> None:
    get_schema(stream).validate(record)


# ---------------------------------------------------------------------------
# built-in streams (the schema table in docs/observability.md)
# ---------------------------------------------------------------------------

register_schema(Schema(
    "round", index="round", description=(
        "per-round executor record, one per protocol round/tick "
        "(host-side; all three engines emit it)"),
    fields=(
        Field("round", "int", "protocol round / tick index"),
        Field("engine", "str", "dense | population | async"),
        Field("msd", "scalar", "centroid MSD vs w_ref"),
        Field("q", "scalar", "realized cohort sampling rate"),
        Field("cohort", "int", "sampled cohort size L (events folded E)"),
        Field("gap", "scalar", "realized spectral gap of A_i"),
        Field("staleness", "series", "per-server staleness (psi age / "
                                     "mean folded age)"),
        Field("grad_norm_mean", "scalar", "mean clipped grad norm"),
        Field("grad_norm_max", "scalar", "max clipped grad norm"),
        Field("fold_mass", "scalar", "total fold-weight mass this round"),
        Field("flushed", "series", "per-server flush indicator"),
        Field("events", "series", "per-server valid arrivals folded"),
        Field("dropped_stale", "series", "per-server over-stale refusals"),
        Field("buffer", "series", "per-server buffer occupancy"),
        Field("q_server", "series", "per-server realized flush q"),
    )))

register_schema(Schema(
    "step", index="step", description=(
        "in-graph per-step tap flushed via io_callback from inside "
        "jitted/scanned engine bodies (read-only; absent when "
        "telemetry is off)"),
    fields=(
        Field("step", "int", "engine step counter"),
        Field("msd", "scalar", "centroid MSD vs w_ref"),
        Field("update_norm", "scalar", "||params_new - params_old||"),
        Field("param_norm", "scalar", "||params_new||"),
        Field("flushed", "int", "servers flushed this tick"),
        Field("events", "int", "valid arrivals folded this tick"),
        Field("events_total", "scalar", "cumulative arrivals folded "
                                        "(MetricsStream carry)"),
        Field("dropped", "int", "over-stale arrivals refused"),
        Field("staleness", "scalar", "mean folded age"),
        Field("fold_mass", "scalar", "total fold-weight mass"),
    )))

register_schema(Schema(
    "privacy", index="step", description=(
        "one record per accountant release charge "
        "(PrivacyAccountant.advance)"),
    fields=(
        Field("step", "int", "ledger step (releases charged so far)"),
        Field("eps", "scalar", "composed epsilon (unamplified curve)"),
        Field("eps_release", "scalar", "this release's epsilon"),
        Field("eps_release_amp", "scalar",
              "this release's subsampling-amplified epsilon"),
        Field("delta", "scalar", "composed delta spent"),
        Field("q", "scalar", "realized sampling rate of this release"),
        Field("curve", "str", "accountant curve"),
        Field("server", "str", "owning ledger tag ('' = scalar ledger)"),
    )))

register_schema(Schema(
    "kernel", index="seq", description=(
        "kernel-dispatch record: backend chosen, block_d autotune "
        "decision, analytic HBM traffic (emitted host-side at trace "
        "time, once per (op, shape))"),
    fields=(
        Field("seq", "int", "dispatch sequence number"),
        Field("op", "str", "kernel op name"),
        Field("backend", "str", "pallas | ref"),
        Field("block_d", "int", "chosen model-dim block"),
        Field("d_pad", "int", "padded model dim"),
        Field("interpret", "int", "1 when running in interpret mode"),
        Field("autotuned", "int", "1 when candidates were timed"),
        Field("mode", "str", "client noise mode (round_fold)"),
        Field("hbm_bytes", "scalar", "analytic fused HBM bytes "
                                     "(roofline.round_pipeline_traffic)"),
        Field("hbm_bytes_ref", "scalar", "analytic reference-chain bytes"),
        Field("pld_passes", "int", "gradient-scale HBM round trips"),
    )))

register_schema(Schema(
    "mesh", index="step", description="mesh trainer per-step record "
                                      "(launch/train.py)",
    fields=(
        Field("step", "int", "training step"),
        Field("loss", "scalar", "mean training loss"),
        Field("seconds", "scalar", "wall-clock seconds since t0"),
        Field("gap", "scalar", "realized spectral gap (fault runs)"),
    )))

register_schema(Schema(
    "fleet", index="tick", description=(
        "per-tick fleet coordinator record (core/fleet): worker "
        "liveness, delivery retries, elastic restarts and transport "
        "replay lag — the resilience counters of a multi-process run"),
    fields=(
        Field("tick", "int", "coordinator dispatch tick"),
        Field("heartbeat_age", "series", "per-server seconds since the "
                                         "last heartbeat"),
        Field("retries", "int", "cumulative send/collect retries"),
        Field("restarts", "int", "cumulative elastic worker restarts"),
        Field("replay_lag", "int", "coordinator transport backlog "
                                   "(records logged/queued but unread)"),
        Field("down", "series", "per-server down indicator this tick"),
        Field("flushes", "int", "servers that flushed this tick"),
        Field("msd", "scalar", "centroid MSD vs w_ref"),
    )))

register_schema(Schema(
    "profile", index="seq", description=(
        "phase-level profiler record (telemetry/profile.py): wall time "
        "attributed to compile vs execute vs host callbacks per "
        "trace_span phase, jit retrace/recompile counters, device "
        "memory watermark"),
    fields=(
        Field("seq", "int", "profiler sequence number (session-monotone)"),
        Field("phase", "str", "trace_span phase name"),
        Field("wall_s", "scalar", "phase wall-clock seconds"),
        Field("compile_s", "scalar", "jaxpr trace + lowering + backend "
                                     "compile seconds inside the phase"),
        Field("execute_s", "scalar", "wall minus compile minus callback "
                                     "(device execute + host driver)"),
        Field("callback_s", "scalar", "host seconds inside telemetry "
                                      "io_callback flushes"),
        Field("retraces", "int", "jaxpr traces started inside the phase"),
        Field("compiles", "int", "XLA backend compiles inside the phase"),
        Field("peak_bytes", "scalar", "device peak_bytes_in_use after the "
                                      "phase (absent when the backend has "
                                      "no memory_stats)"),
    )))
