"""Deterministic mergeable quantile sketch (KLL-style).

The inspector summarizes per-metric p50/p99 across arbitrarily long
JSONL streams without holding every value; shards of a run (or several
runs) merge associatively.  The classic KLL compactor discards odd- or
even-indexed items by coin flip; here the coin is a per-level toggle, so
the sketch is fully deterministic — same inputs (in the same order) give
the same summary, which keeps tests and BENCH comparisons reproducible.
The price is a deterministic (rather than randomized) rank error, still
bounded by the compaction weights: each level-``i`` compaction moves at
most ``k/2`` items of weight ``2**i``, and a level is compacted at most
once per promotion, so the absolute rank error after ``n`` inserts is
``O((n/k) * log2(n/k))`` — tests/test_telemetry.py checks the realized
error against ``numpy.percentile`` on adversarial inputs.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple


class QuantileSketch:
    """Mergeable quantile summary over streamed floats.

    ``k`` is the per-level compactor capacity: bigger k, lower rank
    error, more memory (total memory is O(k log(n/k))).
    """

    def __init__(self, k: int = 128):
        if k < 4:
            raise ValueError("k must be >= 4")
        self.k = int(k)
        self._levels: List[List[float]] = [[]]
        self._coins: List[bool] = [False]
        self.count = 0
        self._min = float("inf")
        self._max = float("-inf")

    # -- ingestion ---------------------------------------------------------

    def add(self, value: float) -> None:
        v = float(value)
        self.count += 1
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v
        self._levels[0].append(v)
        if len(self._levels[0]) >= self.k:
            self._compact()

    def extend(self, values: Iterable[float]) -> None:
        for v in values:
            self.add(v)

    def _compact(self) -> None:
        for lvl in range(len(self._levels)):
            buf = self._levels[lvl]
            if len(buf) < self.k:
                continue
            buf.sort()
            # deterministic coin: alternate keeping odd/even-indexed items
            start = 1 if self._coins[lvl] else 0
            self._coins[lvl] = not self._coins[lvl]
            promoted = buf[start::2]
            self._levels[lvl] = []
            if lvl + 1 == len(self._levels):
                self._levels.append([])
                self._coins.append(False)
            self._levels[lvl + 1].extend(promoted)

    # -- merging -----------------------------------------------------------

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold ``other`` into this sketch (in place; also returned)."""
        if other.count == 0:
            return self
        while len(self._levels) < len(other._levels):
            self._levels.append([])
            self._coins.append(False)
        for lvl, buf in enumerate(other._levels):
            self._levels[lvl].extend(buf)
        self.count += other.count
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)
        # restore capacity invariant bottom-up
        changed = True
        while changed:
            changed = False
            for lvl in range(len(self._levels)):
                if len(self._levels[lvl]) >= self.k:
                    self._compact()
                    changed = True
                    break
        return self

    # -- queries -----------------------------------------------------------

    def _weighted(self) -> List[Tuple[float, int]]:
        items: List[Tuple[float, int]] = []
        for lvl, buf in enumerate(self._levels):
            w = 1 << lvl
            items.extend((v, w) for v in buf)
        items.sort(key=lambda t: t[0])
        return items

    def quantile(self, q: float) -> float:
        """Approximate q-quantile, q in [0, 1]."""
        if self.count == 0:
            raise ValueError("empty sketch")
        if q <= 0.0:
            return self._min
        if q >= 1.0:
            return self._max
        items = self._weighted()
        total = sum(w for _, w in items)
        target = q * total
        acc = 0
        for v, w in items:
            acc += w
            if acc >= target:
                return v
        return items[-1][0]

    def quantiles(self, qs: Sequence[float]) -> List[float]:
        return [self.quantile(q) for q in qs]

    @property
    def min(self) -> float:
        return self._min

    @property
    def max(self) -> float:
        return self._max

    # -- (de)serialization -------------------------------------------------

    def to_dict(self) -> Dict:
        return {"k": self.k, "count": self.count,
                "min": self._min, "max": self._max,
                "levels": [list(b) for b in self._levels],
                "coins": list(self._coins)}

    @classmethod
    def from_dict(cls, d: Dict) -> "QuantileSketch":
        s = cls(k=d["k"])
        s.count = int(d["count"])
        s._min = float(d["min"])
        s._max = float(d["max"])
        s._levels = [list(map(float, b)) for b in d["levels"]]
        s._coins = [bool(c) for c in d["coins"]]
        return s
