"""Mamba2 (SSD) blocks — chunked training path + O(1)-state decode path.

The training/prefill path uses the chunked SSD algorithm (intra-chunk masked
matmul on the MXU + inter-chunk scan over chunk states), not a length-S scan:
this is the TPU adaptation of Mamba2's block-decomposition, keeping the MXU
busy with [Q,Q] and [Q,N] matmuls instead of length-4096 elementwise scans.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import he_init


def ssm_dims(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.headdim
    return d_inner, nheads, s.state_dim, s.ngroups


def mamba2_init(key, cfg: ModelConfig, dtype):
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_inner, H, N, G = ssm_dims(cfg)
    conv_ch = d_inner + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "w_in": he_init(ks[0], (d, 2 * d_inner + 2 * G * N + H), dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_dim, conv_ch)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_out": he_init(ks[2], (d_inner, d), dtype, fan_in=d_inner),
    }


def _split_proj(proj, cfg: ModelConfig):
    d_inner, H, N, G = ssm_dims(cfg)
    z, xc, Bm, Cm, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + G * N,
               2 * d_inner + 2 * G * N], axis=-1)
    return z, xc, Bm, Cm, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: [B,S,C]; w: [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _gated_norm(y, z, scale, eps=1e-5):
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + eps)
    return (yf * scale.astype(jnp.float32)).astype(y.dtype)


def mamba2_forward(params, x, cfg: ModelConfig, h_init=None):
    """Chunked SSD. x: [B,S,D] -> ([B,S,D], state dict {h, conv}).

    The returned state seeds :func:`mamba2_decode` after a prefill."""
    s: SSMConfig = cfg.ssm
    d_inner, H, N, G = ssm_dims(cfg)
    P = s.headdim
    B_, S, _ = x.shape
    Q = min(s.chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    z, xc, Bm, Cm, dt = _split_proj(x @ params["w_in"], cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    K = s.conv_dim
    conv_tail = jnp.pad(conv_in, ((0, 0), (K - 1, 0), (0, 0)))[:, S:, :] \
        if S < K - 1 else conv_in[:, S - (K - 1):, :]
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"]))
    xc = conv_out[..., :d_inner].reshape(B_, S, H, P)
    Bm = conv_out[..., d_inner:d_inner + G * N].reshape(B_, S, G, N)
    Cm = conv_out[..., d_inner + G * N:].reshape(B_, S, G, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(params["A_log"])                                  # [H]
    loga = dt * A                                                  # log decay

    # reshape into chunks
    def chunked(t, shape):
        return t.reshape(B_, nc, Q, *shape)

    xc_c = chunked(xc, (H, P))
    B_c = chunked(Bm, (G, N))
    C_c = chunked(Cm, (G, N))
    dt_c = chunked(dt, (H,))
    la_c = chunked(loga, (H,))

    # head -> group map
    rep = H // G
    B_h = jnp.repeat(B_c, rep, axis=3) if G > 1 else jnp.broadcast_to(
        B_c, (B_, nc, Q, H, N)) if G == 1 else B_c
    C_h = jnp.repeat(C_c, rep, axis=3) if G > 1 else jnp.broadcast_to(
        C_c, (B_, nc, Q, H, N))

    L = jnp.cumsum(la_c, axis=2)                                  # [B,nc,Q,H]
    Ltot = L[:, :, -1, :]                                         # [B,nc,H]

    # intra-chunk: M[t,s] = exp(L_t - L_s) (C_t . B_s) dt_s  for s<=t
    CB = jnp.einsum("bcqhn,bcshn->bchqs", C_h.astype(jnp.float32),
                    B_h.astype(jnp.float32))
    dL = L[:, :, :, None, :].transpose(0, 1, 4, 2, 3) \
        - L[:, :, None, :, :].transpose(0, 1, 4, 2, 3)            # [B,nc,H,Q(t),Q(s)]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(mask, jnp.exp(dL) * CB, 0.0)
    M = M * dt_c.transpose(0, 1, 3, 2)[:, :, :, None, :]          # dt_s
    Y_intra = jnp.einsum("bchqs,bcshp->bcqhp", M, xc_c.astype(jnp.float32))

    # chunk input-to-state:  H_c = sum_s exp(Ltot - L_s) dt_s x_s (x) B_s
    w_s = jnp.exp(Ltot[:, :, None, :] - L) * dt_c                 # [B,nc,Q,H]
    chunk_states = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn",
                              w_s, xc_c.astype(jnp.float32),
                              B_h.astype(jnp.float32))

    # inter-chunk scan over chunk states
    if h_init is None:
        h_init = jnp.zeros((B_, H, P, N), jnp.float32)
    decay_tot = jnp.exp(Ltot)                                     # [B,nc,H]

    def scan_fn(h, inp):
        st, dtot = inp
        h_out = h                                                 # state BEFORE chunk
        h = dtot[:, :, None, None] * h + st
        return h, h_out

    _, h_befores = jax.lax.scan(
        scan_fn, h_init,
        (chunk_states.transpose(1, 0, 2, 3, 4),
         decay_tot.transpose(1, 0, 2)))
    h_befores = h_befores.transpose(1, 0, 2, 3, 4)                # [B,nc,H,P,N]
    h_final = decay_tot[:, -1, :, None, None] * h_befores[:, -1] \
        + chunk_states[:, -1]

    # inter-chunk contribution: y_t += C_t . (exp(L_t) h_before)
    Y_inter = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                         jnp.exp(L), C_h.astype(jnp.float32), h_befores)

    Y = (Y_intra + Y_inter).reshape(B_, S, H, P)
    Y = Y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xc.astype(jnp.float32)
    Y = Y.astype(x.dtype).reshape(B_, S, d_inner)
    out = _gated_norm(Y, z, params["norm_scale"])
    return out @ params["w_out"], {"h": h_final, "conv": conv_tail}


def mamba2_init_cache(cfg: ModelConfig, batch: int, n_layers: int, dtype):
    s: SSMConfig = cfg.ssm
    d_inner, H, N, G = ssm_dims(cfg)
    conv_ch = d_inner + 2 * G * N
    return {
        "h": jnp.zeros((n_layers, batch, H, s.headdim, N), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, s.conv_dim - 1, conv_ch), dtype),
    }


def mamba2_decode(params, x, h_state, conv_state, cfg: ModelConfig):
    """Single-token step. x: [B,1,D]; h_state: [B,H,P,N];
    conv_state: [B,K-1,C]. Returns (out, h_state, conv_state)."""
    s: SSMConfig = cfg.ssm
    d_inner, H, N, G = ssm_dims(cfg)
    P = s.headdim
    B_ = x.shape[0]

    z, xc, Bm, Cm, dt = _split_proj(x @ params["w_in"], cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)              # [B,1,C]
    window = jnp.concatenate([conv_state, conv_in], axis=1)      # [B,K,C]
    conv_out = jax.nn.silu(
        jnp.sum(window * params["conv_w"][None], axis=1) + params["conv_b"])
    new_conv_state = window[:, 1:, :]

    xc = conv_out[..., :d_inner].reshape(B_, H, P)
    Bm = conv_out[..., d_inner:d_inner + G * N].reshape(B_, G, N)
    Cm = conv_out[..., d_inner + G * N:].reshape(B_, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))  # [B,H]
    decay = jnp.exp(dtv * -jnp.exp(params["A_log"]))              # [B,H]

    upd = jnp.einsum("bh,bhp,bhn->bhpn", dtv, xc.astype(jnp.float32),
                     Bh.astype(jnp.float32))
    h_new = decay[:, :, None, None] * h_state + upd
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), h_new)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xc.astype(jnp.float32)
    y = y.astype(x.dtype).reshape(B_, 1, d_inner)
    out = _gated_norm(y, z, params["norm_scale"])
    return out @ params["w_out"], h_new, new_conv_state
