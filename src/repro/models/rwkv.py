"""RWKV-6 (Finch) blocks: data-dependent-decay linear attention.

Time mixing implements the wkv6 recurrence with per-channel data-dependent
decay w_t and bonus u; channel mixing is the squared-ReLU token-shifted FFN.
Training runs a lax.scan over time (state [B,H,hs,hs] carried); decode is a
single recurrence step — O(1) state, which is why rwkv6 runs `long_500k`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RWKVConfig
from repro.models.layers import he_init


def rwkv_dims(cfg: ModelConfig):
    r: RWKVConfig = cfg.rwkv
    return cfg.d_model // r.head_size, r.head_size


def rwkv6_att_init(key, cfg: ModelConfig, dtype):
    r: RWKVConfig = cfg.rwkv
    d = cfg.d_model
    H, hs = rwkv_dims(cfg)
    ks = jax.random.split(key, 10)
    return {
        # token-shift mixing coefficients (5 interpolators + base)
        "mu_x": jnp.full((d,), 0.5, dtype),
        "mu": jnp.full((5, d), 0.5, dtype),          # w,k,v,r,g
        "maa_w1": he_init(ks[0], (d, 5 * r.mix_lora), dtype),
        "maa_w2": (jax.random.normal(ks[1], (5, r.mix_lora, d)) * 0.01
                   ).astype(dtype),
        # decay lora: w = exp(-exp(w0 + tanh(xw @ d1) @ d2))
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "decay_w1": he_init(ks[2], (d, r.decay_lora), dtype),
        "decay_w2": (jax.random.normal(ks[3], (r.decay_lora, d)) * 0.01
                     ).astype(dtype),
        "u": (jax.random.normal(ks[4], (d,)) * 0.1).astype(jnp.float32),
        "w_r": he_init(ks[5], (d, d), dtype),
        "w_k": he_init(ks[6], (d, d), dtype),
        "w_v": he_init(ks[7], (d, d), dtype),
        "w_g": he_init(ks[8], (d, d), dtype),
        "w_o": he_init(ks[9], (d, d), dtype),
        "ln_scale": jnp.ones((d,), dtype),           # per-head group norm
    }


def rwkv6_ffn_init(key, cfg: ModelConfig, dtype):
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "w_k": he_init(ks[0], (d, dff), dtype),
        "w_v": he_init(ks[1], (dff, d), dtype, fan_in=dff),
        "w_r": he_init(ks[2], (d, d), dtype),
    }


def _token_shift(x, prev):
    """Return x_{t-1} sequence; prev is the carry for position 0. x: [B,S,D]."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix_inputs(params, x, sx):
    """Data-dependent token-shift interpolation -> (xw,xk,xv,xr,xg)."""
    dx = sx - x
    xxx = x + dx * params["mu_x"]
    lora = jnp.tanh(xxx @ params["maa_w1"])
    B_, S, _ = x.shape
    lora = lora.reshape(B_, S, 5, -1)
    deltas = jnp.einsum("bsfl,fld->fbsd", lora, params["maa_w2"])
    mixed = [x + dx * (params["mu"][f] + deltas[f]) for f in range(5)]
    return mixed  # w,k,v,r,g


def _decay(params, xw):
    lo = jnp.tanh(xw @ params["decay_w1"]) @ params["decay_w2"]
    return jnp.exp(-jnp.exp(params["w0"] + lo.astype(jnp.float32)))  # in (0,1)


def _group_norm(y, scale, H, eps=1e-5):
    """Per-head layer norm. y: [B,S,H,hs] flattened last two dims on exit."""
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + eps)
    B_, S = y.shape[:2]
    out = yf.reshape(B_, S, -1) * scale.astype(jnp.float32)
    return out


def rwkv6_att_forward(params, x, cfg: ModelConfig, state=None, prev_x=None):
    """Time mixing. x: [B,S,D]. Returns (out, (state, last_x))."""
    H, hs = rwkv_dims(cfg)
    B_, S, d = x.shape
    if prev_x is None:
        prev_x = jnp.zeros((B_, d), x.dtype)
    sx = _token_shift(x, prev_x)
    xw, xk, xv, xr, xg = _mix_inputs(params, x, sx)

    r = (xr @ params["w_r"]).reshape(B_, S, H, hs).astype(jnp.float32)
    k = (xk @ params["w_k"]).reshape(B_, S, H, hs).astype(jnp.float32)
    v = (xv @ params["w_v"]).reshape(B_, S, H, hs).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["w_g"])
    w = _decay(params, xw).reshape(B_, S, H, hs)               # [B,S,H,hs]
    u = params["u"].reshape(H, hs)

    if state is None:
        state = jnp.zeros((B_, H, hs, hs), jnp.float32)

    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp                               # [B,H,hs]
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S_ + u[None, :, :, None] * kv)
        S_ = w_t[:, :, :, None] * S_ + kv
        return S_, y

    seq = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
           v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state, seq)
    y = ys.transpose(1, 0, 2, 3)                               # [B,S,H,hs]
    y = _group_norm(y, params["ln_scale"], H).astype(x.dtype)
    out = (y * g) @ params["w_o"]
    return out, (state, x[:, -1, :])


def rwkv6_ffn_forward(params, x, prev_x=None):
    """Channel mixing. x: [B,S,D]."""
    B_, S, d = x.shape
    if prev_x is None:
        prev_x = jnp.zeros((B_, d), x.dtype)
    sx = _token_shift(x, prev_x)
    dx = sx - x
    xk = x + dx * params["mu_k"]
    xr = x + dx * params["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    return jax.nn.sigmoid(xr @ params["w_r"]) * (k @ params["w_v"]), x[:, -1, :]


def rwkv6_init_cache(cfg: ModelConfig, batch: int, n_layers: int, dtype):
    H, hs = rwkv_dims(cfg)
    d = cfg.d_model
    return {
        "wkv": jnp.zeros((n_layers, batch, H, hs, hs), jnp.float32),
        "att_x": jnp.zeros((n_layers, batch, d), dtype),
        "ffn_x": jnp.zeros((n_layers, batch, d), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
