"""Shared neural-net building blocks (pure-functional, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def he_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def rms_norm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rms_norm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layer_norm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layer_norm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]                        # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": he_init(k1, (d_model, d_ff), dtype),
        "w_up": he_init(k2, (d_model, d_ff), dtype),
        "w_down": he_init(k3, (d_ff, d_model), dtype, fan_in=d_ff),
    }


def swiglu(params, x):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


def gelu_mlp_init(key, d_model, d_ff, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": he_init(k1, (d_model, d_ff), dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": he_init(k2, (d_ff, d_model), dtype, fan_in=d_ff),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x):
    h = jax.nn.gelu(x @ params["w_in"] + params["b_in"])
    return h @ params["w_out"] + params["b_out"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_init(key, vocab, d_model, dtype):
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x):
    """Tied unembedding from the embed table."""
    return x @ params["table"].T


def lm_head_init(key, d_model, vocab, dtype):
    return {"w": he_init(key, (d_model, vocab), dtype)}


def lm_head(params, x):
    return x @ params["w"]


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy. logits: [..., V], labels: [...] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
