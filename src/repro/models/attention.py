"""Attention variants: GQA (optional sliding window) and MLA (DeepSeek/MiniCPM).

Prefill uses query-chunked attention so the [S, S] score matrix is never
materialized (a 32k prefill would otherwise need O(S^2) HBM).  Sliding-window
archs additionally restrict the key slice per chunk, making prefill
sub-quadratic and allowing a ring-buffer KV cache of just `window` slots —
this is what makes `long_500k` feasible for SWA archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import apply_rope, he_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig, dtype):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w_q": he_init(k1, (d, h * dh), dtype),
        "w_k": he_init(k2, (d, kv * dh), dtype),
        "w_v": he_init(k3, (d, kv * dh), dtype),
        "w_o": he_init(k4, (h * dh, d), dtype, fan_in=h * dh),
    }


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def _gqa_scores(q, k):
    """q: [B,Sq,KV,G,Dh], k: [B,Sk,KV,Dh] -> [B,KV,G,Sq,Sk]."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                      k.astype(jnp.float32))


def _chunked_causal_attention(q, k, v, *, window: int, chunk: int):
    """q: [B,S,KV,G,Dh]; k,v: [B,S,KV,Dh]. Causal (+ optional window) attention
    computed in query chunks; never materializes [S,S]."""
    B, S, KV, G, Dh = q.shape
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n_chunks = S // chunk

    # key slice length per chunk: window-limited if SWA else full prefix
    if window and window < S:
        klen = chunk + window  # keys [q0 - window, q0 + chunk)
    else:
        klen = S

    def one_chunk(ci):
        q0 = ci * chunk
        qc = jax.lax.dynamic_slice_in_dim(q, q0, chunk, axis=1)
        if klen == S:
            kc, vc, k0 = k, v, 0
        else:
            k0 = jnp.maximum(q0 - window, 0)
            k0 = jnp.minimum(k0, S - klen)
            kc = jax.lax.dynamic_slice_in_dim(k, k0, klen, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, k0, klen, axis=1)
        s = _gqa_scores(qc, kc) * scale                      # [B,KV,G,chunk,klen]
        qpos = q0 + jnp.arange(chunk)
        kpos = k0 + jnp.arange(klen)
        mask = kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, vc.astype(jnp.float32))
        return out.astype(q.dtype)

    outs = jax.lax.map(one_chunk, jnp.arange(n_chunks))      # [n,B,chunk,KV,G,Dh]
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, KV, G, Dh)


def gqa_forward(params, x, positions, cfg: ModelConfig, *, chunk: int = 1024,
                use_rope: bool = True, causal: bool = True,
                kv_src: jax.Array | None = None):
    """Training/prefill attention. x: [B,S,D] -> [B,S,D].

    kv_src: optional separate K/V source sequence (cross-attention); implies
    non-causal full attention over kv_src.
    """
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B, S, _ = x.shape
    src = x if kv_src is None else kv_src
    q = _split_heads(x @ params["w_q"], h, dh)
    k = _split_heads(src @ params["w_k"], kv, dh)
    v = _split_heads(src @ params["w_v"], kv, dh)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, jnp.broadcast_to(jnp.arange(src.shape[1]),
                                           src.shape[:2]), cfg.rope_theta)
    q = q.reshape(B, S, kv, h // kv, dh)
    if causal and kv_src is None:
        out = _chunked_causal_attention(q, k, v, window=cfg.sliding_window,
                                        chunk=chunk)
    else:
        s = _gqa_scores(q, k) / jnp.sqrt(dh)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w,
                         v.astype(jnp.float32)).astype(x.dtype)
    return out.reshape(B, S, h * dh) @ params["w_o"]


# --- KV cache -----------------------------------------------------------


def gqa_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer length: `window` slots for SWA archs, else full seq."""
    if cfg.sliding_window and cfg.sliding_window < seq_len:
        return cfg.sliding_window
    return seq_len


def gqa_init_cache(cfg: ModelConfig, batch: int, seq_len: int, n_layers: int,
                   dtype) -> dict:
    kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    clen = gqa_cache_len(cfg, seq_len)
    return {
        "k": jnp.zeros((n_layers, batch, clen, kv, dh), dtype),
        "v": jnp.zeros((n_layers, batch, clen, kv, dh), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def gqa_decode(params, x, layer_cache_k, layer_cache_v, pos, cfg: ModelConfig,
               *, use_rope: bool = True):
    """Single-token decode. x: [B,1,D]; caches [B,C,KV,Dh]; pos: tokens so far.

    Returns (out [B,1,D], new_k, new_v).
    """
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B = x.shape[0]
    C = layer_cache_k.shape[1]
    q = _split_heads(x @ params["w_q"], h, dh)
    k = _split_heads(x @ params["w_k"], kv, dh)
    v = _split_heads(x @ params["w_v"], kv, dh)
    if use_rope:
        posv = jnp.full((B, 1), pos)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)

    slot = jnp.mod(pos, C)
    new_k = jax.lax.dynamic_update_slice_in_dim(layer_cache_k, k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(layer_cache_v, v, slot, axis=1)

    qh = q.reshape(B, 1, kv, h // kv, dh)
    s = _gqa_scores(qh, new_k) / jnp.sqrt(dh)                # [B,KV,G,1,C]
    valid = jnp.arange(C) < jnp.minimum(pos + 1, C)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, new_v.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, 1, h * dh)
    return out @ params["w_o"], new_k, new_v


def cross_attend(params, x, k_cache, v_cache, cfg: ModelConfig):
    """Cross-attention against precomputed (encoder) K/V. x: [B,Sq,D];
    k_cache/v_cache: [B,Se,KV,Dh]."""
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B, Sq, _ = x.shape
    q = _split_heads(x @ params["w_q"], h, dh).reshape(B, Sq, kv, h // kv, dh)
    s = _gqa_scores(q, k_cache) / jnp.sqrt(dh)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v_cache.astype(jnp.float32))
    return out.astype(x.dtype).reshape(B, Sq, h * dh) @ params["w_o"]


def cross_kv(params, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output [B,Se,D]."""
    kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    k = _split_heads(enc_out @ params["w_k"], kv, dh)
    v = _split_heads(enc_out @ params["w_v"], kv, dh)
    return k, v


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig, dtype):
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": he_init(ks[0], (d, m.kv_lora_rank), dtype),
        "w_kr": he_init(ks[1], (d, dr), dtype),
        "w_uk": he_init(ks[2], (m.kv_lora_rank, h * dn), dtype,
                        fan_in=m.kv_lora_rank),
        "w_uv": he_init(ks[3], (m.kv_lora_rank, h * dv), dtype,
                        fan_in=m.kv_lora_rank),
        "w_o": he_init(ks[4], (h * dv, d), dtype, fan_in=h * dv),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
    }
    if m.q_lora_rank:
        p["w_dq"] = he_init(ks[5], (d, m.q_lora_rank), dtype)
        p["w_uq"] = he_init(ks[6], (m.q_lora_rank, h * (dn + dr)), dtype,
                            fan_in=m.q_lora_rank)
        p["q_norm"] = jnp.ones((m.q_lora_rank,), dtype)
    else:
        p["w_q"] = he_init(ks[7], (d, h * (dn + dr)), dtype)
    return p


def _rms(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def _mla_qkr(params, x, positions, cfg: ModelConfig):
    """Shared q / compressed-kv / rope-key computation."""
    m: MLAConfig = cfg.mla
    h = cfg.num_heads
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    B, S, _ = x.shape
    if m.q_lora_rank:
        q = _rms(x @ params["w_dq"], params["q_norm"]) @ params["w_uq"]
    else:
        q = x @ params["w_q"]
    q = q.reshape(B, S, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = _rms(x @ params["w_dkv"], params["kv_norm"])       # [B,S,R]
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]            # [B,S,dr]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(params, x, positions, cfg: ModelConfig, *, chunk: int = 1024):
    """Training/prefill MLA (unabsorbed). x: [B,S,D]."""
    m: MLAConfig = cfg.mla
    h = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, x, positions, cfg)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, h, dn)
    v = (c_kv @ params["w_uv"]).reshape(B, S, h, dv)
    scale = 1.0 / jnp.sqrt(dn + dr).astype(jnp.float32)

    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2

    def one_chunk(ci):
        q0 = ci * chunk
        qn = jax.lax.dynamic_slice_in_dim(q_nope, q0, chunk, axis=1)
        qr = jax.lax.dynamic_slice_in_dim(q_rope, q0, chunk, axis=1)
        s = jnp.einsum("bqhd,bshd->bhqs", qn.astype(jnp.float32),
                       k_nope.astype(jnp.float32))
        s += jnp.einsum("bqhd,bsd->bhqs", qr.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
        s *= scale
        qpos = q0 + jnp.arange(chunk)
        mask = jnp.arange(S)[None, :] <= qpos[:, None]
        s = jnp.where(mask, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", w, v.astype(jnp.float32))
        return out.astype(x.dtype)

    outs = jax.lax.map(one_chunk, jnp.arange(S // chunk))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, h * dv)
    return out @ params["w_o"]


def mla_init_cache(cfg: ModelConfig, batch: int, seq_len: int, n_layers: int,
                   dtype) -> dict:
    m: MLAConfig = cfg.mla
    return {
        "c_kv": jnp.zeros((n_layers, batch, seq_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((n_layers, batch, seq_len, m.qk_rope_head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_decode(params, x, cache_ckv, cache_kr, pos, cfg: ModelConfig):
    """Absorbed-matmul MLA decode: attends in the compressed latent space.

    x: [B,1,D]; cache_ckv: [B,C,R]; cache_kr: [B,C,dr].
    """
    m: MLAConfig = cfg.mla
    h = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    B = x.shape[0]
    C = cache_ckv.shape[1]
    posv = jnp.full((B, 1), pos)
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, x, posv, cfg)

    new_ckv = jax.lax.dynamic_update_slice_in_dim(cache_ckv, c_kv, pos, axis=1)
    new_kr = jax.lax.dynamic_update_slice_in_dim(cache_kr, k_rope, pos, axis=1)

    # absorb W_uk into q:  q_eff[h,R] = q_nope[h,dn] @ W_uk[R, h*dn] slice
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, dn)
    q_eff = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))               # [B,1,h,R]
    s = jnp.einsum("bqhr,bsr->bhqs", q_eff, new_ckv.astype(jnp.float32))
    s += jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                    new_kr.astype(jnp.float32))
    s /= jnp.sqrt(dn + dr)
    valid = jnp.arange(C) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)                             # [B,h,1,C]
    ctx = jnp.einsum("bhqs,bsr->bqhr", w, new_ckv.astype(jnp.float32))
    # absorb W_uv on the way out
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, dv)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, 1, h * dv)
    return out @ params["w_o"], new_ckv, new_kr
