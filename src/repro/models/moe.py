"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Dispatch is sort-based (MegaBlocks-style, adapted to static TPU shapes):
token->expert assignments are stably sorted by expert id, positions within
each expert group are computed from group offsets, and tokens are
scatter-gathered into a dense [E, C, D] expert-input buffer.  This keeps the
routing cost at O(T log T + T D) instead of the O(T C E D) of one-hot einsum
dispatch (which would *dominate* model FLOPs at 32k sequence length).

The stacked expert weights [E, d, f] are sharded over the "model" mesh axis
(expert parallelism) when E divides the axis, else the capacity dim of the
buffer is sharded.  Shared experts (DeepSeek-style) run densely alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import he_init, swiglu, swiglu_init


def moe_init(key, cfg: ModelConfig, dtype):
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    dff = m.expert_d_ff or cfg.d_ff
    k_r, k_e, k_s = jax.random.split(key, 3)
    ke = jax.random.split(k_e, 3)
    p = {
        "router": he_init(k_r, (d, m.num_experts), dtype),
        # stacked expert weights [E, ...] -> expert-parallel shardable
        "w_gate": he_init(ke[0], (m.num_experts, d, dff), dtype, fan_in=d),
        "w_up": he_init(ke[1], (m.num_experts, d, dff), dtype, fan_in=d),
        "w_down": he_init(ke[2], (m.num_experts, dff, d), dtype, fan_in=dff),
    }
    if m.num_shared_experts:
        p["shared"] = swiglu_init(k_s, d, dff * m.num_shared_experts, dtype)
    return p


def capacity(tokens: int, m: MoEConfig) -> int:
    cap = int(m.capacity_factor * tokens * m.top_k / m.num_experts)
    cap = max(cap, m.top_k, 4)
    return (cap + 3) // 4 * 4  # pad to a friendly multiple


def moe_forward(params, x, cfg: ModelConfig):
    """x: [B,S,D] -> (out [B,S,D], aux_loss scalar).

    dispatch="global": one token pool across the whole [B,S] batch — higher
    quality capacity allocation but the scatter crosses batch shards (XLA
    inserts an all-reduce of the full expert buffer when B is data-sharded).
    dispatch="row": independent dispatch per batch row — the scatter stays
    local to each data shard (§Perf hillclimb 2).
    """
    m: MoEConfig = cfg.moe
    if m.dispatch == "row":
        outs, auxes = jax.vmap(lambda row: _moe_tokens(
            params, row, cfg))(x)
        return outs, auxes.mean()
    B, S, D = x.shape
    out, aux = _moe_tokens(params, x.reshape(B * S, D), cfg)
    return out.reshape(B, S, D), aux


def _moe_tokens(params, xf, cfg: ModelConfig):
    """Core dispatch over a flat token pool. xf: [T,D]."""
    m: MoEConfig = cfg.moe
    T, D = xf.shape
    E = m.num_experts
    C = capacity(T, m)
    logits = (xf @ params["router"]).astype(jnp.float32)      # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)       # [T,k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch --------------------------------------------
    e_flat = gate_idx.reshape(-1)                             # [T*k]
    w_flat = gate_vals.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(T), m.top_k)

    order = jnp.argsort(e_flat, stable=True)
    e_s, tok_s, w_s = e_flat[order], tok_flat[order], w_flat[order]

    counts = jnp.zeros((E,), jnp.int32).at[e_s].add(1)
    offsets = jnp.cumsum(counts) - counts                     # group starts
    pos = jnp.arange(T * m.top_k) - offsets[e_s]              # rank in group
    keep = pos < C
    dest = e_s * C + jnp.clip(pos, 0, C - 1)                  # [T*k]

    # expert input buffer [E*C, D] (unique dest among kept entries)
    upd = jnp.where(keep[:, None], xf[tok_s], 0).astype(xf.dtype)
    xe = jnp.zeros((E * C, D), xf.dtype).at[dest].add(
        upd, mode="drop").reshape(E, C, D)

    # ---- expert FFN (E shardable over "model") ---------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(E * C, D)

    # ---- combine ---------------------------------------------------------
    gathered = ye[dest] * (w_s * keep)[:, None].astype(xf.dtype)
    out = jnp.zeros((T, D), xf.dtype).at[tok_s].add(gathered, mode="drop")

    if m.num_shared_experts:
        out = out + swiglu(params["shared"], xf)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)                                   # [E]
    frac = counts.astype(jnp.float32) / jnp.maximum(T * m.top_k, 1)
    aux = m.router_aux_coef * E * jnp.sum(me * frac)
    return out, aux
