"""Model registry: builds any assigned architecture from a ModelConfig.

All families expose the same functional interface:

    model = Model(cfg)
    params = model.init(key)
    loss, aux = model.loss(params, batch)
    logits    = model.forward(params, batch)          # [B,S,V]
    cache     = model.init_cache(batch_size, cache_len)
    logits, cache = model.prefill(params, batch)      # fills cache
    logits, cache = model.decode_step(params, tokens, cache)

Layer stacks are stored with a leading layer dimension and executed with
``jax.lax.scan`` (one compiled block body regardless of depth).  Families:

  dense   pre-norm GQA/MLA + SwiGLU                   (yi, smollm, phi3, minicpm3)
  moe     dense attention + MoE FFN                   (mixtral, deepseek-v2-lite)
  ssm     Mamba2 (zamba backbone) / RWKV-6 stacks     (rwkv6)
  hybrid  Mamba2 stack + ONE shared attention block   (zamba2)
  vlm     dense backbone consuming [img_embeds; text] (llava-next-mistral)
  audio   whisper enc-dec with stub conv frontend     (whisper-tiny)
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as nn
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _stack_init(fn, key, n):
    """vmap an init over n layer keys -> params with leading layer dim."""
    return jax.vmap(fn)(jax.random.split(key, n))


# ===========================================================================
# Block bodies (single layer; scanned)
# ===========================================================================


def _attn_op(bp, h, positions, cfg, **kw):
    if cfg.attention == "mla":
        return attn.mla_forward(bp["attn"], h, positions, cfg)
    return attn.gqa_forward(bp["attn"], h, positions, cfg, **kw)


def _dense_block(bp, x, positions, cfg: ModelConfig):
    h = nn.rms_norm(bp["ln1"], x, cfg.norm_eps)
    x = x + _attn_op(bp, h, positions, cfg)
    h = nn.rms_norm(bp["ln2"], x, cfg.norm_eps)
    x = x + nn.swiglu(bp["mlp"], h)
    return x, jnp.zeros((), jnp.float32)


def _moe_block(bp, x, positions, cfg: ModelConfig):
    h = nn.rms_norm(bp["ln1"], x, cfg.norm_eps)
    x = x + _attn_op(bp, h, positions, cfg)
    h = nn.rms_norm(bp["ln2"], x, cfg.norm_eps)
    out, aux = moe_lib.moe_forward(bp["moe"], h, cfg)
    return x + out, aux


def _mamba_block(bp, x, cfg: ModelConfig):
    h = nn.rms_norm(bp["ln"], x, cfg.norm_eps)
    out, state = ssm_lib.mamba2_forward(bp["ssm"], h, cfg)
    return x + out, state


def _rwkv_block(bp, x, cfg: ModelConfig, state=None, att_x=None, ffn_x=None):
    h = nn.rms_norm(bp["ln1"], x, cfg.norm_eps)
    out, (new_state, new_att_x) = rwkv_lib.rwkv6_att_forward(
        bp["att"], h, cfg, state=state, prev_x=att_x)
    x = x + out
    h = nn.rms_norm(bp["ln2"], x, cfg.norm_eps)
    out, new_ffn_x = rwkv_lib.rwkv6_ffn_forward(bp["ffn"], h, prev_x=ffn_x)
    return x + out, (new_state, new_att_x, new_ffn_x)


# ===========================================================================
# Model
# ===========================================================================


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        # megatron-style vocab padding: embedding/lm-head tables are padded
        # to a multiple of 128 so vocab-parallel sharding divides evenly
        # (whisper 51865 -> 51968, minicpm3 73448 -> 73472).  Logits cover
        # the padded vocab; label ids stay < cfg.vocab_size.
        self.padded_vocab = -(-cfg.vocab_size // 128) * 128

    # ------------------------------------------------------------- init ---

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": nn.embed_init(keys[0], self.padded_vocab, cfg.d_model,
                                   dt),
            "final_norm": nn.rms_norm_init(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = nn.lm_head_init(
                keys[1], cfg.d_model, self.padded_vocab, dt)

        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            n_moe = cfg.num_layers
            n_dense_ff = 0
            if cfg.moe is not None and cfg.moe.first_dense_layers:
                n_dense_ff = cfg.moe.first_dense_layers
                n_moe = cfg.num_layers - n_dense_ff
            if cfg.moe is None:
                params["blocks"] = _stack_init(
                    lambda k: self._dense_block_init(k), keys[2],
                    cfg.num_layers)
            else:
                if n_dense_ff:
                    params["dense_blocks"] = _stack_init(
                        lambda k: self._dense_block_init(
                            k, d_ff=cfg.moe.first_dense_d_ff or cfg.d_ff),
                        keys[3], n_dense_ff)
                params["blocks"] = _stack_init(
                    lambda k: self._moe_block_init(k), keys[2], n_moe)
        elif fam == "ssm":  # rwkv6
            params["blocks"] = _stack_init(
                lambda k: self._rwkv_block_init(k), keys[2], cfg.num_layers)
        elif fam == "hybrid":  # zamba2
            params["blocks"] = _stack_init(
                lambda k: self._mamba_block_init(k), keys[2], cfg.num_layers)
            params["shared_attn"] = {
                "ln": nn.rms_norm_init(cfg.d_model, dt),
                "attn": attn.gqa_init(keys[4], cfg, dt),
                "ln2": nn.rms_norm_init(cfg.d_model, dt),
                "mlp": nn.swiglu_init(keys[5], cfg.d_model, cfg.d_ff, dt),
            }
        elif fam == "audio":  # whisper
            params["enc_blocks"] = _stack_init(
                lambda k: self._whisper_enc_block_init(k), keys[2],
                cfg.encoder_layers)
            params["enc_norm"] = nn.layer_norm_init(cfg.d_model, dt)
            params["blocks"] = _stack_init(
                lambda k: self._whisper_dec_block_init(k), keys[3],
                cfg.num_layers)
            params["dec_pos"] = (0.02 * jax.random.normal(
                keys[4], (cfg.max_seq_len if cfg.max_seq_len < 1 << 17
                          else 1 << 16, cfg.d_model))).astype(dt)
        else:
            raise ValueError(f"unknown family {fam!r}")
        return params

    def _dense_block_init(self, key, d_ff: int = 0):
        cfg = self.cfg
        dt = _dtype(cfg)
        k1, k2 = jax.random.split(key)
        a_init = attn.mla_init if cfg.attention == "mla" else attn.gqa_init
        return {
            "ln1": nn.rms_norm_init(cfg.d_model, dt),
            "attn": a_init(k1, cfg, dt),
            "ln2": nn.rms_norm_init(cfg.d_model, dt),
            "mlp": nn.swiglu_init(k2, cfg.d_model, d_ff or cfg.d_ff, dt),
        }

    def _moe_block_init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        k1, k2 = jax.random.split(key)
        a_init = attn.mla_init if cfg.attention == "mla" else attn.gqa_init
        return {
            "ln1": nn.rms_norm_init(cfg.d_model, dt),
            "attn": a_init(k1, cfg, dt),
            "ln2": nn.rms_norm_init(cfg.d_model, dt),
            "moe": moe_lib.moe_init(k2, cfg, dt),
        }

    def _mamba_block_init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        return {
            "ln": nn.rms_norm_init(cfg.d_model, dt),
            "ssm": ssm_lib.mamba2_init(key, cfg, dt),
        }

    def _rwkv_block_init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        k1, k2 = jax.random.split(key)
        return {
            "ln1": nn.rms_norm_init(cfg.d_model, dt),
            "att": rwkv_lib.rwkv6_att_init(k1, cfg, dt),
            "ln2": nn.rms_norm_init(cfg.d_model, dt),
            "ffn": rwkv_lib.rwkv6_ffn_init(k2, cfg, dt),
        }

    def _whisper_enc_block_init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        k1, k2 = jax.random.split(key)
        return {
            "ln1": nn.layer_norm_init(cfg.d_model, dt),
            "attn": attn.gqa_init(k1, cfg, dt),
            "ln2": nn.layer_norm_init(cfg.d_model, dt),
            "mlp": nn.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dt),
        }

    def _whisper_dec_block_init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": nn.layer_norm_init(cfg.d_model, dt),
            "attn": attn.gqa_init(k1, cfg, dt),
            "ln_x": nn.layer_norm_init(cfg.d_model, dt),
            "xattn": attn.gqa_init(k2, cfg, dt),
            "ln2": nn.layer_norm_init(cfg.d_model, dt),
            "mlp": nn.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dt),
        }

    # --------------------------------------------------------- embedding ---

    def _embed_inputs(self, params, batch):
        """Returns (x [B,S,D], label_mask [B,S] or None)."""
        cfg = self.cfg
        x = nn.embed(params["embed"], batch["tokens"])
        mask = None
        if cfg.family == "vlm":
            img = batch["image_embeds"].astype(x.dtype)      # [B,Nimg,D]
            x = jnp.concatenate([img, x], axis=1)
            B, S = x.shape[:2]
            mask = (jnp.arange(S) >= img.shape[1]).astype(jnp.float32)
            mask = jnp.broadcast_to(mask, (B, S))
        if cfg.family == "audio":
            P = params["dec_pos"]
            pos = jnp.arange(x.shape[1]) % P.shape[0]
            x = x + P[pos]
        return x, mask

    # ------------------------------------------------------------ encoder --

    def _encode(self, params, frames):
        """Whisper encoder over stub frame embeddings [B,Se,D]."""
        cfg = self.cfg
        Se = frames.shape[1]
        pos = _sinusoidal(Se, cfg.d_model).astype(frames.dtype)
        x = frames + pos

        def body(x, bp):
            h = nn.layer_norm(bp["ln1"], x, cfg.norm_eps)
            x = x + attn.gqa_forward(bp["attn"], h, None, cfg,
                                     use_rope=False, causal=False)
            h = nn.layer_norm(bp["ln2"], x, cfg.norm_eps)
            x = x + nn.gelu_mlp(bp["mlp"], h)
            return x, None

        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return nn.layer_norm(params["enc_norm"], x, cfg.norm_eps)

    # ------------------------------------------------------------ forward --

    REMAT_POLICIES = {
        None: None,
        "full": None,
        "dots": "dots_with_no_batch_dims_saveable",
        "nothing": "nothing_saveable",
    }

    def _ckpt(self, fn, remat, policy):
        if not remat:
            return fn
        pol_name = self.REMAT_POLICIES.get(policy, policy)
        pol = getattr(jax.checkpoint_policies, pol_name) if pol_name else None
        return jax.checkpoint(fn, policy=pol)

    def forward(self, params, batch, *, remat: bool = True,
                remat_policy: str | None = None):
        """Full-sequence logits [B,S,V] (train / prefill compute path)."""
        cfg = self.cfg
        x, _ = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        fam = cfg.family

        if fam == "audio":
            enc_out = self._encode(params, batch["frames"])

            def dec_body(x, bp):
                h = nn.layer_norm(bp["ln1"], x, cfg.norm_eps)
                x = x + attn.gqa_forward(bp["attn"], h, positions, cfg,
                                         use_rope=False, causal=True)
                h = nn.layer_norm(bp["ln_x"], x, cfg.norm_eps)
                x = x + attn.gqa_forward(bp["xattn"], h, None, cfg,
                                         use_rope=False, causal=False,
                                         kv_src=enc_out)
                h = nn.layer_norm(bp["ln2"], x, cfg.norm_eps)
                x = x + nn.gelu_mlp(bp["mlp"], h)
                return x, None

            body = self._ckpt(dec_body, remat, remat_policy)
            x, _ = jax.lax.scan(body, x, params["blocks"])
            x = nn.rms_norm(params["final_norm"], x, cfg.norm_eps)
            return self._logits(params, x)

        if fam == "ssm":  # rwkv6
            def body(x, bp):
                x, _ = _rwkv_block(bp, x, cfg)
                return x, None

            body = self._ckpt(body, remat, remat_policy)
            x, _ = jax.lax.scan(body, x, params["blocks"])

        elif fam == "hybrid":  # zamba2: static groups of `every` mamba
            # layers followed by the shared attention block (no lax.cond:
            # exact flop accounting + one compiled body per group size)
            shared = params["shared_attn"]

            def mamba_stack(x, blocks):
                def body(x, bp):
                    x, _ = _mamba_block(bp, x, cfg)
                    return x, None
                b = self._ckpt(body, remat, remat_policy)
                x, _ = jax.lax.scan(b, x, blocks)
                return x

            def shared_block(x):
                h = nn.rms_norm(shared["ln"], x, cfg.norm_eps)
                x = x + attn.gqa_forward(shared["attn"], h, positions, cfg)
                h = nn.rms_norm(shared["ln2"], x, cfg.norm_eps)
                return x + nn.swiglu(shared["mlp"], h)

            for g0, g1, has_attn in _hybrid_groups(cfg):
                x = mamba_stack(x, jax.tree.map(
                    lambda b: b[g0:g1], params["blocks"]))
                if has_attn:
                    x = shared_block(x)

        else:  # dense / moe / vlm
            if "dense_blocks" in params:
                d_ff = cfg.moe.first_dense_d_ff or cfg.d_ff

                def dbody(x, bp):
                    x, _ = _dense_block(bp, x, positions, cfg)
                    return x, None

                dbody = self._ckpt(dbody, remat, remat_policy)
                x, _ = jax.lax.scan(dbody, x, params["dense_blocks"])

            block = _moe_block if cfg.moe is not None else _dense_block

            def body(carry, bp):
                x, aux = carry
                x, a = block(bp, x, positions, cfg)
                return (x, aux + a), None

            body = self._ckpt(body, remat, remat_policy)
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
            self._last_aux = aux

        x = nn.rms_norm(params["final_norm"], x, cfg.norm_eps)
        return self._logits(params, x)

    def _logits(self, params, x):
        if self.cfg.tie_embeddings:
            return nn.unembed(params["embed"], x)
        return nn.lm_head(params["lm_head"], x)

    # --------------------------------------------------------------- loss --

    def loss(self, params, batch, *, remat: bool = True,
             remat_policy: str | None = None):
        """Next-token CE; returns (loss, aux_dict)."""
        cfg = self.cfg
        self._last_aux = jnp.zeros((), jnp.float32)
        logits = self.forward(params, batch, remat=remat,
                              remat_policy=remat_policy)
        labels = batch["labels"]
        if cfg.family == "vlm":
            # logits cover [img; text]; labels only cover text
            n_img = batch["image_embeds"].shape[1]
            logits = logits[:, n_img:, :]
        ce = nn.cross_entropy(logits, labels, batch.get("mask"))
        aux = getattr(self, "_last_aux", jnp.zeros((), jnp.float32))
        return ce + aux, {"ce": ce, "router_aux": aux}

    # -------------------------------------------------------------- cache --

    def init_cache(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        dt = _dtype(cfg)
        fam = cfg.family
        L = cfg.num_layers
        if fam in ("dense", "vlm", "moe"):
            if cfg.attention == "mla":
                return attn.mla_init_cache(cfg, batch_size, cache_len, L, dt)
            return attn.gqa_init_cache(cfg, batch_size, cache_len, L, dt)
        if fam == "ssm":
            return rwkv_lib.rwkv6_init_cache(cfg, batch_size, L, dt)
        if fam == "hybrid":
            n_attn = L // cfg.hybrid_attn_every
            c = ssm_lib.mamba2_init_cache(cfg, batch_size, L, dt)
            kvc = attn.gqa_init_cache(cfg, batch_size, cache_len, n_attn, dt)
            c["attn_k"], c["attn_v"] = kvc["k"], kvc["v"]
            c["pos"] = jnp.zeros((), jnp.int32)
            return c
        if fam == "audio":
            c = attn.gqa_init_cache(cfg, batch_size, cache_len, L, dt)
            kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
            Se = cfg.encoder_seq_len
            c["xk"] = jnp.zeros((L, batch_size, Se, kv, dh), dt)
            c["xv"] = jnp.zeros((L, batch_size, Se, kv, dh), dt)
            return c
        raise ValueError(fam)

    # -------------------------------------------------------------- decode --

    def decode_step(self, params, tokens, cache):
        """One token for every sequence. tokens: [B] int32."""
        cfg = self.cfg
        fam = cfg.family
        x = nn.embed(params["embed"], tokens[:, None])        # [B,1,D]
        pos = cache["pos"]
        if fam == "audio":
            P = params["dec_pos"]
            x = x + P[pos % P.shape[0]]

        if fam in ("dense", "vlm", "moe"):
            x = self._decode_dense(params, x, cache)
        elif fam == "ssm":
            x = self._decode_rwkv(params, x, cache)
        elif fam == "hybrid":
            x = self._decode_hybrid(params, x, cache)
        elif fam == "audio":
            x = self._decode_whisper(params, x, cache)
        cache["pos"] = pos + 1
        x = nn.rms_norm(params["final_norm"], x, cfg.norm_eps)
        return self._logits(params, x)[:, 0, :], cache

    def _decode_dense(self, params, x, cache):
        cfg = self.cfg
        pos = cache["pos"]
        mla = cfg.attention == "mla"

        if "dense_blocks" in params:
            nd = cfg.moe.first_dense_layers

            def dbody(x, inp):
                bp, *c = inp
                x, newc = self._dense_decode_block(bp, x, c, pos, swiglu=True)
                return x, newc

            if mla:
                xs = (params["dense_blocks"], cache["c_kv"][:nd],
                      cache["k_rope"][:nd])
            else:
                xs = (params["dense_blocks"], cache["k"][:nd],
                      cache["v"][:nd])
            x, newc = jax.lax.scan(dbody, x, xs)
            if mla:
                cache["c_kv"] = cache["c_kv"].at[:nd].set(newc[0])
                cache["k_rope"] = cache["k_rope"].at[:nd].set(newc[1])
            else:
                cache["k"] = cache["k"].at[:nd].set(newc[0])
                cache["v"] = cache["v"].at[:nd].set(newc[1])
        else:
            nd = 0

        is_moe = cfg.moe is not None

        def body(x, inp):
            bp, *c = inp
            x, newc = self._dense_decode_block(bp, x, c, pos,
                                               swiglu=not is_moe)
            return x, newc

        if mla:
            xs = (params["blocks"], cache["c_kv"][nd:], cache["k_rope"][nd:])
        else:
            xs = (params["blocks"], cache["k"][nd:], cache["v"][nd:])
        x, newc = jax.lax.scan(body, x, xs)
        if mla:
            cache["c_kv"] = cache["c_kv"].at[nd:].set(newc[0])
            cache["k_rope"] = cache["k_rope"].at[nd:].set(newc[1])
        else:
            cache["k"] = cache["k"].at[nd:].set(newc[0])
            cache["v"] = cache["v"].at[nd:].set(newc[1])
        return x

    def _dense_decode_block(self, bp, x, c, pos, *, swiglu: bool):
        cfg = self.cfg
        h = nn.rms_norm(bp["ln1"], x, cfg.norm_eps)
        if cfg.attention == "mla":
            out, nk, nv = attn.mla_decode(bp["attn"], h, c[0], c[1], pos, cfg)
        else:
            out, nk, nv = attn.gqa_decode(bp["attn"], h, c[0], c[1], pos, cfg,
                                          use_rope=cfg.attention == "gqa")
        x = x + out
        h = nn.rms_norm(bp["ln2"], x, cfg.norm_eps)
        if swiglu:
            x = x + nn.swiglu(bp["mlp"], h)
        else:
            out, _ = moe_lib.moe_forward(bp["moe"], h, cfg)
            x = x + out
        return x, (nk, nv)

    def _decode_rwkv(self, params, x, cache):
        cfg = self.cfg

        def body(x, inp):
            bp, st, ax, fx = inp
            x, (nst, nax, nfx) = _rwkv_block(bp, x, cfg, state=st,
                                             att_x=ax, ffn_x=fx)
            return x, (nst, nax, nfx)

        x, (nst, nax, nfx) = jax.lax.scan(
            body, x, (params["blocks"], cache["wkv"], cache["att_x"],
                      cache["ffn_x"]))
        cache["wkv"], cache["att_x"], cache["ffn_x"] = nst, nax, nfx
        return x

    def _decode_hybrid(self, params, x, cache):
        cfg = self.cfg
        shared = params["shared_attn"]
        pos = cache["pos"]

        def body(x, inp):
            bp, h_st, conv_st = inp
            h = nn.rms_norm(bp["ln"], x, cfg.norm_eps)
            out, nh, nconv = ssm_lib.mamba2_decode(bp["ssm"], h, h_st,
                                                   conv_st, cfg)
            return x + out, (nh, nconv)

        nh_all, nconv_all, nk_all, nv_all = [], [], [], []
        slot = 0
        for g0, g1, has_attn in _hybrid_groups(cfg):
            sl = lambda t: t[g0:g1]
            x, (nh, nconv) = jax.lax.scan(
                body, x, (jax.tree.map(sl, params["blocks"]),
                          cache["h"][g0:g1], cache["conv"][g0:g1]))
            nh_all.append(nh)
            nconv_all.append(nconv)
            if has_attn:
                h = nn.rms_norm(shared["ln"], x, cfg.norm_eps)
                out, nk, nv = attn.gqa_decode(
                    shared["attn"], h, cache["attn_k"][slot],
                    cache["attn_v"][slot], pos, cfg)
                x = x + out
                h = nn.rms_norm(shared["ln2"], x, cfg.norm_eps)
                x = x + nn.swiglu(shared["mlp"], h)
                nk_all.append(nk)
                nv_all.append(nv)
                slot += 1
        cache["h"] = jnp.concatenate(nh_all, 0)
        cache["conv"] = jnp.concatenate(nconv_all, 0)
        cache["attn_k"] = jnp.stack(nk_all, 0)
        cache["attn_v"] = jnp.stack(nv_all, 0)
        return x

    def _decode_whisper(self, params, x, cache):
        cfg = self.cfg
        pos = cache["pos"]

        def body(x, inp):
            bp, k_l, v_l, xk_l, xv_l = inp
            h = nn.layer_norm(bp["ln1"], x, cfg.norm_eps)
            out, nk, nv = attn.gqa_decode(bp["attn"], h, k_l, v_l, pos, cfg,
                                          use_rope=False)
            x = x + out
            h = nn.layer_norm(bp["ln_x"], x, cfg.norm_eps)
            x = x + attn.cross_attend(bp["xattn"], h, xk_l, xv_l, cfg)
            h = nn.layer_norm(bp["ln2"], x, cfg.norm_eps)
            x = x + nn.gelu_mlp(bp["mlp"], h)
            return x, (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        cache["k"], cache["v"] = nk, nv
        return x

    # ------------------------------------------------------------- prefill --

    def prefill(self, params, batch, max_len: int = 0):
        """Run the full prompt, build the decode cache, return last logits.

        max_len: cache capacity (>= prompt + expected decode tokens);
        defaults to prompt + 64.  Implemented as forward + cache extraction;
        used by serve drivers and lowered for the `prefill_32k` dry-run.
        """
        cfg = self.cfg
        fam = cfg.family
        x, _ = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        cache = self.init_cache(B, max_len or S + 64)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        if fam in ("dense", "vlm", "moe"):
            x, cache = self._prefill_dense(params, x, positions, cache)
        elif fam == "ssm":
            x, cache = self._prefill_rwkv(params, x, cache)
        elif fam == "hybrid":
            x, cache = self._prefill_hybrid(params, x, positions, cache)
        elif fam == "audio":
            x, cache = self._prefill_whisper(params, x, positions, cache,
                                             batch["frames"])
        cache["pos"] = jnp.asarray(S, jnp.int32)
        x = nn.rms_norm(params["final_norm"], x, cfg.norm_eps)
        return self._logits(params, x[:, -1:, :])[:, 0, :], cache

    def _fill_ring(self, cache_kv, k):
        """Write a full prefill sequence into a (possibly ring) cache.

        cache_kv: [B,C,KV,Dh]; k: [B,S,KV,Dh] with S tokens, C slots."""
        C = cache_kv.shape[1]
        S = k.shape[1]
        if S >= C:
            tail = k[:, S - C:]
            return jnp.roll(tail, (S - C) % C, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(cache_kv, k, 0, axis=1)

    def _prefill_dense(self, params, x, positions, cache):
        cfg = self.cfg
        mla = cfg.attention == "mla"

        def run_stack(x, blocks, is_moe):
            def body(x, bp):
                h = nn.rms_norm(bp["ln1"], x, cfg.norm_eps)
                if mla:
                    qn, qr, c_kv, k_rope = attn._mla_qkr(bp["attn"], h,
                                                         positions, cfg)
                    out = attn.mla_forward(bp["attn"], h, positions, cfg)
                    saved = (c_kv, k_rope)
                else:
                    kk = attn._split_heads(h @ bp["attn"]["w_k"],
                                           cfg.num_kv_heads,
                                           cfg.resolved_head_dim)
                    vv = attn._split_heads(h @ bp["attn"]["w_v"],
                                           cfg.num_kv_heads,
                                           cfg.resolved_head_dim)
                    kk = attn.apply_rope(kk, positions, cfg.rope_theta)
                    out = attn.gqa_forward(bp["attn"], h, positions, cfg)
                    saved = (kk, vv)
                x = x + out
                h = nn.rms_norm(bp["ln2"], x, cfg.norm_eps)
                if is_moe:
                    out, _ = moe_lib.moe_forward(bp["moe"], h, cfg)
                    x = x + out
                else:
                    x = x + nn.swiglu(bp["mlp"], h)
                return x, saved

            return jax.lax.scan(body, x, blocks)

        nd = 0
        saved_all = []
        if "dense_blocks" in params:
            nd = cfg.moe.first_dense_layers
            x, saved = run_stack(x, params["dense_blocks"], False)
            saved_all.append(saved)
        x, saved = run_stack(x, params["blocks"], cfg.moe is not None)
        saved_all.append(saved)
        s0 = jnp.concatenate([s[0] for s in saved_all], 0) \
            if len(saved_all) > 1 else saved_all[0][0]
        s1 = jnp.concatenate([s[1] for s in saved_all], 0) \
            if len(saved_all) > 1 else saved_all[0][1]

        if mla:
            # caches [L,B,C,R]: write first S positions
            S = s0.shape[2]
            cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
                cache["c_kv"], s0, 0, axis=2)
            cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k_rope"], s1, 0, axis=2)
        else:
            cache["k"] = jax.vmap(self._fill_ring)(cache["k"], s0)
            cache["v"] = jax.vmap(self._fill_ring)(cache["v"], s1)
        return x, cache

    def _prefill_rwkv(self, params, x, cache):
        cfg = self.cfg

        def body(x, bp):
            x, st = _rwkv_block(bp, x, cfg)
            return x, st

        x, (wkv, att_x, ffn_x) = jax.lax.scan(body, x, params["blocks"])
        cache["wkv"], cache["att_x"], cache["ffn_x"] = wkv, att_x, ffn_x
        return x, cache

    def _prefill_hybrid(self, params, x, positions, cache):
        cfg = self.cfg
        shared = params["shared_attn"]

        def body(x, bp):
            h = nn.rms_norm(bp["ln"], x, cfg.norm_eps)
            out, st = ssm_lib.mamba2_forward(bp["ssm"], h, cfg)
            return x + out, st

        h_all, conv_all, k_all, v_all = [], [], [], []
        for g0, g1, has_attn in _hybrid_groups(cfg):
            x, st = jax.lax.scan(
                body, x, jax.tree.map(lambda b: b[g0:g1], params["blocks"]))
            h_all.append(st["h"])
            conv_all.append(st["conv"])
            if has_attn:
                h = nn.rms_norm(shared["ln"], x, cfg.norm_eps)
                kk = attn._split_heads(h @ shared["attn"]["w_k"],
                                       cfg.num_kv_heads,
                                       cfg.resolved_head_dim)
                vv = attn._split_heads(h @ shared["attn"]["w_v"],
                                       cfg.num_kv_heads,
                                       cfg.resolved_head_dim)
                kk = attn.apply_rope(kk, positions, cfg.rope_theta)
                x = x + attn.gqa_forward(shared["attn"], h, positions, cfg)
                h2 = nn.rms_norm(shared["ln2"], x, cfg.norm_eps)
                x = x + nn.swiglu(shared["mlp"], h2)
                slot = len(k_all)
                k_all.append(self._fill_ring(cache["attn_k"][slot], kk))
                v_all.append(self._fill_ring(cache["attn_v"][slot], vv))
        cache["h"] = jnp.concatenate(h_all, 0)
        cache["conv"] = jnp.concatenate(conv_all, 0)
        cache["attn_k"] = jnp.stack(k_all, 0)
        cache["attn_v"] = jnp.stack(v_all, 0)
        return x, cache

    def _prefill_whisper(self, params, x, positions, cache, frames):
        cfg = self.cfg
        enc_out = self._encode(params, frames)

        def body(x, bp):
            h = nn.layer_norm(bp["ln1"], x, cfg.norm_eps)
            kk = attn._split_heads(h @ bp["attn"]["w_k"], cfg.num_kv_heads,
                                   cfg.resolved_head_dim)
            vv = attn._split_heads(h @ bp["attn"]["w_v"], cfg.num_kv_heads,
                                   cfg.resolved_head_dim)
            x = x + attn.gqa_forward(bp["attn"], h, positions, cfg,
                                     use_rope=False, causal=True)
            h = nn.layer_norm(bp["ln_x"], x, cfg.norm_eps)
            xk, xv = attn.cross_kv(bp["xattn"], enc_out, cfg)
            x = x + attn.cross_attend(bp["xattn"], h, xk, xv, cfg)
            h = nn.layer_norm(bp["ln2"], x, cfg.norm_eps)
            x = x + nn.gelu_mlp(bp["mlp"], h)
            return x, (kk, vv, xk, xv)

        x, (kk, vv, xk, xv) = jax.lax.scan(body, x, params["blocks"])
        cache["k"] = jax.vmap(self._fill_ring)(cache["k"], kk)
        cache["v"] = jax.vmap(self._fill_ring)(cache["v"], vv)
        cache["xk"], cache["xv"] = xk, xv
        return x, cache


def _hybrid_groups(cfg: ModelConfig):
    """Static (start, end, has_attn) layer groups for the zamba2 schedule:
    shared attention fires after every `hybrid_attn_every` mamba layers."""
    every = cfg.hybrid_attn_every
    L = cfg.num_layers
    groups = []
    i = 0
    while i < L:
        j = min(i + every, L)
        groups.append((i, j, j - i == every))
        i = j
    return groups


def _sinusoidal(length: int, dim: int) -> jax.Array:
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    return jnp.asarray(
        np.concatenate([np.sin(angle), np.cos(angle)], axis=-1),
        jnp.float32)
