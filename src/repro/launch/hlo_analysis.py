"""Static analyzer for optimized HLO text: loop-scaled FLOPs / HBM bytes /
collective bytes.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which makes
scan-over-layers programs look ~L times cheaper than they are.  This module
re-derives the three roofline inputs from the HLO text itself:

  * the module is split into computations;
  * a call graph (fusion `calls=`, while `body=`/`condition=`, conditional
    `branch_computations=`) is walked from ENTRY, multiplying by each while's
    ``known_trip_count`` — so a 30-layer scan body counts 30x;
  * FLOPs: `dot` ops contribute 2 * |output| * |contraction| (operand shapes
    resolved through the computation's symbol table); elementwise arithmetic
    contributes |output|;
  * HBM bytes: the sum of operand+output sizes of *materializing* top-level
    ops in executed (non-fusion) computations — fusion boundaries are where
    XLA reads/writes HBM;
  * collective bytes: output sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute ops, loop-scaled.

All quantities are per-device: the input is the SPMD-partitioned module.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*(?:e[0-9]+m[0-9]+\w*)?)\[([\d,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "compare", "select", "and", "or", "xor", "not",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "power", "sine", "cosine",
    "clamp", "remainder", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "atan2", "erf",
}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}

_NON_MATERIALIZING = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def shape_info(type_str: str) -> tuple[int, int]:
    """(total elements, total bytes) over all array literals in a type str."""
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclass
class Op:
    name: str
    opcode: str
    out_type: str
    operands: list
    line: str


@dataclass
class Computation:
    name: str
    params: dict = field(default_factory=dict)   # param name -> type str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)  # op name -> type str


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:[a-z]+[0-9]*[^\s]*\[[\d,]*\][^\s]*|\(.*?\)))\s+"
    r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_module(text: str) -> tuple[dict, str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(raw)
            if m and raw.rstrip().endswith("{"):
                is_entry, name, params = m.groups()
                cur = Computation(name)
                comps[name] = cur
                if is_entry:
                    entry = name
                for pm in re.finditer(r"%?([\w.\-]+):\s*"
                                      r"(\([^)]*\)|[a-z]+[0-9]*\[[\d,]*\][^,)]*)",
                                      params):
                    cur.params[pm.group(1)] = pm.group(2)
                    cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if raw.startswith("}"):
            cur = None
            continue
        om = _OP_RE.match(raw)
        if om:
            nm, out_type, opcode = om.groups()
            # operands: names inside the first (...) after the opcode
            rest = raw[om.end():]
            depth = 1
            args = []
            buf = ""
            for ch in rest:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                buf += ch
            operands = _OPERAND_RE.findall(buf)
            op = Op(nm, opcode, out_type, operands, raw)
            cur.ops.append(op)
            cur.symbols[nm] = out_type
    return comps, entry


def _call_edges(op: Op) -> list[tuple[str, float]]:
    """(callee computation, scale) pairs induced by this op."""
    edges = []
    line = op.line
    if op.opcode == "while":
        trip = 1
        tm = re.search(r'known_trip_count[="\{:]+n["\':]+(\d+)', line)
        if tm:
            trip = int(tm.group(1))
        bm = re.search(r"body=%?([\w.\-]+)", line)
        cm = re.search(r"condition=%?([\w.\-]+)", line)
        if bm:
            edges.append((bm.group(1), float(trip)))
        if cm:
            edges.append((cm.group(1), float(trip + 1)))
    elif op.opcode == "fusion":
        fm = re.search(r"calls=%?([\w.\-]+)", line)
        if fm:
            edges.append((fm.group(1), 1.0))
    elif op.opcode == "conditional":
        for bm in re.finditer(r"branch_computations=\{([^}]*)\}", line):
            for name in _OPERAND_RE.findall(bm.group(1)):
                edges.append((name, 1.0))
        tm = re.search(r"true_computation=%?([\w.\-]+)", line)
        fm = re.search(r"false_computation=%?([\w.\-]+)", line)
        if tm:
            edges.append((tm.group(1), 1.0))
        if fm:
            edges.append((fm.group(1), 1.0))
    # to_apply (reduce/scatter/sort comparators) intentionally not traversed
    return edges


def computation_multipliers(comps: dict, entry: str) -> dict[str, float]:
    mult = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    # fixed point over full recompute passes (call graph is a DAG; DFS
    # preorder is not guaranteed topological, so iterate to convergence)
    changed = True
    iters = 0
    while changed and iters < 200:
        changed = False
        iters += 1
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        order = _topo_order(comps, entry)
        for name in order:
            m = new.get(name, 0.0)
            if m == 0.0:
                continue
            for op in comps[name].ops:
                for callee, scale in _call_edges(op):
                    if callee in new:
                        new[callee] += m * scale
        if new != mult:
            mult = new
            changed = True
    return mult


def _topo_order(comps: dict, entry: str) -> list[str]:
    seen = []
    visited = set()

    def visit(name):
        if name in visited or name not in comps:
            return
        visited.add(name)
        seen.append(name)
        for op in comps[name].ops:
            for callee, _ in _call_edges(op):
                visit(callee)

    visit(entry)
    return seen


def _fusion_computations(comps: dict) -> set[str]:
    fused = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", op.line)
                if fm:
                    fused.add(fm.group(1))
    return fused


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems, _ = shape_info(op.out_type)
    # contraction sizes from lhs shape + lhs_contracting_dims
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not cm or not op.operands:
        return 2.0 * out_elems  # fallback
    lhs_type = comp.symbols.get(op.operands[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 2.0 * out_elems
    dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
    contract = 1
    for ci in cm.group(1).split(","):
        if ci != "" and int(ci) < len(dims):
            contract *= dims[int(ci)]
    return 2.0 * out_elems * contract


@dataclass
class HLOStats:
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: dict = field(default_factory=dict)
    collective_bytes_by_op: dict = field(default_factory=dict)
    unknown_trip_loops: int = 0


def analyze_hlo(text: str) -> HLOStats:
    comps, entry = parse_module(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult = computation_multipliers(comps, entry)
    fused = _fusion_computations(comps)
    stats = HLOStats()

    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        in_fusion = name in fused
        for op in comp.ops:
            out_elems, out_bytes = shape_info(op.out_type)
            # ---- flops
            if op.opcode == "dot":
                f = _dot_flops(op, comp) * m
                stats.flops += f
                stats.dot_flops += f
            elif op.opcode in _ELEMENTWISE:
                stats.flops += out_elems * m
            elif op.opcode in ("reduce", "reduce-window"):
                # approx: one op per input element
                in_elems = sum(shape_info(comp.symbols.get(o, ""))[0]
                               for o in op.operands[:1])
                stats.flops += max(in_elems, out_elems) * m
            elif op.opcode == "convolution":
                # fallback: 2 * out * (kernel elems) — rare in this codebase
                k_elems = shape_info(comp.symbols.get(
                    op.operands[1], ""))[0] if len(op.operands) > 1 else 1
                stats.flops += 2.0 * out_elems * max(k_elems, 1) \
                    / max(out_elems, 1) * out_elems * m
            # ---- collectives
            base = op.opcode.removesuffix("-start")
            if base in {"all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"}:
                b = out_bytes * m
                stats.collective_bytes += b
                stats.collective_counts[base] = \
                    stats.collective_counts.get(base, 0) + 1
                stats.collective_bytes_by_op[base] = \
                    stats.collective_bytes_by_op.get(base, 0.0) + b
            # ---- hbm bytes at fusion boundaries
            if not in_fusion and op.opcode not in _NON_MATERIALIZING \
                    and op.opcode not in ("while", "conditional", "call"):
                opnd_bytes = sum(shape_info(comp.symbols.get(o, ""))[1]
                                 for o in op.operands)
                stats.hbm_bytes += (out_bytes + opnd_bytes) * m
            if op.opcode == "while" and "known_trip_count" not in op.line:
                stats.unknown_trip_loops += 1
    return stats
