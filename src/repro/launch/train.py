"""Training launcher: GFL training of any --arch on a mesh.

On real hardware this runs the production mesh; on CPU it runs reduced
configs on a forced-device test mesh (--devices) so the full path —
sharded params, client scans, sparse combine collectives, checkpointing,
privacy accounting — is exercised end to end.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --reduced --mesh 2x4 --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import rng_key
from repro.checkpoint import save_checkpoint
from repro.configs.base import GFLConfig
from repro.configs.registry import get_config
from repro.core.privacy.mechanism import mechanism_for
from repro.data import TokenStream, federated_token_batches
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh, make_test_mesh, num_servers
from repro.models import Model


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 2:
        return make_test_mesh(dims, ("data", "model"))
    return make_test_mesh(dims, ("pod", "data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="production",
                    help="'production', 'production-multipod' or e.g. '2x4'")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--per-client", type=int, default=2)
    ap.add_argument("--privacy", default="hybrid",
                    help="registered mechanism spec (see "
                         "repro.core.privacy.mechanism), e.g. hybrid, "
                         "gaussian_dp, scheduled:iid_dp")
    ap.add_argument("--sigma", type=float, default=0.01)
    ap.add_argument("--mu", type=float, default=0.1)
    ap.add_argument("--combine", default="sparse",
                    choices=["sparse", "rotate", "dense"])
    ap.add_argument("--fault", default="none",
                    help="resilience fault spec (docs/resilience.md), e.g. "
                         "links:0.1+dropout:0.2")
    ap.add_argument("--virtual-clients", type=int, default=0,
                    help="virtual population size K per server; 0 keeps the "
                         "positional --clients cohort.  With K > 0 a "
                         "CohortScheduler samples --clients ids per round "
                         "from the population (docs/population.md) and the "
                         "accountant reports subsampling-amplified epsilon")
    ap.add_argument("--cohort", default="uniform",
                    help="cohort-scheduler spec (docs/population.md), e.g. "
                         "uniform+trace:diurnal,period=24,min=0.2")
    ap.add_argument("--async", dest="async_spec", default="none",
                    help="event-driven executor spec (docs/async.md), e.g. "
                         "async:buffer=8,latency=lognorm:0.5,max_stale=4 — "
                         "drives staleness-weighted cohort weights and "
                         "per-server release accounting")
    ap.add_argument("--use-kernels", action="store_true",
                    help="route the round through the fused Pallas kernel "
                         "layer (docs/kernels.md): the dense combine runs "
                         "the fused graph-combine per leaf (interpret mode "
                         "on CPU)")
    ap.add_argument("--telemetry", default="off",
                    help="telemetry sink spec (docs/observability.md): "
                         "'off' (default, bit-identical), or a '+'-joined "
                         "jsonl[:path]|csv[:base]|memory|console[:every] "
                         "spec — per-step mesh metrics, the privacy "
                         "ledger stream and a Chrome trace JSON land in "
                         "the sinks (inspect with python -m "
                         "repro.telemetry.inspect)")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)

    if args.mesh == "production":
        mesh = make_production_mesh()
    elif args.mesh == "production-multipod":
        mesh = make_production_mesh(multi_pod=True)
    else:
        mesh = parse_mesh(args.mesh)
    Pn = num_servers(mesh)
    print(f"mesh {dict(mesh.shape)} -> {Pn} GFL servers; arch {cfg.name}")

    gfl_cfg = GFLConfig(topology="ring", privacy=args.privacy,
                        sigma_g=args.sigma, mu=args.mu, grad_bound=10.0,
                        combine_impl=args.combine, fault=args.fault,
                        cohort=args.cohort, async_spec=args.async_spec,
                        use_kernels=args.use_kernels,
                        telemetry=args.telemetry)
    # mechanism-aware: the noise profile picks the curve (eps is inf for
    # a zero-noise config — the honest Theorem-2 answer)
    acc = mechanism_for(gfl_cfg).accountant()
    stream = TokenStream(vocab=cfg.vocab_size, seed=0)

    scheduler = None
    if args.virtual_clients <= 0 and args.cohort != "uniform":
        raise SystemExit(
            "--cohort only takes effect with --virtual-clients > 0 (the "
            "scheduler samples cohort ids from the virtual population); "
            "pass --virtual-clients or drop --cohort")
    if args.virtual_clients > 0:
        from repro.core.population import CohortScheduler, parse_cohort_spec
        sampler, floor, trace = parse_cohort_spec(args.cohort)
        if sampler == "importance":
            raise SystemExit(
                "--cohort importance needs per-client gradient-norm "
                "feedback, which the mesh step does not report; use the "
                "simulator engine (run_gfl_population) or a uniform "
                "sampler with a trace")
        # dropout realizations stay with the topology process below (same
        # stream constants either way — see CohortScheduler._rng)
        scheduler = CohortScheduler(
            args.virtual_clients, args.clients, Pn, sampler=sampler,
            floor=floor, trace=trace, seed=0)
        acc.sampling_rate = args.clients / args.virtual_clients
        print(f"virtual population: K={args.virtual_clients} per server, "
              f"cohort L={args.clients} ({args.cohort})")

    async_drv = async_acc = None
    from repro.core.events import AsyncCohortDriver, parse_async_spec
    from repro.core.population import parse_cohort_spec
    async_spec = parse_async_spec(args.async_spec)
    if async_spec is not None:
        k_pop = args.virtual_clients or args.clients
        # the event layer drives the mesh step's cohort-weight path:
        # per-server buffered release gating with staleness weights, and
        # the matching per-server release accounting (docs/async.md).
        # The availability trace is applied exactly once — a scheduler
        # already thins the cohort at sampling time, so the driver only
        # applies it when no scheduler is active (which the --cohort
        # guard above reduces to the always-on trace).
        trace = ("always" if scheduler is not None
                 else parse_cohort_spec(args.cohort)[2])
        async_drv = AsyncCohortDriver(async_spec, Pn, args.clients, k_pop,
                                      trace=trace, seed=0)
        async_acc = mechanism_for(gfl_cfg).async_accountant(Pn)
        print(f"async event layer: {async_spec.to_spec()} "
              f"(per-server buffered releases, staleness alpha="
              f"{async_spec.alpha:g})")

    process = (steps_lib.make_topology_process(mesh, gfl_cfg)
               if gfl_cfg.fault != "none" else None)
    from repro.telemetry import (emit, session_from_config,
                                 telemetry_active, trace_span)
    with session_from_config(gfl_cfg), mesh:
        with trace_span("train_setup", arch=cfg.name, servers=Pn):
            step = jax.jit(steps_lib.make_train_step(model, gfl_cfg, mesh))
            state = steps_lib.init_train_state(model, gfl_cfg, mesh,
                                               rng_key())
        t0 = time.time()
        # cohort selection stream stays decoupled from the model-init seed
        sel_key = rng_key(1234)
        for i in range(args.steps):
            ids = weights = None
            q_round = None
            if scheduler is not None:
                sel = scheduler.select(jax.random.fold_in(sel_key, i), i)
                ids, weights, q_round = sel.client_idx, sel.weights, sel.q
            if async_drv is not None:
                aw, flushed, q_srv = async_drv.step(i, ids)
                weights = aw if weights is None else weights * aw
            batch = federated_token_batches(
                stream, seed=0, step=i, P=Pn, L=args.clients,
                per_client=args.per_client, seq_len=args.seq,
                client_ids=ids)
            if process is not None:
                real = process.realize(i)
                alive = (process.client_alive(i, args.clients)
                         if process.fault.client_dropout > 0 else None)
                state, metrics = step(state, batch, real.A, alive,
                                      cohort_weights=weights)
                if real.gap != 0.0 and i % max(args.steps // 10, 1) == 0:
                    print(f"  round {i}: spectral gap {real.gap:.3f}")
            else:
                state, metrics = step(state, batch, cohort_weights=weights)
            # one ledger release per protocol round, charged at THIS
            # round's realized rate (a running mean would under-report the
            # spend whenever q varies round to round — f(q) is convex-ish
            # increasing, so per-release rates must be recorded as drawn).
            # Under --async a server only releases when its buffer fills:
            # its own ledger advances on its own cadence.
            if async_acc is not None:
                async_acc.record_round(flushed, q_srv)
                eps = async_acc.epsilon()
            else:
                eps = acc.advance(1, q=q_round)
            if telemetry_active():   # the loss sync is on-path only
                rec = {"step": i, "loss": float(metrics["loss"]),
                       "seconds": time.time() - t0}
                if process is not None:
                    rec["gap"] = process.realize(i).gap
                emit("mesh", rec)
                if "update_norm" in metrics:
                    emit("step", {
                        "step": i + 1,
                        "update_norm": float(metrics["update_norm"]),
                        "param_norm": float(metrics["param_norm"])})
            if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
                amp = (f" eps_amp {acc.amplified_epsilon():.2f} "
                       f"(q~{scheduler.realized_q:.3g})"
                       if scheduler is not None and async_acc is None
                       else "")
                if async_acc is not None:
                    rel = async_acc.releases
                    amp = (f" eps_amp {async_acc.amplified_epsilon():.2f} "
                           f"rel {min(rel)}-{max(rel)}")
                print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                      f"eps {eps:.1f}{amp} ({time.time()-t0:.0f}s)")
    if args.checkpoint:
        save_checkpoint(args.checkpoint,
                        jax.tree.map(lambda x: x[0], state.params),
                        step=args.steps)
        print(f"saved consensus checkpoint to {args.checkpoint}")


if __name__ == "__main__":
    main()
