"""Sharding rules: pytree-path patterns -> PartitionSpecs.

Parameters follow megatron-style tensor parallelism over the "model" axis:
column-parallel in-projections, row-parallel out-projections, vocab-parallel
embeddings, expert-parallel MoE stacks.  In GFL training every leaf gains a
leading server dim sharded over the data (and pod) axes.  GSPMD handles the
few non-divisible cases (e.g. whisper's vocab 51865) by internal padding.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# (regex over "/"-joined path, spec builder (model_axis) -> PartitionSpec)
# First match wins; specs are for the UNstacked (no layer dim) leaf — the
# layer dim is inserted at position 0 for stacked blocks and the server dim
# in front of everything for GFL training.
_RULES: list[tuple[str, callable]] = [
    # embeddings / head: vocab-parallel
    (r"embed/table$", lambda m: P(m, None)),
    (r"lm_head/w$", lambda m: P(None, m)),
    (r"dec_pos$", lambda m: P(None, None)),
    # attention (gqa + mla + whisper cross)
    (r"(attn|xattn)/w_(q|k|v)$", lambda m: P(None, m)),
    (r"(attn|xattn)/w_o$", lambda m: P(m, None)),
    (r"attn/w_(dq|dkv|kr)$", lambda m: P(None, None)),
    (r"attn/w_u(q|k|v)$", lambda m: P(None, m)),
    (r"attn/(q_norm|kv_norm)$", lambda m: P(None)),
    # dense mlp
    (r"(mlp|shared)/w_(gate|up|in)$", lambda m: P(None, m)),
    (r"(mlp|shared)/w_(down|out)$", lambda m: P(m, None)),
    (r"(mlp|shared)/b_in$", lambda m: P(m)),
    (r"(mlp|shared)/b_out$", lambda m: P(None)),
    # moe: routed experts expert-parallel over "model" when E divides it;
    # steps.py rewrites to ff-parallel when it does not (mixtral E=8)
    (r"moe/router$", lambda m: P(None, None)),
    (r"moe/w_(gate|up)$", lambda m: P(m, None, None)),
    (r"moe/w_down$", lambda m: P(m, None, None)),
    # mamba2
    (r"ssm/w_in$", lambda m: P(None, m)),
    (r"ssm/w_out$", lambda m: P(m, None)),
    (r"ssm/(conv_w|conv_b|dt_bias|A_log|D|norm_scale)$", lambda m: P()),
    # rwkv6
    (r"att/w_(r|k|v|g)$", lambda m: P(None, m)),
    (r"att/w_o$", lambda m: P(m, None)),
    (r"att/(mu_x|mu|maa_w1|maa_w2|w0|decay_w1|decay_w2|u|ln_scale)$",
     lambda m: P()),
    (r"ffn/w_k$", lambda m: P(None, m)),
    (r"ffn/w_v$", lambda m: P(m, None)),
    (r"ffn/(w_r|mu_k|mu_r)$", lambda m: P()),
    # norms and anything small: replicate
    (r".*", lambda m: P()),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_spec(path_str: str, cfg: ModelConfig, *,
               model_axis: Optional[str] = "model",
               stacked: bool = False,
               server_axes: Optional[tuple] = None) -> P:
    """PartitionSpec for one param leaf. model_axis=None -> replicated."""
    spec = None
    for pat, builder in _RULES:
        if re.search(pat, path_str):
            spec = builder(model_axis)
            break
    parts = list(spec)
    # moe expert-parallel fallback: shard ff dim when E doesn't divide axis
    if re.search(r"moe/w_(gate|up|down)$", path_str) and cfg.moe is not None \
            and model_axis is not None:
        if cfg.moe.num_experts % 16 != 0:
            if path_str.endswith("w_down"):
                parts = [None, model_axis, None]   # [E, F, D]
            else:
                parts = [None, None, model_axis]   # [E, D, F]
    is_stacked = stacked and _leaf_is_stacked(path_str)
    if is_stacked:
        parts = [None] + parts                      # layer dim
    if server_axes:
        parts = [tuple(server_axes)] + parts        # GFL server dim
    return P(*parts)


def _leaf_is_stacked(path_str: str) -> bool:
    return bool(re.match(r"(blocks|dense_blocks|enc_blocks)/", path_str))


def params_shardings(params, cfg: ModelConfig, mesh, *,
                     server_axes: Optional[tuple] = None,
                     model_axis: Optional[str] = "model"):
    """Pytree of NamedShardings matching `params`.

    model_axis=None replicates every leaf over the model axis (the
    client-parallel small-model mode)."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    specs = []
    for (path, leaf) in flat[0]:
        ps = _path_str(path)
        specs.append(NamedSharding(
            mesh, param_spec(ps, cfg, stacked=True, server_axes=server_axes,
                             model_axis=model_axis)))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Cache / activation shardings
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, mesh, *, shard_seq: bool = False) -> dict:
    """PartitionSpecs for the decode cache pytree.

    Default: batch over data(+pod) axes, trailing feature dim over model.
    shard_seq (long_500k, batch=1): sequence dim over data(+pod) instead.
    """
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    b, s = (None, da) if shard_seq else (da, None)
    fam = cfg.family
    specs: dict = {"pos": P()}
    if fam in ("dense", "vlm", "moe"):
        if cfg.attention == "mla":
            specs["c_kv"] = P(None, b, s, "model")
            specs["k_rope"] = P(None, b, s, None)
        else:
            specs["k"] = P(None, b, s, None, "model")
            specs["v"] = P(None, b, s, None, "model")
    elif fam == "ssm":
        specs["wkv"] = P(None, b, "model" if not shard_seq else None,
                         None, None)
        specs["att_x"] = P(None, b, None)
        specs["ffn_x"] = P(None, b, None)
        if shard_seq:  # batch=1: shard heads over model only
            specs["wkv"] = P(None, None, "model", None, None)
            specs["att_x"] = P(None, None, "model")
            specs["ffn_x"] = P(None, None, "model")
    elif fam == "hybrid":
        specs["h"] = P(None, b, "model", None, None)
        specs["conv"] = P(None, b, None, "model")
        specs["attn_k"] = P(None, b, s, None, "model")
        specs["attn_v"] = P(None, b, s, None, "model")
    elif fam == "audio":
        specs["k"] = P(None, b, s, None, "model")
        specs["v"] = P(None, b, s, None, "model")
        specs["xk"] = P(None, b, None, None, "model")
        specs["xv"] = P(None, b, None, None, "model")
    return specs


def cache_shardings(cache, cfg: ModelConfig, mesh, *, shard_seq=False):
    specs = cache_specs(cfg, mesh, shard_seq=shard_seq)
    return {k: NamedSharding(mesh, specs[k]) for k in cache}


def batch_specs(cfg: ModelConfig, mesh, *, kind: str,
                gfl_train: bool = False,
                client_parallel: bool = False) -> dict:
    """PartitionSpecs for input batches."""
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    if gfl_train:
        # leading dims [P_servers, L, b, ...]; client-parallel mode spreads
        # the L clients over the idle model axis
        lead = (da, "model" if client_parallel else None, None)
    else:
        lead = (da,)
    specs = {"tokens": P(*lead, None), "labels": P(*lead, None)}
    if cfg.family == "vlm":
        specs["image_embeds"] = P(*lead, None, "model")
    if cfg.family == "audio":
        specs["frames"] = P(*lead, None, "model")
    return specs
