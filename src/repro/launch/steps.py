"""Mesh-scale GFL training step and serving steps.

TRAINING (the paper's protocol, eqs. 6-8, at datacenter scale)
  - every param leaf has a leading server dim P sharded over the data(+pod)
    mesh axes; within a server, weights are tensor-parallel over "model";
  - client updates (6): lax.scan over the L client microbatch groups of each
    server, per-client gradients clipped to the paper's bound B
    (Assumption 3), accumulated into the server mean;
  - server aggregation (7): secure-agg pairwise masks cancel EXACTLY in the
    mean (eq. 23), so the aggregate is computed directly; the mask mechanics
    are exercised bit-level by the Pallas kernel + simulator paths;
  - server combination (8): ring-rotation collective over the server axes
    (see `_rotate_combine`) with graph-homomorphic Laplace noise (eq. 24):
    the rotating buffer carries (psi_m + g_m) exactly as the wire protocol
    does, and each server subtracts its own g_p at the end.

  Combine implementations (GFLConfig.combine_impl):
    dense    einsum over a gathered [P, ...] stack — semantic baseline, only
             viable for small models;
    rotate   P-1 ring collective_permutes, O(1) extra memory, works for ANY
             combination matrix A (weights indexed per rotation step);
    sparse   neighbour-only permutes for ring/torus graphs — the beyond-paper
             optimized path (collective bytes ~ degree/P of rotate's).

SERVING: consensus-model prefill / decode, no GFL protocol (params
replicated over data axes, TP over "model"); decode caches sharded per
`sharding.cache_specs`.

Privacy noise (which distribution, which level, whether it cancels) is owned
by the PrivacyMechanism resolved from GFLConfig.privacy — this module only
asks the mechanism for client/combine noise pytrees and applies the
cancellation structure its noise_profile() declares.  Non-cancelling client
noise is applied as a single variance-equivalent draw (sigma/sqrt(L))
instead of L per-client draws: at 47B params, L materialized noise pytrees
would not fit HBM, and the MSE analysis only sees the mean.  (DESIGN.md §7.)
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

try:                                    # jax >= 0.4.x moved this around
    _shard_map = jax.shard_map
except AttributeError:                  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from repro import rng_key
from repro.configs.base import GFLConfig, InputShape, ModelConfig
from repro.core.privacy.mechanism import RoundContext, mechanism_for
from repro.core.topology import combination_matrix
from repro.launch import sharding as shd
from repro.launch.mesh import num_servers, server_axes
from repro.models import Model
from repro.optim.clip import clip_by_global_norm


class TrainState(NamedTuple):
    params: dict
    step: jax.Array
    key: jax.Array


# ---------------------------------------------------------------------------
# combine implementations
# ---------------------------------------------------------------------------


def _kernel_dense_combine(A, psi, g):
    """Dense combine routed through the fused graph-combine Pallas kernel
    (eq. 8 + 24): each leaf is flattened to [P, D] and streamed through
    :func:`repro.kernels.ops.graph_combine` — one HBM pass per leaf instead
    of the gather -> noise-add -> einsum -> subtract chain.  Only the
    cancelling (graph-homomorphic) noise structure maps onto the kernel;
    ``make_train_step`` falls back to the einsum for everything else."""
    from repro.kernels import ops as kops
    Pn = jax.tree_util.tree_leaves(psi)[0].shape[0]

    def mix(x, noise):
        flat = kops.graph_combine(
            A, x.reshape(Pn, -1),
            None if noise is None else noise.reshape(Pn, -1))
        return flat.reshape(x.shape).astype(x.dtype)

    if g is None:
        return jax.tree.map(lambda x: mix(x, None), psi)
    return jax.tree.map(mix, psi, g)


def _dense_combine(A, psi, g, cancel: bool = True):
    """einsum baseline: w_p = sum_m A[m,p] psi_m + (A^T g)_p [- g_p].

    `cancel` applies the graph-homomorphic self-subtraction (eq. 24); it is
    driven by the mechanism's ``noise_profile().server_cancels_exactly``.
    """
    def mix(x, noise):
        mixed = jnp.einsum("mp,m...->p...", A.astype(jnp.float32),
                           (x + noise).astype(jnp.float32))
        if cancel:
            mixed = mixed - noise.astype(jnp.float32)
        return mixed.astype(x.dtype)
    if g is None:
        return jax.tree.map(
            lambda x: jnp.einsum("mp,m...->p...", A.astype(jnp.float32),
                                 x.astype(jnp.float32)).astype(x.dtype), psi)
    return jax.tree.map(mix, psi, g)


def _make_shardmap_combine(mesh, cfg: ModelConfig, gfl: GFLConfig,
                           params_like):
    """shard_map ring-rotation / sparse combine over the server axes.

    Works per-leaf: each device holds its server's model-parallel shard of
    psi_p (+ its own noise g_p); rotating collective_permutes bring every
    other server's (psi_m + g_m) past each device, which accumulates
    a_mp-weighted contributions.  For `sparse` + ring graphs only the two
    neighbour exchanges run.

    The combination matrix is a replicated runtime ARGUMENT of the returned
    callable (weights are gathered per rotation step), so per-round
    effective matrices from the resilience runtime slot straight in: a dead
    link is a zero-weight permute.
    """
    saxes = server_axes(mesh)
    Pn = num_servers(mesh)

    leaf_paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(params_like)[0]
    ]
    treedef = jax.tree_util.tree_structure(params_like)
    model_axis = None if gfl.client_parallel else "model"
    specs = jax.tree_util.tree_unflatten(treedef, [
        shd.param_spec(ps, cfg, stacked=True, server_axes=saxes,
                       model_axis=model_axis)
        for ps in leaf_paths
    ])

    def my_server_idx():
        if len(saxes) == 1:
            return jax.lax.axis_index(saxes[0])
        # pod-major flattening: idx = pod * data_size + data
        return (jax.lax.axis_index(saxes[0]) * mesh.shape[saxes[1]]
                + jax.lax.axis_index(saxes[1]))

    def ring_perm():
        return [((i + 1) % Pn, i) for i in range(Pn)]  # recv from right

    def _rotate_combine_leaf(x, Aj):
        """x: local shard with leading server dim of size 1 (this server's
        psi_p + g_p).  Returns sum_m a_mp (psi_m + g_m) for this p.

        combine_wire="bf16": an optimization_barrier after every permute
        pins the rotating buffer to the parameter dtype — otherwise XLA
        hoists the f32 accumulation convert above the whole permute chain
        and doubles every wire transfer (§Perf hillclimb 1)."""
        p = my_server_idx()
        # combine_wire="bf16": accumulate in the param dtype so the leaf fn
        # contains NO converts for XLA to hoist — the permute chain stays at
        # 2 bytes/elem on the wire.  (An optimization_barrier variant keeps
        # f32 accumulation on TPU, but the CPU backend deletes barriers and
        # upcasts the chain — measured in EXPERIMENTS.md §Perf iter 1.)
        # combine_wire="f32": f32 accumulation, XLA upcasts the wire.
        wt = x.dtype if gfl.combine_wire == "bf16" else jnp.float32
        buf = x
        acc = (Aj[p, p].astype(wt) * x.astype(wt))
        for step in range(1, Pn):
            buf = jax.lax.ppermute(buf, saxes if len(saxes) > 1 else saxes[0],
                                   ring_perm())
            src = jnp.mod(p + step, Pn)   # after s left-rotations
            acc = acc + Aj[src, p].astype(wt) * buf.astype(wt)
        return acc.astype(x.dtype)

    def combine_fn(noisy_psi, Aj):
        return jax.tree.map(lambda x: _rotate_combine_leaf(x, Aj), noisy_psi)

    return _shard_map(combine_fn, mesh=mesh, in_specs=(specs, P()),
                         out_specs=specs)


def _make_sparse_combine(mesh, cfg: ModelConfig, gfl: GFLConfig,
                         params_like):
    """Neighbour-only combine for ring (1 server axis) / torus (pod x data).

    Collective bytes per leaf: deg * shard (vs (P-1) * shard for rotate).
    Requires A to be the Metropolis ring (single axis) or the product graph
    A_pod (x) A_ring (multi-pod).  On a single server axis the weights are
    gathered from the runtime A argument (so per-round effective matrices
    work: a dead neighbour link is a zero weight); the multi-pod product
    path derives its factor weights statically and therefore only supports
    the static base graph (make_train_step enforces this).
    """
    saxes = server_axes(mesh)
    Pn = num_servers(mesh)

    leaf_paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(params_like)[0]
    ]
    treedef = jax.tree_util.tree_structure(params_like)
    model_axis = None if gfl.client_parallel else "model"
    specs = jax.tree_util.tree_unflatten(treedef, [
        shd.param_spec(ps, cfg, stacked=True, server_axes=saxes,
                       model_axis=model_axis)
        for ps in leaf_paths
    ])

    def _combine_leaf(x, Aj):
        wt = x.dtype if gfl.combine_wire == "bf16" else jnp.float32
        if len(saxes) == 1:
            ax = saxes[0]
            n = mesh.shape[ax]
            p = jax.lax.axis_index(ax)
            left = jax.lax.ppermute(
                x, ax, [((i + 1) % n, i) for i in range(n)])
            acc = (Aj[p, p].astype(wt) * x.astype(wt)
                   + Aj[jnp.mod(p + 1, n), p].astype(wt) * left.astype(wt))
            if n > 2:  # on a 2-ring left == right: don't double-count
                right = jax.lax.ppermute(
                    x, ax, [((i - 1) % n, i) for i in range(n)])
                acc = acc + Aj[jnp.mod(p - 1, n), p].astype(wt) \
                    * right.astype(wt)
            return acc.astype(x.dtype)
        # product graph: mix along data ring, then along pod ring
        pod_ax, data_ax = saxes
        nd = mesh.shape[data_ax]
        npod = mesh.shape[pod_ax]
        # data-ring Metropolis weights for a ring of size nd
        from repro.core.topology import combination_matrix as _cm
        Ad = jnp.asarray(_cm("ring", nd), jnp.float32)
        Ap = jnp.asarray(_cm("ring", npod) if npod > 2
                         else np.full((2, 2), 0.5), jnp.float32)
        pd = jax.lax.axis_index(data_ax)
        left = jax.lax.ppermute(
            x, data_ax, [((i + 1) % nd, i) for i in range(nd)])
        acc = (Ad[pd, pd].astype(wt) * x.astype(wt)
               + Ad[jnp.mod(pd + 1, nd), pd].astype(wt) * left.astype(wt))
        if nd > 2:   # on a 2-ring left == right: don't double-count
            right = jax.lax.ppermute(
                x, data_ax, [((i - 1) % nd, i) for i in range(nd)])
            acc = acc + Ad[jnp.mod(pd - 1, nd), pd].astype(wt) \
                * right.astype(wt)
        y = acc.astype(x.dtype)          # data-mixed value, BEFORE pod mix:
        pp = jax.lax.axis_index(pod_ax)  # both pod permutes must carry y
        fwd = jax.lax.ppermute(
            y, pod_ax, [((i + 1) % npod, i) for i in range(npod)])
        acc = (Ap[pp, pp].astype(wt) * y.astype(wt)
               + Ap[jnp.mod(pp + 1, npod), pp].astype(wt)
               * fwd.astype(wt))
        if npod > 2:
            bwd = jax.lax.ppermute(
                y, pod_ax, [((i - 1) % npod, i) for i in range(npod)])
            acc = acc + Ap[jnp.mod(pp - 1, npod), pp].astype(wt) \
                * bwd.astype(wt)
        return acc.astype(x.dtype)

    def combine_fn(noisy_psi, Aj):
        return jax.tree.map(lambda x: _combine_leaf(x, Aj), noisy_psi)

    return _shard_map(combine_fn, mesh=mesh, in_specs=(specs, P()),
                         out_specs=specs)


def make_combination_matrix(mesh, gfl: GFLConfig) -> np.ndarray:
    """A for the mesh's server count; multi-pod uses the product graph
    A_pod (x) A_data so sparse combine factorizes over the two axes."""
    saxes = server_axes(mesh)
    if len(saxes) == 1:
        return combination_matrix(gfl.topology, mesh.shape[saxes[0]],
                                  rows=gfl.torus_rows, seed=gfl.topology_seed)
    npod = mesh.shape[saxes[0]]
    nd = mesh.shape[saxes[1]]
    Ad = combination_matrix(gfl.topology if gfl.topology != "torus" else "ring",
                            nd, seed=gfl.topology_seed)
    Ap = np.full((npod, npod), 1.0 / npod) if npod <= 2 \
        else combination_matrix("ring", npod)
    return np.kron(Ap, Ad)


def make_topology_process(mesh, gfl: GFLConfig):
    """The mesh run's fault process: per-round effective A_i + client
    participation masks over the mesh's base graph (product graph on
    multi-pod meshes).  Feed its realizations to the train step:

        proc = make_topology_process(mesh, gfl_cfg)
        real = proc.realize(step_idx)
        alive = (proc.client_alive(step_idx, L)
                 if proc.fault.client_dropout > 0 else None)
        state, metrics = step(state, batch, real.A, alive)
    """
    from repro.core.resilience import TopologyProcess
    return TopologyProcess(make_combination_matrix(mesh, gfl), gfl.fault,
                           seed=gfl.topology_seed)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_train_step(model: Model, gfl: GFLConfig, mesh,
                    clients: int = 4,
                    remat_policy: str | None = None) -> Callable:
    """Build the jit-able GFL train step.

    params leaves: [P_servers, ...]; batch leaves: [P_servers, L, b, ...].
    Returns (state, batch[, A, client_alive, cohort_weights]) -> (state,
    metrics).

    The trailing arguments are the resilience / population hooks (all
    optional; defaults reproduce the static path exactly): ``A`` overrides
    the base combination matrix with a per-round effective matrix from
    :func:`make_topology_process` (dead links become zero-weight entries /
    permutes), and ``client_alive`` ([P, L] mask) applies mid-round client
    dropout — the aggregate renormalizes over survivors, which is exactly
    the dropout-safe secure-agg semantics since the mesh computes the
    aggregate directly (masks cancel; see docs/resilience.md).

    ``cohort_weights`` ([P, L]) is the population engine's unbiased
    ``1/(K pi_k)`` cohort reweighting (docs/population.md): each client's
    gradient is scaled by its weight BEFORE the per-client clip (so the
    contribution stays inside the grad_bound sensitivity ball the privacy
    calibration assumes; heavy weights saturate) and before the server
    mean — a non-uniformly-sampled cohort (importance sampling,
    availability traces) estimates the population update without bias up
    to that clipping.  Like the resilience hooks it is a traced runtime
    argument — one compilation serves every round's cohort."""
    from repro.core.resilience import parse_fault_spec
    from repro.core.resilience.runtime import ensure_dropout_safe
    from repro.telemetry import telemetry_active, trace_span

    cfg = model.cfg
    with trace_span("make_combination_matrix", combine=gfl.combine_impl):
        A = make_combination_matrix(mesh, gfl)
    Pn = num_servers(mesh)
    Aj = jnp.asarray(A, jnp.float32)

    fault = parse_fault_spec(gfl.fault)
    if fault.straggler > 0:
        raise ValueError(
            "straggler faults are simulator-only for now (they need the "
            "per-server psi cache of repro.core.resilience.runtime); mesh "
            "fault specs support links/outage/dropout components")
    if (fault.perturbs_topology and gfl.combine_impl == "sparse"
            and len(server_axes(mesh)) > 1):
        raise ValueError(
            "sparse combine on a multi-pod mesh derives its product-graph "
            "weights statically and cannot apply per-round link faults; "
            "use combine_impl='rotate' (or 'dense') with fault specs")

    acc_dtype = jnp.dtype(gfl.grad_acc_dtype)

    def client_mean_grads(w_p, batch_p, alive_p=None, weights_p=None):
        """(6)+(7): scan over L clients; per-client clip to B; mean.

        ``alive_p`` ([L] 0/1, optional): dropped clients contribute nothing
        and the mean renormalizes over the survivor count.  ``weights_p``
        ([L], optional): cohort importance weights, applied BEFORE the
        per-client clip — the clipped contribution stays inside the
        grad_bound ball the privacy calibration assumes (heavy weights
        saturate instead of inflating sensitivity), and the mean stays
        over L — resp. the survivor count — so the 1/(K pi) estimator of
        docs/population.md is unbiased up to that clipping."""
        scaled = alive_p is not None or weights_p is not None

        def body(acc, xs):
            if scaled:
                client_batch, w, a = xs
            else:
                client_batch, w, a = xs, None, None
            (loss, aux), grads = jax.value_and_grad(model.loss, has_aux=True)(
                w_p, client_batch, remat_policy=remat_policy)
            if w is not None:
                grads = jax.tree.map(
                    lambda g: g * w.astype(g.dtype), grads)
            if gfl.grad_bound > 0:
                grads, _ = clip_by_global_norm(grads, gfl.grad_bound)
            if a is None:
                acc = jax.tree.map(
                    lambda c, g: c + g.astype(acc_dtype), acc, grads)
            else:
                acc = jax.tree.map(
                    lambda c, g: c + g.astype(acc_dtype) * a.astype(acc_dtype),
                    acc, grads)
                loss = loss * a
            return acc, loss

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype), w_p)
        L = jax.tree_util.tree_leaves(batch_p)[0].shape[0]
        if scaled:
            a = jnp.ones((L,)) if alive_p is None else alive_p
            w = jnp.ones((L,)) if weights_p is None else weights_p
            xs = (batch_p, w, a)
        else:
            xs = batch_p
        acc, losses = jax.lax.scan(body, zeros, xs)
        if alive_p is None:
            mean_g = jax.tree.map(lambda c: (c / L).astype(jnp.float32), acc)
            return mean_g, losses.mean()
        n = jnp.maximum(alive_p.sum(), 1.0).astype(acc_dtype)
        mean_g = jax.tree.map(lambda c: (c / n).astype(jnp.float32), acc)
        return mean_g, losses.sum() / n.astype(losses.dtype)

    def client_parallel_grads(params, batch, alive=None, weights=None):
        """Small-model mode (§Perf hillclimb 3): ALL (server, client) grads
        computed concurrently — the L client dim is sharded over the
        "model" axis (params are replicated over it), turning the idle TP
        ranks of a too-small model into data parallelism.  Per-client
        clipping (Assumption 3) is preserved."""
        saxes_ = server_axes(mesh)
        da = saxes_ if len(saxes_) > 1 else saxes_[0]

        def one_client(w_p, client_batch):
            (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
                w_p, client_batch, remat_policy=remat_policy)
            return grads, loss

        grads, losses = jax.vmap(lambda w_p, batch_p: jax.vmap(
            lambda cb: one_client(w_p, cb))(batch_p))(params, batch)
        # pin [P, L, ...] grads: P -> data axes, L -> model axis
        grads = jax.lax.with_sharding_constraint(
            grads, jax.tree.map(
                lambda g: NamedSharding(mesh, P(da, "model")), grads))
        if weights is not None:
            # cohort weights scale BEFORE the per-client clip (sensitivity
            # stays inside grad_bound — same ordering as client_mean_grads)
            wf = weights.astype(jnp.float32)
            grads = jax.tree.map(
                lambda g: g * wf.reshape(wf.shape + (1,) * (g.ndim - 2)
                                         ).astype(g.dtype), grads)
        if gfl.grad_bound > 0:
            # per-(server, client) global-norm clip over the param tree
            sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)),
                             axis=tuple(range(2, g.ndim)))
                     for g in jax.tree.leaves(grads))          # [P, L]
            coef = jnp.minimum(1.0, gfl.grad_bound
                               / jnp.maximum(jnp.sqrt(sq), 1e-12))
            grads = jax.tree.map(
                lambda g: (g * coef.reshape(coef.shape + (1,) * (g.ndim - 2))
                           .astype(g.dtype)), grads)
        if alive is None and weights is None:
            mean_g = jax.tree.map(
                lambda g: jnp.mean(g.astype(jnp.float32), axis=1), grads)
            return mean_g, losses.mean(axis=1)
        a = (jnp.ones(losses.shape, jnp.float32) if alive is None
             else alive.astype(jnp.float32))                  # [P, L]
        n = jnp.maximum(a.sum(axis=1), 1.0)                   # [P]
        mean_g = jax.tree.map(
            lambda g: (g.astype(jnp.float32)
                       * a.reshape(a.shape + (1,) * (g.ndim - 2))
                       ).sum(axis=1) / n.reshape((-1,) + (1,) * (g.ndim - 2)),
            grads)
        return mean_g, (losses * a).sum(axis=1) / n

    mech = mechanism_for(gfl)
    profile = mech.noise_profile()
    if fault.client_dropout > 0:
        ensure_dropout_safe(profile, where="mesh client dropout")

    def step_fn(state: TrainState, batch, A_round=None, client_alive=None,
                cohort_weights=None):
        key, k_noise, k_client = jax.random.split(state.key, 3)
        ctx = RoundContext(step=state.step)
        A_rt = Aj if A_round is None else jnp.asarray(A_round, jnp.float32)
        # the survivor-weighted / cohort-weighted mean is a DIFFERENT XLA
        # program (different fusion, ~1-ulp drift), so each is only traced
        # in when actually used — this keeps the zero-probability
        # resilience path and the uniform-cohort path bit-identical to the
        # static path
        alive = (None if client_alive is None or fault.client_dropout == 0
                 else jnp.asarray(client_alive, jnp.float32))
        weights = (None if cohort_weights is None
                   else jnp.asarray(cohort_weights, jnp.float32))

        # (6)+(7) per server, vmapped over the sharded server dim
        if gfl.client_parallel:
            mean_g, loss = client_parallel_grads(state.params, batch, alive,
                                                 weights)
        elif alive is None and weights is None:
            mean_g, loss = jax.vmap(client_mean_grads)(state.params, batch)
        elif weights is None:
            mean_g, loss = jax.vmap(client_mean_grads)(state.params, batch,
                                                       alive)
        elif alive is None:
            mean_g, loss = jax.vmap(
                lambda w_p, b_p, s_p: client_mean_grads(w_p, b_p, None, s_p)
            )(state.params, batch, weights)
        else:
            mean_g, loss = jax.vmap(client_mean_grads)(state.params, batch,
                                                       alive, weights)
        psi = jax.tree.map(
            lambda w, g: (w.astype(jnp.float32)
                          - gfl.mu * g).astype(w.dtype),
            state.params, mean_g)

        # client-level residual noise (mechanisms whose masks cancel
        # exactly return None; iid returns the variance-equivalent draw —
        # the O(mu^{-1}) term of Theorem 1).  Under dropout each server's
        # draw scales with ITS realized survivor count ([P] vector),
        # keeping the per-server 1/sqrt(L'_p) variance equivalence honest.
        if profile.client_sigma > 0:
            L = jax.tree_util.tree_leaves(batch)[0].shape[1]
            L_eff = (L if alive is None
                     else jnp.maximum(alive.sum(axis=1), 1.0))
            cg = mech.client_noise_tree(k_client, psi, L_eff, ctx)
            if cg is not None:
                psi = jax.tree.map(lambda x, n: x + n, psi, cg)

        # (8) with the mechanism's server-level noise
        g = (mech.combine_noise_tree(k_noise, psi, ctx)
             if profile.server_sigma > 0 else None)
        cancel = profile.server_cancels_exactly

        if gfl.combine_impl == "dense":
            # whole-run kernel switch: the cancelling noise structure maps
            # onto the fused Pallas combine (docs/kernels.md); iid (non-
            # cancelling) noise keeps the einsum's [P, P, D] edge draws
            if gfl.use_kernels and (g is None or cancel):
                new_params = _kernel_dense_combine(A_rt, psi, g)
            else:
                new_params = _dense_combine(A_rt, psi, g, cancel=cancel)
        else:
            maker = (_make_sparse_combine if gfl.combine_impl == "sparse"
                     else _make_shardmap_combine)
            combine = maker(mesh, cfg, gfl, state.params)
            if g is not None:
                # the rotating buffer carries (psi_m + g_m) exactly as the
                # wire protocol does; cancelling mechanisms subtract their
                # own g_p afterwards (eq. 24)
                noisy = jax.tree.map(lambda x, n: x + n, psi, g)
                mixed = combine(noisy, A_rt)
                if cancel:
                    new_params = jax.tree.map(
                        lambda m, n: (m.astype(jnp.float32)
                                      - n.astype(jnp.float32)).astype(m.dtype),
                        mixed, g)
                else:
                    new_params = mixed
            else:
                new_params = combine(psi, A_rt)

        metrics = {"loss": loss.mean(), "step": state.step}
        # read-only telemetry tap: the norm reductions are only traced in
        # when a session is active at build time — the step closure is
        # rebuilt per make_train_step call, so the off path compiles the
        # exact program it does today.  No io_callback here (callback
        # operands would fight SPMD sharding propagation on real meshes);
        # the launcher emits these host-side from the metrics dict.
        if telemetry_active():
            sq_upd = sq_par = jnp.zeros((), jnp.float32)
            for n, o in zip(jax.tree_util.tree_leaves(new_params),
                            jax.tree_util.tree_leaves(state.params)):
                d = n.astype(jnp.float32) - o.astype(jnp.float32)
                sq_upd = sq_upd + jnp.sum(d * d)
                sq_par = sq_par + jnp.sum(
                    n.astype(jnp.float32) * n.astype(jnp.float32))
            metrics["update_norm"] = jnp.sqrt(sq_upd)
            metrics["param_norm"] = jnp.sqrt(sq_par)
        return TrainState(new_params, state.step + 1, key), metrics

    return step_fn


def init_train_state(model: Model, gfl: GFLConfig, mesh, key) -> TrainState:
    """Per-server replicated init (all servers start from the same point)."""
    Pn = num_servers(mesh)
    params = model.init(key)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (Pn,) + x.shape), params)
    return TrainState(params, jnp.zeros((), jnp.int32), key)


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(model: Model) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch)
    return prefill


def make_decode_step(model: Model) -> Callable:
    def decode(params, tokens, cache):
        return model.decode_step(params, tokens, cache)
    return decode


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs for AOT lowering; no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def sanitize_spec(shape: tuple, spec: P, mesh) -> P:
    """Drop mesh axes from dims they don't divide evenly (e.g. phi3's
    2047-slot sliding-window ring cache can't be 16-way sequence-sharded).
    Explicit out_shardings require divisibility; replication is the safe
    fallback for such (always small) dims."""
    parts = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            parts.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        parts.append(entry if dim % size == 0 else None)
    return P(*parts)


def train_batch_shape(cfg: ModelConfig, shape: InputShape, n_servers: int,
                      clients: int = 4):
    """Leading dims [P, L, b] for the GFL batch."""
    per_server = shape.global_batch // n_servers
    L = min(clients, per_server)
    b = per_server // L
    return L, b


def input_specs(model: Model, shape: InputShape, mesh, *,
                gfl: GFLConfig | None = None, clients: int = 4) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    cfg = model.cfg
    S = shape.seq_len
    saxes = server_axes(mesh)

    def ns(spec):
        return NamedSharding(mesh, spec)

    if shape.kind == "train":
        Pn = num_servers(mesh)
        L, b = train_batch_shape(cfg, shape, Pn, clients)
        bspecs = shd.batch_specs(
            cfg, mesh, kind="train", gfl_train=True,
            client_parallel=bool(gfl and gfl.client_parallel))
        S_text = S - cfg.num_image_tokens if cfg.family == "vlm" else S
        batch = {
            "tokens": _sds((Pn, L, b, S_text), jnp.int32,
                           ns(bspecs["tokens"])),
            "labels": _sds((Pn, L, b, S_text), jnp.int32,
                           ns(bspecs["labels"])),
        }
        if cfg.family == "vlm":
            batch["image_embeds"] = _sds(
                (Pn, L, b, cfg.num_image_tokens, cfg.d_model),
                jnp.dtype(cfg.param_dtype), ns(bspecs["image_embeds"]))
        if cfg.family == "audio":
            batch["frames"] = _sds(
                (Pn, L, b, cfg.encoder_seq_len, cfg.d_model),
                jnp.dtype(cfg.param_dtype), ns(bspecs["frames"]))
        return batch

    B = shape.global_batch
    bspecs = shd.batch_specs(cfg, mesh, kind=shape.kind)
    if shape.kind == "prefill":
        S_text = S - cfg.num_image_tokens if cfg.family == "vlm" else S
        batch = {"tokens": _sds((B, S_text), jnp.int32, ns(bspecs["tokens"]))}
        if cfg.family == "vlm":
            batch["image_embeds"] = _sds(
                (B, cfg.num_image_tokens, cfg.d_model),
                jnp.dtype(cfg.param_dtype), ns(bspecs["image_embeds"]))
        if cfg.family == "audio":
            batch["frames"] = _sds((B, cfg.encoder_seq_len, cfg.d_model),
                                   jnp.dtype(cfg.param_dtype),
                                   ns(bspecs["frames"]))
        return batch

    # decode: tokens [B] + cache of S tokens
    shard_seq = B == 1
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    cspecs = shd.cache_specs(cfg, mesh, shard_seq=shard_seq)
    cache = {k: _sds(v.shape, v.dtype,
                     ns(sanitize_spec(v.shape, cspecs[k], mesh)))
             for k, v in cache.items()}
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    tok_spec = P(None) if B == 1 else P(da)
    return {
        "tokens": _sds((B,), jnp.int32, ns(tok_spec)),
        "cache": cache,
    }


def params_specs(model: Model, mesh, *, gfl_train: bool,
                 client_parallel: bool = False) -> tuple:
    """(ShapeDtypeStruct pytree, NamedSharding pytree) for the params."""
    cfg = model.cfg
    saxes = server_axes(mesh) if gfl_train else None
    shapes = jax.eval_shape(lambda k: model.init(k), rng_key())
    if gfl_train:
        Pn = num_servers(mesh)
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((Pn,) + s.shape, s.dtype), shapes)
    shardings = shd.params_shardings(
        shapes, cfg, mesh, server_axes=saxes,
        model_axis=None if client_parallel else "model")
    sds = jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(
        s.shape, s.dtype, sharding=sh), shapes, shardings)
    return sds, shardings
