"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing
jax; everything else sees the real device count.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU integration tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes)


def server_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that carry the GFL server dimension."""
    return tuple(a for a in mesh.axis_names if a != "model")


def num_servers(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in server_axes(mesh)]))
