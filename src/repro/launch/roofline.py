"""Roofline analysis from AOT-compiled artifacts (no hardware execution).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * LINK_BW)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (whole-program,
already accounting for SPMD partitioning: XLA reports per-module costs for
the partitioned module).  collective_bytes is parsed from the optimized HLO
text: the sum of operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (x trip count when the
op sits inside a while loop body executed `trip` times, conservatively
estimated from scan trip counts is NOT attempted — scans over layers carry
their collectives in the body ONCE in the text but execute L times, so we
scale body collectives by the enclosing loop trip count when it is
statically printed in the loop's backend_config/attributes; otherwise we
report the unscaled sum and flag it).

Hardware constants: TPU v5e-class chip.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of all array literals in an HLO type string like
    '(f32[16,128], u32[2])' or 'bf16[8,1024]{1,0}'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)   # op -> count
    bytes_by_op: dict = field(default_factory=dict)
    total_bytes: int = 0
    in_loop_bytes: int = 0   # collectives inside while bodies (unscaled)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of collective ops in optimized HLO text."""
    stats = CollectiveStats()
    in_loop_depth = 0
    for raw in hlo_text.splitlines():
        line = raw.strip()
        # crude while-body tracking: body computations are separate HLO
        # computations named e.g. %while_body_xx; collectives inside them
        # execute trip-count times.  We tag by computation name.
        if line.startswith(("while_body", "%while_body", "body_")):
            pass
        m = re.search(r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\][^ ]*)\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)", line)
        if not m:
            continue
        out_shape, op = m.groups()
        # operand bytes: parse the operand list inside (...) after op name
        args = line[m.end():]
        # operand types are not printed inline; use output size as proxy for
        # permute/all-reduce (same size), all-gather output = P*input -> use
        # output, reduce-scatter output = input/P -> scale by P unknown; we
        # use max(output, input-ish) = output size which is the wire size
        # for gather and an undercount for scatter by definition of operand.
        b = shape_bytes(out_shape)
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b
        stats.total_bytes += b
    return stats


def parse_collectives_scaled(hlo_text: str) -> CollectiveStats:
    """Like parse_collectives but scales collectives that live inside while
    bodies by the loop trip count when XLA printed it
    (`known_trip_count={n=K}`) — scan-over-layers makes this matter."""
    # map computation name -> trip count from call sites
    trip = {}
    for m in re.finditer(
            r"while\(.*?\).*?body=%?([\w.\-]+).*?known_trip_count=\{n=(\d+)\}",
            hlo_text):
        trip[m.group(1)] = int(m.group(2))
    # also reverse attribute order
    for m in re.finditer(
            r"known_trip_count=\{n=(\d+)\}.*?body=%?([\w.\-]+)", hlo_text):
        trip[m.group(2)] = int(m.group(1))

    stats = CollectiveStats()
    current_comp = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        cm = re.match(r"%?([\w.\-]+)\s*(\([^)]*\))?\s*->.*\{$", line.strip())
        if line and not line.startswith(" ") and "{" in line:
            nm = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", line.strip())
            if nm:
                current_comp = nm.group(1)
        m = re.search(r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\][^ ]*)\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)", line)
        if not m:
            continue
        out_shape, op = m.groups()
        b = shape_bytes(out_shape)
        scale = trip.get(current_comp, 1)
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b * scale
        stats.total_bytes += b * scale
        if scale > 1:
            stats.in_loop_bytes += b * scale
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flop_frac: float = 0.0
    collective_detail: dict = field(default_factory=dict)
    memory_per_device: dict = field(default_factory=dict)

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hlo_bytes / (self.chips * HBM_BW)
        self.collective_s = self.collective_bytes / (self.chips * LINK_BW)
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_flop_frac = (self.model_flops / self.hlo_flops
                                 if self.hlo_flops else 0.0)
        return self

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def round_pipeline_traffic(P: int, L: int, D: int, *, itemsize: int = 4,
                           mode: str = "mask", fused: bool = True) -> dict:
    """Analytic HBM bytes of one GFL round's client fold + combine.

    Counts reads/writes of every materialized tensor in the chain over the
    ``[P, L, D]`` gradients (itemized so the docs table and the kernel
    bench agree on the accounting).  ``mode`` is the mechanism's client
    level: "none" | "mask" (secure-agg, generated in-VMEM when fused) |
    "laplace" (iid noise, pre-drawn and streamed once when fused).

    Reference chain (each XLA op re-reads its operand from HBM):
      norms, scale+update, noise materialize+add (noised modes), fold,
      combine.  Fused pipeline (repro.kernels.round_fold + graph_combine):
      a norms pass, one scale/noise/fold pass, and the fused combine —
      in "laplace" mode the parity-preserving pre-drawn noise operand
      costs one extra HBM write + read (counted honestly on BOTH sides;
      "mask" noise is generated in-VMEM and costs nothing).

    Besides byte totals, ``pld_passes`` counts the gradient-scale
    ([P, L, D]) HBM round trips — the quantity that dominates at model
    scale, where the [P, D]-order terms vanish: 8 for the reference chain,
    2 fused ("none"/"mask"), 4 fused ("laplace", incl. the noise
    write+read).
    """
    PLD = P * L * D * itemsize
    PD = P * D * itemsize
    if fused:
        terms = {
            "norms_pass_read": PLD,
            "fold_pass_read": PLD + PD,                    # grads + base w
            # parity-preserving pre-drawn noise: sampler writes the
            # [P, L, D] operand, the fold pass streams it back
            "noise_materialize": PLD if mode == "laplace" else 0,
            "noise_stream": PLD if mode == "laplace" else 0,
            "psi_write": PD,
            "combine": 3 * PD if mode == "none" else 4 * PD,
        }
        passes = {"none": 2, "mask": 2, "laplace": 4}[mode]
    else:
        noised = mode != "none"
        terms = {
            "norms_pass_read": PLD,
            "update_read_write": 2 * PLD + PD,
            "noise_materialize": PLD if noised else 0,
            "noise_add": 3 * PLD if noised else 0,
            "fold_read": PLD,
            "psi_write": PD,
            "combine": 3 * PD if mode == "none" else 4 * PD,
        }
        passes = 8 if noised else 4
    terms["total"] = sum(terms.values())
    terms["pld_passes"] = passes
    return terms


def model_flops_estimate(n_params: int, n_active_params: int, tokens: int,
                         kind: str) -> float:
    """6*N*D for training, 2*N*D for inference forward (per step)."""
    n = n_active_params or n_params
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def active_params(cfg, n_params: int) -> int:
    """Active (per-token) params for MoE archs; == n_params for dense."""
    if cfg.moe is None:
        return n_params
    m = cfg.moe
    dff = m.expert_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * dff
    routed_total = m.num_experts * per_expert * (
        cfg.num_layers - m.first_dense_layers)
    routed_active = m.top_k * per_expert * (
        cfg.num_layers - m.first_dense_layers)
    return n_params - routed_total + routed_active
