"""Serving launcher: batched prefill + decode of any --arch on a mesh.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --reduced --mesh 2x4 --batch 4 --prompt-len 64 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import rng_key
from repro.configs.registry import get_config
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.train import parse_mesh
from repro.models import Model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="2x4")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    mesh = make_production_mesh() if args.mesh == "production" \
        else parse_mesh(args.mesh)
    key = rng_key()

    with mesh:
        params = model.init(key)
        batch = {"tokens": jax.random.randint(
            jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size)}
        if cfg.family == "vlm":
            batch["image_embeds"] = 0.1 * jax.random.normal(
                jax.random.fold_in(key, 2),
                (args.batch, cfg.num_image_tokens, cfg.d_model))
        if cfg.family == "audio":
            batch["frames"] = 0.1 * jax.random.normal(
                jax.random.fold_in(key, 2),
                (args.batch, cfg.encoder_seq_len, cfg.d_model))
        prefill = jax.jit(model.prefill)
        decode = jax.jit(model.decode_step)
        t0 = time.time()
        logits, cache = prefill(params, batch)
        toks = jnp.argmax(logits, -1)
        print(f"prefill {args.batch}x{args.prompt_len} in "
              f"{(time.time()-t0)*1e3:.0f} ms")
        t0 = time.time()
        for _ in range(args.new_tokens):
            logits, cache = decode(params, toks, cache)
            toks = jnp.argmax(logits, -1)
        jax.block_until_ready(toks)
        dt = time.time() - t0
        n = args.batch * args.new_tokens
        print(f"decoded {n} tokens in {dt*1e3:.0f} ms ({n/dt:.0f} tok/s)")
        assert np.isfinite(np.asarray(logits, np.float32)).all()


if __name__ == "__main__":
    main()
