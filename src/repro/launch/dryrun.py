import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) combo.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM and unsupported collectives all fail here.
Produces one JSON per combo with memory analysis, cost analysis and the
parsed collective schedule for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k [--multi-pod] [--combine rotate|sparse|dense]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import rng_key
from repro.configs.base import GFLConfig, INPUT_SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import roofline as rl
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh, num_servers
from repro.models import Model

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "dryrun_results")

# long_500k requires sub-quadratic attention (DESIGN.md §4):
LONG_OK = {"zamba2-1.2b", "rwkv6-3b", "mixtral-8x7b",
           "llava-next-mistral-7b", "phi3-mini-3.8b"}
LONG_SKIP_REASON = {
    "yi-6b": "pure full attention (no windowed variant in source model)",
    "smollm-135m": "pure full attention",
    "minicpm3-4b": "MLA full attention",
    "deepseek-v2-lite-16b": "MLA full attention (compressed cache, "
                            "still O(S) full-attn)",
    "whisper-tiny": "enc-dec with 448-token decoder; 500k decode meaningless",
}


def default_gfl(combine: str, **over) -> GFLConfig:
    return GFLConfig(topology="ring", privacy="hybrid", sigma_g=0.2,
                     grad_bound=10.0, mu=0.1, combine_impl=combine, **over)


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                combine: str = "sparse", donate: bool = True,
                clients: int = 4, gfl_over: dict | None = None,
                moe_dispatch: str | None = None,
                remat_policy: str | None = None):
    """Lower + compile one combo; returns (compiled, lowered, meta)."""
    import dataclasses
    cfg = get_config(arch)
    if moe_dispatch and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch=moe_dispatch))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    gfl = default_gfl(combine, **(gfl_over or {}))

    with mesh:
        if shape.kind == "train":
            step_fn = steps_lib.make_train_step(model, gfl, mesh,
                                                clients=clients,
                                                remat_policy=remat_policy)
            p_sds, p_shard = steps_lib.params_specs(
                model, mesh, gfl_train=True,
                client_parallel=gfl.client_parallel)
            state = steps_lib.TrainState(
                p_sds, jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            batch = steps_lib.input_specs(model, shape, mesh, gfl=gfl,
                                          clients=clients)
            out_sh = (steps_lib.TrainState(p_shard, None, None), None)
            jitted = jax.jit(step_fn, out_shardings=out_sh,
                             donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            p_sds, p_shard = steps_lib.params_specs(model, mesh,
                                                    gfl_train=False)
            batch = steps_lib.input_specs(model, shape, mesh)
            fn = steps_lib.make_prefill_step(model)
            jitted = jax.jit(fn)
            lowered = jitted.lower(p_sds, batch)
        else:  # decode
            p_sds, p_shard = steps_lib.params_specs(model, mesh,
                                                    gfl_train=False)
            specs = steps_lib.input_specs(model, shape, mesh)
            fn = steps_lib.make_decode_step(model)
            cache_sh = {k: v.sharding for k, v in specs["cache"].items()}
            jitted = jax.jit(fn, out_shardings=(None, cache_sh),
                             donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(p_sds, specs["tokens"], specs["cache"])
        compiled = lowered.compile()
    return compiled, lowered, {"cfg": cfg, "mesh": mesh, "shape": shape,
                               "model": model}


def analyze(compiled, lowered, meta, *, arch, shape_name, multi_pod,
            combine) -> dict:
    from repro.launch.hlo_analysis import analyze_hlo

    cfg, mesh, shape = meta["cfg"], meta["mesh"], meta["shape"]
    chips = int(np.prod(list(mesh.shape.values())))
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):    # older jax returns [dict]
        cost = cost[0] if cost else {}

    hlo = compiled.as_text()
    # loop-scaled static analysis (cost_analysis counts while bodies once);
    # quantities are per-device for the SPMD-partitioned module.
    st = analyze_hlo(hlo)
    flops = st.flops * chips          # global-equivalent (replication shows
    byts = st.hbm_bytes * chips       #  up as inflated totals — intended)

    mem = compiled.memory_analysis()
    memd = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if mem is not None and hasattr(mem, attr):
            memd[attr] = int(getattr(mem, attr))

    shapes = jax.eval_shape(lambda k: Model(cfg).init(k), rng_key())
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    n_active = rl.active_params(cfg, n_params)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
    else:
        tokens = shape.global_batch  # one token per sequence
    mflops = rl.model_flops_estimate(
        n_params, n_active, tokens,
        "train" if shape.kind == "train" else "serve")

    roof = rl.Roofline(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16", chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        collective_bytes=float(st.collective_bytes) * chips,
        model_flops=mflops,
        collective_detail={"counts": st.collective_counts,
                           "bytes_by_op": st.collective_bytes_by_op,
                           "unknown_trip_loops": st.unknown_trip_loops},
        memory_per_device=memd,
    ).finalize()
    out = json.loads(roof.to_json())
    out["n_params"] = n_params
    out["n_active_params"] = n_active
    out["dot_flops_per_device"] = st.dot_flops
    out["cost_analysis_flops_unscaled"] = float(cost.get("flops", 0.0))
    out["combine"] = combine if shape.kind == "train" else None
    out["kind"] = shape.kind
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            combine: str = "sparse", save: bool = True,
            clients: int = 4, gfl_over: dict | None = None,
            moe_dispatch: str | None = None, variant: str = "",
            remat_policy: str | None = None) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}_{shape_name}_{mesh_name}_{combine}"
    if variant:
        tag += f"_{variant}"
    if shape_name == "long_500k" and arch not in LONG_OK:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skip": LONG_SKIP_REASON.get(arch, "full attention"),
               "combine": combine}
        if save:
            _save(tag, rec)
        print(f"SKIP {tag}: {rec['skip']}")
        return rec
    t0 = time.time()
    compiled, lowered, meta = lower_combo(arch, shape_name,
                                          multi_pod=multi_pod,
                                          combine=combine, clients=clients,
                                          gfl_over=gfl_over,
                                          moe_dispatch=moe_dispatch,
                                          remat_policy=remat_policy)
    rec = analyze(compiled, lowered, meta, arch=arch, shape_name=shape_name,
                  multi_pod=multi_pod, combine=combine)
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["variant"] = variant
    # keep printing what the assignment asks for
    ma = compiled.memory_analysis()
    print(f"OK {tag}: compile={rec['compile_s']}s "
          f"flops={rec['hlo_flops']:.3e} bytes={rec['hlo_bytes']:.3e} "
          f"coll={rec['collective_bytes']:.3e} "
          f"bottleneck={rec['bottleneck']}")
    if save:
        _save(tag, rec)
    del compiled, lowered
    return rec


def _save(tag: str, rec: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--combine", default="sparse",
                    choices=["sparse", "rotate", "dense"])
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args(argv)

    archs = [a for a in ARCH_IDS if a != "gfl-logreg"] \
        if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, multi_pod=mp, combine=args.combine)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"FAIL {arch}/{shape}/mp={mp}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
