"""Importance sampling of clients (Rizk, Vlaski & Sayed [22], [23]).

The GFL paper's authors' companion work replaces uniform client sampling
with probabilities proportional to client gradient norms, with unbiased
1/(L pi_k) reweighting in the aggregate.  We implement the practical
variant: probabilities from running estimates of per-client gradient norms
(updated whenever a client participates), floored for exploration.

    pi_k  proportional to  max(||g_k|| estimate, floor)
    psi_p = w_p - mu * (1/L) sum_{k in L_p} g_k / (K pi_k)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ISState(NamedTuple):
    norm_est: jax.Array    # [P, K] running gradient-norm estimates
    counts: jax.Array      # [P, K] participation counts


def init_is_state(P: int, K: int) -> ISState:
    return ISState(jnp.ones((P, K)), jnp.zeros((P, K), jnp.int32))


def sampling_probs(state: ISState, floor: float = 0.1) -> jax.Array:
    """[P, K] client-sampling probabilities (sum to 1 per server)."""
    est = jnp.maximum(state.norm_est, floor * state.norm_est.mean(
        axis=1, keepdims=True))
    return est / est.sum(axis=1, keepdims=True)


def sample_clients(key: jax.Array, probs: jax.Array, L: int) -> jax.Array:
    """[P, L] client indices, sampled WITH replacement per [23] (keeps the
    importance weights unbiased)."""
    P, K = probs.shape

    def pick(k, p):
        return jax.random.choice(k, K, (L,), replace=True, p=p)

    return jax.vmap(pick)(jax.random.split(key, P), probs)


def importance_weights(probs: jax.Array, idx: jax.Array) -> jax.Array:
    """[P, L] unbiased reweighting 1/(K pi_k) for the sampled clients."""
    K = probs.shape[1]
    pi = jnp.take_along_axis(probs, idx, axis=1)
    return 1.0 / (K * jnp.maximum(pi, 1e-9))


def update_norm_estimates(state: ISState, idx: jax.Array,
                          grad_norms: jax.Array, decay: float = 0.7
                          ) -> ISState:
    """EMA-update the estimates of the clients that participated.

    idx: [P, L] sampled indices; grad_norms: [P, L] observed norms."""
    P, L = idx.shape

    def upd(est_row, cnt_row, idx_row, nrm_row):
        new_est = est_row.at[idx_row].set(
            decay * est_row[idx_row] + (1 - decay) * nrm_row)
        new_cnt = cnt_row.at[idx_row].add(1)
        return new_est, new_cnt

    est, cnt = jax.vmap(upd)(state.norm_est, state.counts, idx, grad_norms)
    return ISState(est, cnt)
