"""Importance sampling of clients (Rizk, Vlaski & Sayed [22], [23]).

The GFL paper's authors' companion work replaces uniform client sampling
with probabilities proportional to client gradient norms, with unbiased
1/(L pi_k) reweighting in the aggregate.  We implement the practical
variant: probabilities from running estimates of per-client gradient norms
(updated whenever a client participates), floored for exploration.

    pi_k  proportional to  max(||g_k|| estimate, floor)
    psi_p = w_p - mu * (1/L) sum_{k in L_p} g_k / (K pi_k)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ISState(NamedTuple):
    norm_est: jax.Array    # [P, K] running gradient-norm estimates
    counts: jax.Array      # [P, K] participation counts


def init_is_state(P: int, K: int) -> ISState:
    return ISState(jnp.ones((P, K)), jnp.zeros((P, K), jnp.int32))


_EST_CAP = 1e6   # gradient-norm estimates above this are runaway values
                 # (clipped so a single inf/overflow cannot zero out every
                 # other client's probability)


def sampling_probs(state: ISState, floor: float = 0.1) -> jax.Array:
    """[P, K] client-sampling probabilities (sum to 1 per server).

    Robust to degenerate estimates: NaNs are treated as the unit prior,
    infs are clipped to ``_EST_CAP``, and the exploration floor is lower
    bounded away from zero so an all-zero row degrades to the uniform
    distribution instead of 0/0.  Rows are always valid distributions
    (property-tested in tests/test_sampling.py)."""
    est = jnp.nan_to_num(state.norm_est, nan=1.0, posinf=_EST_CAP,
                         neginf=0.0)
    est = jnp.clip(est, 0.0, _EST_CAP)
    est = jnp.maximum(est, jnp.maximum(
        floor * est.mean(axis=1, keepdims=True), 1e-12))
    return est / est.sum(axis=1, keepdims=True)


def sample_clients(key: jax.Array, probs: jax.Array, L: int) -> jax.Array:
    """[P, L] client indices, sampled WITH replacement per [23] (keeps the
    importance weights unbiased)."""
    P, K = probs.shape

    def pick(k, p):
        return jax.random.choice(k, K, (L,), replace=True, p=p)

    return jax.vmap(pick)(jax.random.split(key, P), probs)


def importance_weights(probs: jax.Array, idx: jax.Array,
                       k_norm=None) -> jax.Array:
    """[P, L] unbiased reweighting 1/(K pi_k) for the sampled clients.

    ``k_norm`` overrides the normalizing population size (scalar or [P]):
    under an availability trace only K_avail clients are samplable, and the
    unbiased target is the mean over the *available* population —
    E[(1/L) sum_i g_{k_i} / (K_avail pi_{k_i})] = (1/K_avail) sum_avail g_k.
    """
    K = probs.shape[1] if k_norm is None else k_norm
    K = jnp.reshape(jnp.asarray(K, probs.dtype), (-1, 1)) \
        if jnp.ndim(K) == 1 else K
    pi = jnp.take_along_axis(probs, idx, axis=1)
    return 1.0 / (K * jnp.maximum(pi, 1e-9))


def update_norm_estimates(state: ISState, idx: jax.Array,
                          grad_norms: jax.Array, decay: float = 0.7
                          ) -> ISState:
    """EMA-update the estimates of the clients that participated.

    idx: [P, L] sampled indices; grad_norms: [P, L] observed norms."""
    P, L = idx.shape

    def upd(est_row, cnt_row, idx_row, nrm_row):
        new_est = est_row.at[idx_row].set(
            decay * est_row[idx_row] + (1 - decay) * nrm_row)
        new_cnt = cnt_row.at[idx_row].add(1)
        return new_est, new_cnt

    est, cnt = jax.vmap(upd)(state.norm_est, state.counts, idx, grad_norms)
    return ISState(est, cnt)
