"""Central registry of the spec-string grammars.

Every config surface in this repo that is a compact spec string —
faults, availability traces, cohort schedules, client populations,
event-layer latency/async specs — has a ``parse`` function and a
``to_spec`` inverse.  Before this module they lived scattered across
``resilience/faults.py``, ``population/cohort.py``,
``population/population.py`` and ``events/spec.py``; the registry maps
``name -> (parse, to_spec, examples)`` so tooling can *enumerate* the
grammars: gflint's GFL005 checks every parser is registered, and the
round-trip tests drive :func:`all_grammars` so a newly registered
grammar is inverse-tested automatically.

Round-trip law (canonical-form, both directions)::

    parse(to_spec(parse(s))) == parse(s)     for every valid spec s
    to_spec(parse(c)) == c                   for canonical c = to_spec(...)
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Sequence

from repro.core.events.spec import (parse_async_spec, parse_latency_spec)
from repro.core.fleet.spec import parse_fleet_spec
from repro.core.population.cohort import (cohort_to_spec,
                                          parse_cohort_spec,
                                          parse_trace_spec)
from repro.core.population.population import (parse_population_spec,
                                              population_to_spec)
from repro.core.resilience.faults import parse_fault_spec
from repro.telemetry.watch import parse_watch_spec, watch_to_spec


class SpecGrammar(NamedTuple):
    """One spec-string grammar: a parse/serialize pair plus canonical
    example specs (used by the registry-driven round-trip tests)."""
    name: str
    parse: Callable[[str], object]
    to_spec: Callable[[object], str]
    examples: Sequence[str]


_REGISTRY: Dict[str, SpecGrammar] = {}


def register_grammar(name: str, parse, to_spec, examples=()) -> SpecGrammar:
    if name in _REGISTRY:
        raise ValueError(f"spec grammar {name!r} already registered")
    g = SpecGrammar(name, parse, to_spec, tuple(examples))
    _REGISTRY[name] = g
    return g


def get_grammar(name: str) -> SpecGrammar:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown spec grammar {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def all_grammars() -> Dict[str, SpecGrammar]:
    return dict(_REGISTRY)


register_grammar(
    "fault", parse_fault_spec, lambda m: m.to_spec(),
    examples=("none", "links:0.1", "links:0.1+dropout:0.2",
              "outage:0.05,kill=1",
              "straggler:0.3,stale=2+dropout:0.1"))

register_grammar(
    "trace", parse_trace_spec, lambda t: t.to_spec(),
    examples=("always", "diurnal,period=24,min=0.2",
              "devclass,slow=0.5,p=0.3"))

# parse_cohort_spec returns the (sampler, floor, trace) tuple the
# scheduler consumes; the serializer takes the same tuple back
register_grammar(
    "cohort", parse_cohort_spec, lambda t: cohort_to_spec(*t),
    examples=("uniform", "importance,floor=0.2",
              "uniform+trace:diurnal,period=24,min=0.2",
              "importance,floor=0.05+trace:devclass,slow=0.5,p=0.3"))

register_grammar(
    "population", parse_population_spec, population_to_spec,
    examples=("dense", "synthetic:iid,sigma=1.0",
              "synthetic:hetero,hi=1.5,lo=0.5",
              "synthetic:mixture,clusters=4,drift=0.5",
              "dirichlet:0.3,pool=4000"))

register_grammar(
    "latency", parse_latency_spec, lambda ls: ls.to_spec(),
    examples=("zero", "fixed:2", "exp:1.5", "lognorm:0.5"))

# "none" -> None is part of the async grammar: an absent event layer
# round-trips through the same channel as a configured one
register_grammar(
    "async", parse_async_spec,
    lambda a: "none" if a is None else a.to_spec(),
    examples=("none", "async:buffer=8,latency=lognorm:0.5,max_stale=4",
              "async:buffer=4,latency=fixed:2,alpha=0.5"))

# multi-process fleet deployments (core/fleet): transport substrate,
# retry/backoff budget, heartbeat cadence, checkpoint cadence
register_grammar(
    "fleet", parse_fleet_spec, lambda s: s.to_spec(),
    examples=("fleet", "fleet:transport=filelog",
              "fleet:transport=socket,retry=3,timeout=2.0,backoff=exp",
              "fleet:retry=5,timeout=0.5,backoff=const,heartbeat=0.2,"
              "ckpt_every=2"))

# live-monitor alert rules (telemetry/watch.py): eps-budget exhaustion,
# spectral-gap collapse, NaN trajectories, exploding norms, staleness,
# throughput drop vs trailing window
register_grammar(
    "watch", parse_watch_spec, watch_to_spec,
    examples=("nan", "eps:0.9,target=4", "gap:0.05+nan+norm:100",
              "stale:4+throughput:0.5,window=20", "restart:2+nan"))
