"""Laplace noise utilities.

The paper's server perturbations are Laplace: ``g_{p,i} ~ Lap(0, sigma_g/sqrt(2))``
so that the *variance* is ``sigma_g**2`` (Var[Lap(0,b)] = 2 b^2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def laplace_from_uniform(u: jax.Array, scale) -> jax.Array:
    """Inverse-CDF transform: u in (-1/2, 1/2) -> Lap(0, scale).

    This is the pure-jnp oracle mirrored by the Pallas kernel
    (:mod:`repro.kernels.laplace`).
    """
    u = jnp.asarray(u)
    return -scale * jnp.sign(u) * jnp.log1p(-2.0 * jnp.abs(u))


def sample_laplace(key: jax.Array, shape, sigma, dtype=jnp.float32) -> jax.Array:
    """Sample Lap(0, sigma/sqrt(2)) i.e. variance sigma**2."""
    b = sigma / jnp.sqrt(2.0)
    u = jax.random.uniform(key, shape, dtype=dtype,
                           minval=-0.5 + 1e-7, maxval=0.5 - 1e-7)
    return laplace_from_uniform(u, jnp.asarray(b, dtype))
