"""Additive-noise sampling utilities (Laplace and Gaussian).

The paper's server perturbations are Laplace: ``g_{p,i} ~ Lap(0, sigma_g/sqrt(2))``
so that the *variance* is ``sigma_g**2`` (Var[Lap(0,b)] = 2 b^2).  The
Gaussian-DP mechanism draws ``N(0, sigma_g**2)`` instead; both samplers are
normalized so ``sigma`` is the standard deviation, which is the quantity the
MSE analysis (Theorem 1) sees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def laplace_from_uniform(u: jax.Array, scale) -> jax.Array:
    """Inverse-CDF transform: u in (-1/2, 1/2) -> Lap(0, scale).

    This is the pure-jnp oracle mirrored by the Pallas kernel
    (:mod:`repro.kernels.laplace`).
    """
    u = jnp.asarray(u)
    return -scale * jnp.sign(u) * jnp.log1p(-2.0 * jnp.abs(u))


def sample_laplace(key: jax.Array, shape, sigma, dtype=jnp.float32) -> jax.Array:
    """Sample Lap(0, sigma/sqrt(2)) i.e. variance sigma**2."""
    b = sigma / jnp.sqrt(2.0)
    u = jax.random.uniform(key, shape, dtype=dtype,
                           minval=-0.5 + 1e-7, maxval=0.5 - 1e-7)
    return laplace_from_uniform(u, jnp.asarray(b, dtype))


def sample_gaussian(key: jax.Array, shape, sigma, dtype=jnp.float32
                    ) -> jax.Array:
    """Sample N(0, sigma**2) — same std normalization as sample_laplace."""
    return jax.random.normal(key, shape, dtype=dtype) * jnp.asarray(
        sigma, dtype)


SAMPLERS = {
    "laplace": sample_laplace,
    "gaussian": sample_gaussian,
}


def get_sampler(distribution: str):
    """Resolve an additive-noise sampler by name ("laplace" | "gaussian")."""
    try:
        return SAMPLERS[distribution]
    except KeyError:
        raise ValueError(
            f"unknown noise distribution {distribution!r}; "
            f"expected one of {sorted(SAMPLERS)}") from None
