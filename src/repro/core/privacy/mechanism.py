"""First-class privacy mechanisms for the GFL protocol.

The paper's Theorems 1-2 are stated for *any* private scheme that can be
modeled as additive noise.  This module makes that generality concrete: a
:class:`PrivacyMechanism` owns both protocol hooks of a scheme

  client level (eq. 7):  ``client_protect(w_clients, key, ctx) -> psi_p``
  server level (eq. 8):  ``server_combine(psi, key, A, ctx) -> w``

plus the pytree variants the mesh trainer uses
(``client_noise_tree`` / ``combine_noise_tree``) and a declarative
:meth:`~PrivacyMechanism.noise_profile` (per-level sigma, distribution,
cancellation structure, accountant curve) consumed by the
:class:`~repro.core.privacy.accountant.PrivacyAccountant` and by tests.

Mechanisms are looked up by name in a string-keyed registry, so
``GFLConfig.privacy`` is a registry key instead of an ``if``-ladder at every
call site::

    mech = mechanism_for(cfg)                  # parses cfg.privacy
    psi  = mech.client_protect(w_clients, key, ctx)
    w    = mech.server_combine(psi, key, A, ctx)

Registered schemes: ``none``, ``iid_dp``, ``hybrid`` (the paper's three),
``gaussian_dp`` (graph-homomorphic Gaussian noise, Gauthier et al. 2023,
with its own (eps, delta) accountant curve) and ``scheduled`` (wraps any
mechanism, spec ``"scheduled:<inner>"``, scaling sigma per-step from
``GFLConfig.epsilon_target`` so the budget is hit exactly at
``GFLConfig.epsilon_horizon``).

``cfg.use_kernels`` is a WHOLE-RUN switch: the engines route the fused
round-fold kernel (clip -> update -> privatize -> fold, docs/kernels.md)
through the backend-dispatch layer in :mod:`repro.kernels.ops` whenever a
mechanism declares a fusible client level via :meth:`~PrivacyMechanism.
fold_spec`, and every server level with CANCELLING noise structure (the
``none``/hybrid families) routes through the fused graph-combine kernel —
``iid_dp``'s non-cancelling per-edge noise keeps the reference einsum,
which cannot map onto the eq.-24 identity.  Mechanisms whose noise
cannot be expressed as a
fold-time term (or whose sigma is traced, e.g. ``scheduled`` inside jit)
return ``fold_spec() = None`` and fall back to the reference hooks — call
sites still never branch on the scheme name.  Adding a scheme is ~15
lines: subclass, override the hooks you need, decorate with
``@register_mechanism("name")`` (see docs/privacy_mechanisms.md).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.privacy.accountant import (
    PrivacyAccountant,
    scheduled_sigma_at,
)
from repro.core.privacy.homomorphic import (
    combine_nonprivate,
    homomorphic_combine_noise,
    iid_noise_combine,
)
from repro.core.privacy.noise import get_sampler
from repro.core.privacy.secure_agg import (
    masked_client_mean_dropout_vec,
    pairwise_masks_vec,
)

DEFAULT_SCHEDULE_HORIZON = 100


@dataclass(frozen=True)
class NoiseProfile:
    """Declarative description of a mechanism's injected noise.

    ``client_cancels_exactly`` / ``server_cancels_exactly`` declare the
    paper's two cancellation identities (eq. 23 / eq. 25): exact mask
    cancellation in the client mean and centroid-nullspace server noise.
    Tests assert the identities for every mechanism that declares them.
    ``curve`` selects the PrivacyAccountant model.

    ``client_dropout_safe`` declares whether the client level stays honest
    when sampled clients DROP OUT mid-round (``GFLConfig.fault`` with a
    ``dropout:`` component): pairwise secure-agg masks only cancel if the
    mechanism implements Bonawitz-style survivor renormalization
    (``client_protect_masked``).  The resilience runtime and the mesh
    trainer REFUSE to run client dropout through a mechanism that declares
    exact client cancellation without dropout safety — otherwise orphaned
    masks would silently corrupt the aggregate while the accountant keeps
    claiming the cancellation-based budget.  See docs/resilience.md.
    """
    distribution: str              # "laplace" | "gaussian" | "none"
    client_sigma: float
    server_sigma: float
    client_cancels_exactly: bool
    server_cancels_exactly: bool
    curve: str = "laplace_thm2"    # accountant curve key
    delta: float = 1e-5            # gaussian curve only
    horizon: int = 0               # scheduled curve only
    epsilon_target: float = 0.0    # scheduled curve only
    client_dropout_safe: bool = False  # survives mid-round client dropout


class FoldSpec(NamedTuple):
    """How a mechanism's client level enters the fused round-fold kernel.

    ``mode`` is the kernel's noise mode: ``"none"`` (plain weighted fold),
    ``"mask"`` (in-kernel pairwise secure-agg streams, cancel exactly) or
    ``"laplace"`` (pre-drawn per-client iid noise folded with the survivor
    mean).  ``sigma`` must be a STATIC float — mechanisms whose scale is
    traced return None from :meth:`PrivacyMechanism.fold_spec` instead.
    """
    mode: str
    sigma: float


@dataclass(frozen=True)
class RoundContext:
    """Per-round information threaded into the mechanism hooks.

    ``step`` may be a traced jax scalar inside jit.  ``sigma`` is an
    override used by wrapping mechanisms (``scheduled``); when set it may
    also be traced, and backends that require a static scale (the Pallas
    mask kernel) transparently fall back to the reference path.
    """
    step: Any = 0
    sigma: Any = None


def _is_static_scale(sigma) -> bool:
    """True when sigma is a concrete python/numpy float — i.e. usable as a
    static argument to the jit-wrapped Pallas kernels."""
    return isinstance(sigma, (int, float, np.floating))


def _tree_noise(key: jax.Array, tree, sigma, distribution: str):
    """Additive-noise pytree matching `tree` (leading server dim included
    in the leaves).  Samples in f32 and casts to each leaf dtype.

    ``sigma`` may be a scalar or a 1-D [P] array (per-server scale, e.g.
    realized survivor counts under client dropout); the vector case
    broadcasts over each leaf's leading server dim."""
    sampler = get_sampler(distribution)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))

    def leaf_sigma(leaf):
        if isinstance(sigma, jax.Array) and sigma.ndim == 1:
            return sigma.reshape(sigma.shape + (1,) * (leaf.ndim - 1))
        return sigma

    out = [sampler(k, leaf.shape, leaf_sigma(leaf), jnp.float32
                   ).astype(leaf.dtype)
           for k, leaf in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


class PrivacyMechanism:
    """Base class: the non-private protocol.  Subclasses override the
    hooks whose behavior they change; everything defaults to no noise."""

    name = "none"

    def __init__(self, cfg):
        self.cfg = cfg

    # ------------------------------------------------------------ helpers

    def sigma(self, ctx: Optional[RoundContext] = None):
        """Noise std for this round (ctx.sigma overrides cfg.sigma_g)."""
        if ctx is not None and ctx.sigma is not None:
            return ctx.sigma
        return self.cfg.sigma_g

    def accountant(self) -> PrivacyAccountant:
        """Accountant configured from this mechanism's noise profile."""
        return PrivacyAccountant.from_profile(
            self.noise_profile(), self.cfg.mu, self.cfg.grad_bound)

    def async_accountant(self, P: int):
        """Per-server ledgers for event-driven (non-lockstep) release
        schedules — see accountant.AsyncAccountant and docs/async.md."""
        from repro.core.privacy.accountant import AsyncAccountant
        return AsyncAccountant.from_profile(
            self.noise_profile(), self.cfg.mu, self.cfg.grad_bound, P)

    # ------------------------------------------------------ flat-vector API

    def client_protect(self, w_clients: jax.Array, key: jax.Array,
                       ctx: Optional[RoundContext] = None) -> jax.Array:
        """Aggregation step (7) for one server: [L, D] -> [D]."""
        return jnp.mean(w_clients, axis=0)

    def client_protect_masked(self, w_clients: jax.Array, key: jax.Array,
                              alive: jax.Array,
                              ctx: Optional[RoundContext] = None) -> jax.Array:
        """Aggregation step (7) under mid-round client DROPOUT.

        ``alive``: [L] bool participation mask.  The default (no client
        noise) is the exact mean over survivors; mechanisms with client
        noise override to keep their structure honest under dropout and
        declare it via ``noise_profile().client_dropout_safe``.  Only
        invoked by the resilience runtime when the fault model actually
        drops clients — the all-alive path stays on ``client_protect``.
        """
        n_alive = jnp.maximum(alive.sum(), 1)
        return jnp.where(alive[:, None], w_clients, 0.0).sum(axis=0) / n_alive

    def fold_spec(self, ctx: Optional[RoundContext] = None
                  ) -> Optional[FoldSpec]:
        """How the client level maps onto the fused round-fold kernel
        (:mod:`repro.kernels.round_fold`), or None when it doesn't (the
        engines then run the reference ``client_protect`` hooks).  The
        noise-free base protocol is a plain weighted fold."""
        return FoldSpec("none", 0.0)

    def server_combine(self, psi: jax.Array, key: jax.Array, A: jax.Array,
                       ctx: Optional[RoundContext] = None, *,
                       cache: Optional[jax.Array] = None,
                       gate: Optional[jax.Array] = None) -> jax.Array:
        """Combination step (8) across all servers: [P, D] -> [P, D].

        ``gate``/``cache`` ([P] mask, [P, D]) are the event engine's
        cached-psi re-announce: gated-off servers contribute ``cache``
        instead of ``psi`` (fused into the Pallas combine when
        ``cfg.use_kernels``)."""
        from repro.kernels import ops as kops
        if self.cfg.use_kernels:
            return kops.graph_combine(A, psi, None, cache=cache, gate=gate)
        return combine_nonprivate(A, kops.apply_gate(psi, gate, cache))

    # --------------------------------------------------------- pytree API

    def client_noise_tree(self, key: jax.Array, tree, L: int,
                          ctx: Optional[RoundContext] = None):
        """Client-level residual noise for the mesh path, or None.

        Mechanisms whose client noise cancels exactly in the mean (secure
        aggregation) return None: at mesh scale the aggregate is computed
        directly and the mask mechanics are exercised by the kernels and
        the simulator.  Non-cancelling mechanisms return one
        variance-equivalent draw (sigma / sqrt(L)) instead of L pytrees,
        which would not fit HBM at 47B params (DESIGN.md section 7).
        """
        return None

    def combine_noise_tree(self, key: jax.Array, tree,
                           ctx: Optional[RoundContext] = None):
        """Server-level noise pytree g for the mesh combine, or None.

        The combine implementations mix ``psi + g`` and, when
        ``noise_profile().server_cancels_exactly``, subtract each server's
        own g afterwards (eq. 24's wire protocol).
        """
        return None

    # -------------------------------------------------------- declaration

    def noise_profile(self) -> NoiseProfile:
        return NoiseProfile(distribution="none", client_sigma=0.0,
                            server_sigma=0.0, client_cancels_exactly=True,
                            server_cancels_exactly=True, curve="none",
                            client_dropout_safe=True)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


_REGISTRY: Dict[str, Callable[..., PrivacyMechanism]] = {}


def register_mechanism(name: str):
    """Class decorator registering a mechanism under `name`."""
    def deco(cls):
        if name in _REGISTRY:
            raise ValueError(f"privacy mechanism {name!r} already registered")
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def list_mechanisms() -> list[str]:
    """Sorted names of all registered mechanisms."""
    return sorted(_REGISTRY)


def get_mechanism(spec: str, cfg) -> PrivacyMechanism:
    """Instantiate the mechanism named by `spec` for a GFLConfig.

    A spec is ``"name"`` or ``"name:arg"`` — the optional arg is passed to
    the factory (used by ``"scheduled:<inner>"`` to pick the wrapped
    mechanism).
    """
    name, _, arg = spec.partition(":")
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown privacy mechanism {name!r}; registered: "
            f"{list_mechanisms()}") from None
    return factory(cfg, arg) if arg else factory(cfg)


def mechanism_for(cfg) -> PrivacyMechanism:
    """Resolve ``cfg.privacy`` through the registry."""
    return get_mechanism(cfg.privacy, cfg)


# ---------------------------------------------------------------------------
# the paper's three schemes
# ---------------------------------------------------------------------------


@register_mechanism("none")
class NoPrivacy(PrivacyMechanism):
    """g == 0 everywhere — the non-private baseline."""


class _SecureAggClientMixin:
    """Client level of the hybrid family: pairwise secure-agg masks that
    cancel exactly in the mean (eq. 23).

    This hook is the reference path; under ``cfg.use_kernels`` the engines
    intercept at :meth:`fold_spec` and run the whole client level through
    the fused round-fold kernel instead (in-VMEM mask streams), so no
    kernel branch lives here."""

    def client_protect(self, w_clients, key, ctx=None):
        if not self.cfg.secure_agg:
            return jnp.mean(w_clients, axis=0)
        L, D = w_clients.shape
        masks = pairwise_masks_vec(key, L, D, self.sigma(ctx),
                                   w_clients.dtype)
        return jnp.mean(w_clients + masks, axis=0)

    def client_protect_masked(self, w_clients, key, alive, ctx=None):
        """Dropout-safe secure aggregation: Bonawitz survivor
        renormalization (orphaned pair streams subtracted, mean rescaled
        over survivors).  The Pallas mask kernel has no dropout variant, so
        this always takes the reference vectorized path — dropout rounds
        are rare and the kernel path still serves the all-alive rounds."""
        if not self.cfg.secure_agg:
            return PrivacyMechanism.client_protect_masked(
                self, w_clients, key, alive, ctx)
        return masked_client_mean_dropout_vec(w_clients, key, alive,
                                              self.sigma(ctx))

    def fold_spec(self, ctx=None):
        """Pairwise masks cancel exactly in the (survivor-)mean, so the
        client level is a weighted fold plus in-kernel mask streams; a
        traced sigma (scheduled wrapper inside jit) cannot parameterize
        the static mask scale -> fall back to the reference hooks."""
        if not self.cfg.secure_agg:
            return FoldSpec("none", 0.0)
        sigma = self.sigma(ctx)
        if not _is_static_scale(sigma):
            return None
        if float(sigma) == 0.0:          # zero-scale masks are exact zeros
            return FoldSpec("none", 0.0)
        return FoldSpec("mask", float(sigma))


class _HomomorphicServerMixin:
    """Server level of the hybrid family: graph-homomorphic noise in the
    nullspace of the averaging operator (eq. 24-25), any distribution."""

    distribution = "laplace"

    def server_combine(self, psi, key, A, ctx=None, *, cache=None,
                       gate=None):
        sigma = self.sigma(ctx)
        from repro.kernels import ops as kops
        if self.cfg.use_kernels:
            sampler = get_sampler(self.distribution)
            g = sampler(key, psi.shape, sigma, psi.dtype)
            # fused Pallas kernel computes A^T (psi_eff+g) - g (eq. 8 + 24),
            # with the cached-psi re-announce select fused in when gated
            return kops.graph_combine(A, psi, g, cache=cache, gate=gate)
        return homomorphic_combine_noise(key, A,
                                         kops.apply_gate(psi, gate, cache),
                                         sigma,
                                         distribution=self.distribution)

    def combine_noise_tree(self, key, tree, ctx=None):
        return _tree_noise(key, tree, self.sigma(ctx), self.distribution)


@register_mechanism("hybrid")
class HybridMechanism(_SecureAggClientMixin, _HomomorphicServerMixin,
                      PrivacyMechanism):
    """The paper's scheme: secure-agg masks + graph-homomorphic Laplace."""

    def noise_profile(self):
        # secure_agg off -> NO client-level noise at all (plain mean), so
        # client_sigma is 0 and cancellation holds trivially
        return NoiseProfile(distribution="laplace",
                            client_sigma=(self.cfg.sigma_g
                                          if self.cfg.secure_agg else 0.0),
                            server_sigma=self.cfg.sigma_g,
                            client_cancels_exactly=True,
                            server_cancels_exactly=True,
                            curve="laplace_thm2",
                            client_dropout_safe=True)


@register_mechanism("gaussian_dp")
class GaussianDPMechanism(_SecureAggClientMixin, _HomomorphicServerMixin,
                          PrivacyMechanism):
    """Graph-homomorphic GAUSSIAN noise (Gauthier et al. 2023): the eq. 25
    nullspace identity is distribution-free, but the accountant follows the
    (eps, delta) Gaussian-mechanism curve instead of Theorem 2's Laplace
    curve."""

    distribution = "gaussian"

    def noise_profile(self):
        return NoiseProfile(distribution="gaussian",
                            client_sigma=(self.cfg.sigma_g
                                          if self.cfg.secure_agg else 0.0),
                            server_sigma=self.cfg.sigma_g,
                            client_cancels_exactly=True,
                            server_cancels_exactly=True,
                            curve="gaussian",
                            client_dropout_safe=True)


@register_mechanism("iid_dp")
class IIDLaplaceDP(PrivacyMechanism):
    """The paper's baseline: independent Laplace at both levels.  Nothing
    cancels — this is the O(mu^{-1}) utility penalty of Theorem 1."""

    def client_protect(self, w_clients, key, ctx=None):
        # reference path only: under use_kernels the engines route through
        # the fused round-fold kernel (fold_spec), which draws THIS
        # sampler's noise on the same key — one noise trajectory per seed
        # regardless of backend
        L, D = w_clients.shape
        noise = get_sampler("laplace")(key, (L, D), self.sigma(ctx),
                                       w_clients.dtype)
        return jnp.mean(w_clients + noise, axis=0)

    def client_protect_masked(self, w_clients, key, alive, ctx=None):
        """Per-client iid noise has no pair structure to orphan: the
        survivor mean of (update + noise) is already honest — noise scale
        per survivor is unchanged, only the 1/L' averaging factor moves."""
        L, D = w_clients.shape
        noise = get_sampler("laplace")(key, (L, D), self.sigma(ctx),
                                       w_clients.dtype)
        return PrivacyMechanism.client_protect_masked(
            self, w_clients + noise, key, alive, ctx)

    def fold_spec(self, ctx=None):
        """Per-client iid noise folds with the survivor-mean weight; the
        draws themselves come from the reference sampler (same key), so
        the fused path keeps backend parity tight."""
        sigma = self.sigma(ctx)
        if not _is_static_scale(sigma):
            return None
        if float(sigma) == 0.0:
            return FoldSpec("none", 0.0)
        return FoldSpec("laplace", float(sigma))

    def server_combine(self, psi, key, A, ctx=None, *, cache=None,
                       gate=None):
        from repro.kernels.ops import apply_gate
        return iid_noise_combine(key, A, apply_gate(psi, gate, cache),
                                 self.sigma(ctx))

    def client_noise_tree(self, key, tree, L, ctx=None):
        # variance-equivalent single draw: mean of L iid draws has std
        # sigma / sqrt(L), and the MSE analysis only sees the mean.  L may
        # be traced and/or a per-server [P] vector (realized survivor
        # counts under client dropout — each server's noise scales with
        # ITS survivor count, not the fleet average).
        return _tree_noise(key, tree,
                           self.sigma(ctx)
                           / jnp.sqrt(jnp.asarray(L, jnp.float32)),
                           "laplace")

    def combine_noise_tree(self, key, tree, ctx=None):
        return _tree_noise(key, tree, self.sigma(ctx), "laplace")

    def noise_profile(self):
        return NoiseProfile(distribution="laplace",
                            client_sigma=self.cfg.sigma_g,
                            server_sigma=self.cfg.sigma_g,
                            client_cancels_exactly=False,
                            server_cancels_exactly=False,
                            curve="laplace_thm2",
                            client_dropout_safe=True)


# ---------------------------------------------------------------------------
# scheduled: accountant-driven per-step sigma (wraps any mechanism)
# ---------------------------------------------------------------------------


@register_mechanism("scheduled")
class ScheduledMechanism(PrivacyMechanism):
    """Accountant-driven noise schedule around any registered mechanism.

    Spec ``"scheduled"`` wraps ``hybrid``; ``"scheduled:<inner>"`` wraps any
    other scheme.  When ``cfg.epsilon_target > 0`` the round-i noise std is
    ``scheduled_sigma_at(i+1, mu, B, horizon, epsilon_target)`` — each step
    spends a uniform epsilon_target / horizon slice of the budget, so the
    composed epsilon is LINEAR in i and equals epsilon_target exactly at
    ``cfg.epsilon_horizon`` (Theorem 2's fixed-sigma curve is quadratic).
    With ``epsilon_target == 0`` the wrapper is the identity.
    """

    def __init__(self, cfg, inner: str = "hybrid"):
        super().__init__(cfg)
        if inner.partition(":")[0] == "scheduled":
            raise ValueError("scheduled mechanism cannot wrap itself")
        self.inner = get_mechanism(inner, cfg)

    @property
    def horizon(self) -> int:
        return self.cfg.epsilon_horizon or DEFAULT_SCHEDULE_HORIZON

    def sigma_at(self, step):
        """Noise std for (0-indexed) round `step`; traced-step safe.  The
        per-release constant follows the INNER distribution (a Gaussian
        inner needs sqrt(2 ln 1.25/delta) x the Laplace sigma for the same
        per-step epsilon slice)."""
        if self.cfg.epsilon_target <= 0:
            return self.cfg.sigma_g
        inner_prof = self.inner.noise_profile()
        return scheduled_sigma_at(step + 1, self.cfg.mu, self.cfg.grad_bound,
                                  self.horizon, self.cfg.epsilon_target,
                                  distribution=inner_prof.distribution,
                                  delta=inner_prof.delta)

    def _inner_ctx(self, ctx: Optional[RoundContext]) -> RoundContext:
        ctx = ctx if ctx is not None else RoundContext()
        return replace(ctx, sigma=self.sigma_at(ctx.step))

    def client_protect(self, w_clients, key, ctx=None):
        return self.inner.client_protect(w_clients, key, self._inner_ctx(ctx))

    def client_protect_masked(self, w_clients, key, alive, ctx=None):
        return self.inner.client_protect_masked(w_clients, key, alive,
                                                self._inner_ctx(ctx))

    def fold_spec(self, ctx=None):
        # a traced per-step sigma makes the inner fold_spec return None
        # (the fused kernels need a static scale); a static step schedules
        # straight through
        return self.inner.fold_spec(self._inner_ctx(ctx))

    def server_combine(self, psi, key, A, ctx=None, *, cache=None,
                       gate=None):
        return self.inner.server_combine(psi, key, A, self._inner_ctx(ctx),
                                         cache=cache, gate=gate)

    def client_noise_tree(self, key, tree, L, ctx=None):
        return self.inner.client_noise_tree(key, tree, L,
                                            self._inner_ctx(ctx))

    def combine_noise_tree(self, key, tree, ctx=None):
        return self.inner.combine_noise_tree(key, tree, self._inner_ctx(ctx))

    def noise_profile(self):
        inner = self.inner.noise_profile()
        if self.cfg.epsilon_target <= 0 or inner.distribution == "none":
            # nothing to schedule: a noiseless inner stays noiseless (no
            # finite-epsilon claim for a run that injects zero noise)
            return inner
        # which levels the inner actually injects at is structural, not a
        # magnitude question — probe its profile at a reference sigma of 1
        # (cfg.sigma_g may be 0 while the schedule still injects noise)
        ref = type(self.inner)(replace(self.cfg, sigma_g=1.0)
                               ).noise_profile()
        # report the end-of-horizon sigma (the schedule's maximum)
        sigma_h = float(self.sigma_at(self.horizon - 1))
        return replace(inner,
                       client_sigma=sigma_h if ref.client_sigma > 0 else 0.0,
                       server_sigma=sigma_h if ref.server_sigma > 0 else 0.0,
                       curve="scheduled", horizon=self.horizon,
                       epsilon_target=self.cfg.epsilon_target)
