"""Differential-privacy accounting for the GFL algorithm (Theorem 2).

Sensitivity (eq. 26):  Delta(i) <= 2 mu B i
Theorem 2:  the hybrid scheme is eps(i)-DP at iteration i when

    sigma_g = sqrt(2) * mu * B * (1 + i) * i / eps(i)

Equivalently, for a fixed sigma_g, privacy decays quadratically:

    eps(i) = sqrt(2) * mu * B * (1 + i) * i / sigma_g = O(i^2).

Beyond the paper's Laplace curve this module carries two more curves,
selected by a :class:`PrivacyMechanism`'s ``noise_profile().curve``:

``gaussian``
    (eps, delta)-DP of the Gaussian mechanism (Gauthier et al. 2023
    variant) under basic composition: the sqrt(2) Laplace constant becomes
    ``sqrt(2 ln(1.25/delta))``.

``scheduled``
    Per-step noise schedule spending a uniform ``eps_target / horizon``
    budget each iteration, so the composed epsilon is *linear* in i and
    hits ``eps_target`` exactly at the horizon (instead of Theorem 2's
    quadratic blow-up).  ``scheduled_sigma_at`` is traced-value safe and is
    what the ``scheduled`` mechanism evaluates inside jit.

Every curve additionally exposes an **amplification-by-subsampling**
variant (arXiv:2301.06412 accounting for the partial-participation regime
of arXiv:2203.07105): when round j samples each client with probability
q_j — the ``CohortScheduler``'s realized rate L/K — release j is charged
``ln(1 + q_j (e^{eps_j} - 1))`` instead of its full-participation eps_j
(and deltas scale to ``q_j * delta``).  ``advance(steps, q=...)`` records
realized rates; ``amplified_epsilon()`` / ``amplified_delta()`` read the
amplified ledger, and q = 1 reproduces the unamplified curve exactly.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


def sensitivity(i, mu: float, B: float):
    """Delta(i) <= 2 mu B i (eq. 26)."""
    return 2.0 * mu * B * i


def epsilon_at(i: int, mu: float, B: float, sigma_g: float) -> float:
    """eps(i) for fixed noise std sigma_g (Theorem 2, rearranged)."""
    if sigma_g <= 0:
        return float("inf")
    return (2.0 ** 0.5) * mu * B * (1 + i) * i / sigma_g


def sigma_for_epsilon(i: int, mu: float, B: float, eps: float) -> float:
    """Noise std needed for eps(i)-DP at horizon i (Theorem 2)."""
    if eps <= 0:
        raise ValueError("epsilon must be positive")
    return (2.0 ** 0.5) * mu * B * (1 + i) * i / eps


# --------------------------------------------------------- Gaussian curve --


def _gaussian_const(delta: float) -> float:
    """sqrt(2 ln(1.25/delta)) — the Gaussian-mechanism analogue of the
    Laplace sqrt(2)."""
    if not 0 < delta < 1:
        raise ValueError("delta must be in (0, 1)")
    return math.sqrt(2.0 * math.log(1.25 / delta))


def gaussian_epsilon_at(i: int, mu: float, B: float, sigma_g: float,
                        delta: float = 1e-5) -> float:
    """Epsilon of the Gaussian scheme at iteration i, basic composition
    over the per-iteration releases (sensitivity eq. 26).

    ``delta`` is the PER-RELEASE delta; under basic composition the deltas
    add, so the composed guarantee after i releases is
    ``(returned epsilon, i * delta)``-DP — see
    :meth:`PrivacyAccountant.delta_spent`.
    """
    if sigma_g <= 0:
        return float("inf")
    return _gaussian_const(delta) * mu * B * (1 + i) * i / sigma_g


def gaussian_sigma_for_epsilon(i: int, mu: float, B: float, eps: float,
                               delta: float = 1e-5) -> float:
    """Gaussian noise std for (eps, delta)-DP at horizon i."""
    if eps <= 0:
        raise ValueError("epsilon must be positive")
    return _gaussian_const(delta) * mu * B * (1 + i) * i / eps


# -------------------------------------------------------- scheduled curve --


def per_release_constant(distribution: str = "laplace",
                         delta: float = 1e-5) -> float:
    """sigma = const * Delta / eps for one release of the given additive
    noise: sqrt(2) for Laplace (pure eps-DP), sqrt(2 ln(1.25/delta)) for
    Gaussian ((eps, delta)-DP)."""
    return (_gaussian_const(delta) if distribution == "gaussian"
            else 2.0 ** 0.5)


def scheduled_sigma_at(i, mu: float, B: float, horizon: int,
                       eps_target: float, distribution: str = "laplace",
                       delta: float = 1e-5):
    """Per-step noise std of the uniform-budget schedule.

    Step i releases a message of sensitivity Delta(i) = 2 mu B i and is
    granted eps_i = eps_target / horizon, so

        sigma_i = const(distribution) * Delta(i) * horizon / eps_target

    with the per-release constant of the wrapped noise distribution.
    Pure arithmetic in ``i`` — safe to call with a traced jax scalar.
    """
    if eps_target <= 0:
        raise ValueError("epsilon target must be positive")
    if horizon <= 0:
        raise ValueError("horizon must be positive")
    return (per_release_constant(distribution, delta)
            * sensitivity(i, mu, B) * horizon / eps_target)


def scheduled_epsilon_spent(i: int, horizon: int, eps_target: float) -> float:
    """Composed epsilon after i steps of the uniform-budget schedule:
    linear consumption, equal to eps_target exactly at i == horizon (and
    still growing linearly past it — running longer keeps spending)."""
    if horizon <= 0:
        raise ValueError("horizon must be positive")
    return eps_target * i / horizon


# ---------------------------------------------- subsampling amplification --


def amplified_release_epsilon(eps: float, q: float) -> float:
    """Privacy amplification by subsampling for ONE release.

    A mechanism that is eps-DP on the full population is
    ``ln(1 + q (e^eps - 1))``-DP when each client participates with
    probability q (and a delta, if any, scales to q * delta) — the
    partial-participation accounting of arXiv:2301.06412 / the classic
    subsampling lemma.  q = 1 returns eps exactly; q -> 0 approaches
    q * eps (the small-budget linear regime).
    """
    if not 0.0 < q <= 1.0:
        raise ValueError(f"sampling rate q={q} not in (0, 1]")
    if q == 1.0 or math.isinf(eps):
        return eps
    if eps <= 30.0:
        return math.log1p(q * math.expm1(eps))
    # large eps: rewrite as ln(e^{ln q + eps} + (1 - q)) so nothing
    # overflows and a tiny q cannot drive the result negative (q e^eps
    # may still be < 1 there — the naive eps + ln q shortcut is wrong
    # until q e^eps dominates)
    x = math.log(q) + eps
    if x > 700.0:            # e^x would overflow float64; (1-q) vanishes
        return x
    return math.log1p(math.exp(x) - q)


_CURVES = ("laplace_thm2", "gaussian", "scheduled", "none")


@dataclass
class PrivacyAccountant:
    """Tracks the epsilon ledger of a running GFL job.

    ``curve`` selects the accountant model; the default reproduces the
    paper's Theorem-2 Laplace analysis.  Build one for a registered
    mechanism with :meth:`from_profile` (consumes
    ``PrivacyMechanism.noise_profile()``).
    """
    mu: float
    grad_bound: float
    sigma_g: float
    step: int = 0
    history: list = field(default_factory=list)
    curve: str = "laplace_thm2"
    delta: float = 1e-5
    horizon: int = 0
    epsilon_target: float = 0.0
    distribution: str = "laplace"
    sampling_rate: float = 1.0     # default per-round cohort rate q = L/K
    q_history: list = field(default_factory=list)  # realized q per release
    owner: str = ""                # ledger tag in telemetry records ("" =
                                   # the scalar ledger; AsyncAccountant tags
                                   # its per-server ledgers "server<p>")

    def __post_init__(self):
        if self.curve not in _CURVES:
            raise ValueError(f"unknown accountant curve {self.curve!r}; "
                             f"expected one of {_CURVES}")

    @classmethod
    def from_profile(cls, profile, mu: float, grad_bound: float
                     ) -> "PrivacyAccountant":
        """Accountant configured from a mechanism's NoiseProfile."""
        return cls(mu=mu, grad_bound=grad_bound,
                   sigma_g=profile.server_sigma, curve=profile.curve,
                   delta=profile.delta, horizon=profile.horizon,
                   epsilon_target=profile.epsilon_target,
                   distribution=profile.distribution)

    def advance(self, steps: int = 1, q: float | None = None) -> float:
        """Advance the ledger by `steps` releases.

        ``q`` records the realized cohort sampling rate of those releases
        (defaults to the accountant's ``sampling_rate``).  Pass the rate
        the rounds ACTUALLY ran at — per round, ``CohortSelection.q`` —
        not a running mean over rounds with different rates: the
        amplification bound is per release, and averaging a varying q
        before recording under-reports the spend.  The returned epsilon is
        the UNAMPLIFIED curve (the paper's full-participation ledger);
        :meth:`amplified_epsilon` reads the amplified one.
        """
        self.q_history.extend([self.sampling_rate if q is None else q]
                              * steps)
        self.step += steps
        eps = self.epsilon()
        self.history.append((self.step, eps))
        from repro.telemetry import emit, telemetry_active
        if telemetry_active():
            q_rel = self.q_history[-1] if self.q_history \
                else self.sampling_rate
            eps_rel = self.per_release_epsilon(self.step)
            emit("privacy", {
                "step": self.step, "eps": eps, "eps_release": eps_rel,
                "eps_release_amp": (
                    amplified_release_epsilon(eps_rel, q_rel)
                    if 0.0 < q_rel <= 1.0 else eps_rel),
                "delta": self.delta_spent(), "q": q_rel,
                "curve": self.curve, "server": self.owner})
        return eps

    def epsilon(self) -> float:
        if self.curve == "none":
            return 0.0
        if self.curve == "gaussian":
            return gaussian_epsilon_at(self.step, self.mu, self.grad_bound,
                                       self.sigma_g, self.delta)
        if self.curve == "scheduled":
            return scheduled_epsilon_spent(self.step, self.horizon,
                                           self.epsilon_target)
        return epsilon_at(self.step, self.mu, self.grad_bound, self.sigma_g)

    def per_release_epsilon(self, j: int) -> float:
        """Epsilon of release j alone (1-indexed), i.e. the increment the
        composed curve charges at step j: the Theorem-2 Laplace/Gaussian
        curves satisfy eps(i) = sum_{j<=i} c * 2 mu B j / sigma, and the
        scheduled curve spends a uniform eps_target / horizon slice."""
        if self.curve == "none":
            return 0.0
        if self.curve == "scheduled":
            if self.horizon <= 0:
                raise ValueError("scheduled curve needs a positive horizon")
            return self.epsilon_target / self.horizon
        if self.sigma_g <= 0:
            return float("inf")
        const = (_gaussian_const(self.delta) if self.curve == "gaussian"
                 else 2.0 ** 0.5)
        return const * 2.0 * self.mu * self.grad_bound * j / self.sigma_g

    def _release_qs(self) -> list:
        """Realized per-release sampling rates, padded with the default."""
        qs = list(self.q_history[:self.step])
        qs += [self.sampling_rate] * (self.step - len(qs))
        return qs

    def amplified_epsilon(self, q: float | None = None) -> float:
        """Composed epsilon under amplification by subsampling.

        Each release j is charged ``ln(1 + q_j (e^{eps_j} - 1))`` instead
        of eps_j, where q_j is the realized cohort sampling rate recorded
        by :meth:`advance` (override every q_j with the ``q`` argument).
        q = 1 reproduces :meth:`epsilon` exactly — unit-pinned in
        tests/test_privacy.py.
        """
        if self.curve == "none":
            return 0.0
        qs = [q] * self.step if q is not None else self._release_qs()
        return sum(amplified_release_epsilon(self.per_release_epsilon(j), qj)
                   for j, qj in enumerate(qs, start=1))

    def amplified_delta(self, q: float | None = None) -> float:
        """Composed delta under subsampling: each release's delta scales by
        its q before the basic-composition sum."""
        if self.distribution != "gaussian":
            return 0.0
        qs = [q] * self.step if q is not None else self._release_qs()
        return self.delta * sum(qs)

    def amplification_curve(self, steps: int, q: float) -> list:
        """Prospective amplified-epsilon trajectory [(i, eps_amp(i))] for a
        fixed sampling rate q — does not mutate the ledger."""
        out, total = [], 0.0
        for j in range(1, steps + 1):
            total += amplified_release_epsilon(self.per_release_epsilon(j), q)
            out.append((j, total))
        return out

    def delta_spent(self) -> float:
        """Composed delta after `step` releases: the per-release deltas add
        under basic composition, so a Gaussian-noise ledger at step i is
        honestly (epsilon(), i * delta)-DP — including a scheduled curve
        wrapping a Gaussian inner.  Pure-epsilon (Laplace) curves spend 0."""
        if self.distribution == "gaussian":
            return self.step * self.delta
        return 0.0

    def sensitivity(self) -> float:
        return sensitivity(self.step, self.mu, self.grad_bound)

    def sigma_schedule(self, horizon: int, eps_target: float) -> float:
        """Fixed sigma to guarantee eps_target at `horizon` steps."""
        if self.curve == "gaussian":
            return gaussian_sigma_for_epsilon(horizon, self.mu,
                                              self.grad_bound, eps_target,
                                              self.delta)
        return sigma_for_epsilon(horizon, self.mu, self.grad_bound,
                                 eps_target)


# ------------------------------------------------- per-server async ledger --


@dataclass
class AsyncAccountant:
    """Per-server release ledgers for the event-driven executor.

    Once servers stop releasing in lockstep (repro.core.events), "the"
    epsilon of the run is no longer one composed curve: each server
    releases at ITS OWN realized cadence and realized sampling rate q, and
    the privacy surface is per-server (cf. the topology-dependent
    decentralized bounds of arXiv:2312.07956).  This extension keeps one
    :class:`PrivacyAccountant` per server, advances server p's ledger only
    on the ticks p actually flushed (``record_round`` /
    ``record_schedule`` consume the ``(flushed, q)`` schedule an
    :class:`~repro.core.events.engine.AsyncRunResult` carries), and
    reports the worst server's spend as the headline number.

    The synchronous lockstep schedule — every server flushing every tick
    at the same q — is a pinned special case: every per-server ledger then
    equals the scalar accountant's, so ``epsilon()`` /
    ``amplified_epsilon()`` reproduce the synchronous curves exactly
    (unit-pinned in tests/test_events.py).
    """
    servers: list

    @classmethod
    def from_profile(cls, profile, mu: float, grad_bound: float, P: int
                     ) -> "AsyncAccountant":
        """One ledger per server, each configured like
        :meth:`PrivacyAccountant.from_profile`."""
        ledgers = [PrivacyAccountant.from_profile(profile, mu, grad_bound)
                   for _ in range(P)]
        for p, acc in enumerate(ledgers):
            acc.owner = f"server{p}"
        return cls(ledgers)

    @property
    def P(self) -> int:
        return len(self.servers)

    @property
    def releases(self) -> list:
        """Per-server release counts so far."""
        return [acc.step for acc in self.servers]

    def record_round(self, flushed, q=None) -> None:
        """Advance the ledgers of the servers that flushed this tick.

        ``flushed``: [P] bool; ``q``: [P] realized per-flush sampling
        rates (entries of non-flushing servers ignored; None charges each
        ledger's default rate)."""
        for p, did in enumerate(flushed):
            if did:
                qp = None if q is None else float(q[p])
                if qp is not None and qp <= 0.0:
                    qp = None   # schedule rows store 0 for "no flush"
                self.servers[p].advance(1, q=qp)

    def record_schedule(self, flushed, q=None) -> None:
        """Record a whole run's [T, P] release schedule (the
        ``AsyncRunResult.flushed`` / ``.q`` arrays)."""
        for t in range(len(flushed)):
            self.record_round(flushed[t], None if q is None else q[t])

    def per_server_epsilon(self) -> list:
        return [acc.epsilon() for acc in self.servers]

    def epsilon(self) -> float:
        """Worst-server composed epsilon (0 with no servers/releases)."""
        eps = self.per_server_epsilon()
        return max(eps) if eps else 0.0

    def amplified_epsilon(self) -> float:
        """Worst-server composed epsilon under subsampling amplification,
        against each server's own realized q history."""
        eps = [acc.amplified_epsilon() for acc in self.servers]
        return max(eps) if eps else 0.0

    def amplified_delta(self) -> float:
        return max((acc.amplified_delta() for acc in self.servers),
                   default=0.0)

    def delta_spent(self) -> float:
        return max((acc.delta_spent() for acc in self.servers), default=0.0)
