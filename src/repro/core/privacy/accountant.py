"""Differential-privacy accounting for the GFL algorithm (Theorem 2).

Sensitivity (eq. 26):  Delta(i) <= 2 mu B i
Theorem 2:  the hybrid scheme is eps(i)-DP at iteration i when

    sigma_g = sqrt(2) * mu * B * (1 + i) * i / eps(i)

Equivalently, for a fixed sigma_g, privacy decays quadratically:

    eps(i) = sqrt(2) * mu * B * (1 + i) * i / sigma_g = O(i^2).
"""
from __future__ import annotations

from dataclasses import dataclass, field


def sensitivity(i: int, mu: float, B: float) -> float:
    """Delta(i) <= 2 mu B i (eq. 26)."""
    return 2.0 * mu * B * i


def epsilon_at(i: int, mu: float, B: float, sigma_g: float) -> float:
    """eps(i) for fixed noise std sigma_g (Theorem 2, rearranged)."""
    if sigma_g <= 0:
        return float("inf")
    return (2.0 ** 0.5) * mu * B * (1 + i) * i / sigma_g


def sigma_for_epsilon(i: int, mu: float, B: float, eps: float) -> float:
    """Noise std needed for eps(i)-DP at horizon i (Theorem 2)."""
    if eps <= 0:
        raise ValueError("epsilon must be positive")
    return (2.0 ** 0.5) * mu * B * (1 + i) * i / eps


@dataclass
class PrivacyAccountant:
    """Tracks the epsilon ledger of a running GFL job."""
    mu: float
    grad_bound: float
    sigma_g: float
    step: int = 0
    history: list = field(default_factory=list)

    def advance(self, steps: int = 1) -> float:
        self.step += steps
        eps = self.epsilon()
        self.history.append((self.step, eps))
        return eps

    def epsilon(self) -> float:
        return epsilon_at(self.step, self.mu, self.grad_bound, self.sigma_g)

    def sensitivity(self) -> float:
        return sensitivity(self.step, self.mu, self.grad_bound)

    def sigma_schedule(self, horizon: int, eps_target: float) -> float:
        """Fixed sigma to guarantee eps_target at `horizon` steps."""
        return sigma_for_epsilon(horizon, self.mu, self.grad_bound, eps_target)
