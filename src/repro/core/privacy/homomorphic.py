"""Graph-homomorphic perturbations (eq. 24-25; Vlaski & Sayed, ICASSP 2021).

Each server ``m`` samples ONE Laplace vector ``g_m ~ Lap(0, sigma_g/sqrt 2)``
per iteration and perturbs the update it sends to neighbour ``p`` with::

    g_{mp} =  g_m                          if m != p
    g_{mp} = -(1 - a_mm)/a_mm * g_m        if m == p

which satisfies the null-space condition (eq. 25)

    (1/P) sum_p sum_m a_mp g_{mp} = 0

for any doubly-stochastic A, so the *network centroid* sees zero injected
noise and the O(mu^{-1}) utility penalty of Theorem 1 disappears.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.privacy.noise import get_sampler


def homomorphic_noise_matrix(key: jax.Array, A: jax.Array, dim: int,
                             sigma: float, dtype=jnp.float32,
                             distribution: str = "laplace") -> jax.Array:
    """Materialize g_{mp} as a [P, P, dim] tensor (reference path).

    Row m is the noise server m adds to the update it sends to p (column p).
    The null-space identity (eq. 25) holds for ANY additive noise, so the
    distribution is a parameter (Laplace is the paper's choice; Gaussian is
    the Gauthier et al. 2023 variant).
    """
    P = A.shape[0]
    g = get_sampler(distribution)(key, (P, dim), sigma, dtype)  # g_m
    diag = jnp.diagonal(A)                                     # a_mm
    self_coef = -(1.0 - diag) / diag                           # eq. (24)
    out = jnp.broadcast_to(g[:, None, :], (P, P, dim))
    eye = jnp.eye(P, dtype=dtype)[:, :, None]
    return out * (1.0 - eye) + (self_coef[:, None] * g)[:, None, :] * eye


def homomorphic_combine_noise(key: jax.Array, A: jax.Array, psi: jax.Array,
                              sigma: float, distribution: str = "laplace"
                              ) -> jax.Array:
    """Server combination (8) with homomorphic noise, WITHOUT materializing
    the P x P noise tensor:

        w_p = sum_m a_mp (psi_m + g_{mp})
            = sum_m a_mp psi_m + sum_{m} a_mp g_m - g_p   [using eq. 24]

    since ``a_pp * (-(1-a_pp)/a_pp) g_p = -(1-a_pp) g_p`` merges with the
    ``m != p`` terms into ``(A^T g)_p - g_p``.

    psi: [P, dim] -> returns [P, dim].
    """
    P, dim = psi.shape
    g = get_sampler(distribution)(key, (P, dim), sigma, psi.dtype)
    mixed = A.T.astype(psi.dtype) @ psi
    noise = A.T.astype(psi.dtype) @ g - g
    return mixed + noise


def iid_noise_combine(key: jax.Array, A: jax.Array, psi: jax.Array,
                      sigma: float, distribution: str = "laplace"
                      ) -> jax.Array:
    """Baseline 'standard DP' scheme: independent noise per edge."""
    P, dim = psi.shape
    g = get_sampler(distribution)(key, (P, P, dim), sigma, psi.dtype)
    return A.T.astype(psi.dtype) @ psi + jnp.einsum(
        "mp,mpd->pd", A.astype(psi.dtype), g)


def combine_nonprivate(A: jax.Array, psi: jax.Array) -> jax.Array:
    """Noise-free server combination (8)."""
    return A.T.astype(psi.dtype) @ psi
