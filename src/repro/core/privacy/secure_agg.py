"""Client-level secure aggregation (eq. 23 of the paper).

We model Bonawitz-style secret sharing as pairwise antithetic masks: every
ordered pair (j < k) of participating clients shares a PRG seed; client j adds
``+PRG(j,k)`` and client k adds ``-PRG(j,k)``.  The masks cancel *exactly* in
the server sum (eq. 23: ``sum_k g_{p,k,i} = 0``) while each individual masked
update is marginally uniform-ish noise of scale ``mask_scale``.

Exact cancellation (not just in expectation) is the property the paper's
hybrid analysis relies on, and is what our hypothesis tests assert.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pair_key(base: jax.Array, j: int | jax.Array, k: int | jax.Array) -> jax.Array:
    return jax.random.fold_in(jax.random.fold_in(base, j), k)


def pairwise_masks(key: jax.Array, num_clients: int, dim: int,
                   mask_scale: float = 1.0, dtype=jnp.float32) -> jax.Array:
    """Return masks [L, dim] with columns summing exactly to zero.

    mask_k = sum_{j<k} -PRG(j,k) + sum_{j>k} +PRG(k,j)

    O(L^2) python-loop REFERENCE implementation: production call sites use
    the vectorized :func:`pairwise_masks_vec` (same PRG streams, so the two
    agree to float addition order — the hypothesis test asserts it); this
    version is kept as the oracle that test compares against.
    """
    L = num_clients
    masks = jnp.zeros((L, dim), dtype)
    for j in range(L):
        for k in range(j + 1, L):
            m = mask_scale * jax.random.normal(_pair_key(key, j, k), (dim,), dtype)
            masks = masks.at[j].add(m)
            masks = masks.at[k].add(-m)
    return masks


def pair_stream_matrix(key: jax.Array, L: int, dim: int, scale: float,
                       dtype=jnp.float32) -> jax.Array:
    """Antisymmetric pair-stream tensor S [L, L, dim].

    ``S[j, k] = PRG(j, k)`` for ``j < k`` and ``S[k, j] = -S[j, k]``:
    entry (j, k) is the mask stream client j adds on account of its pair
    with client k.  ``mask_j = S[j].sum(0)``.  Exposing S (rather than only
    the row sums) is what makes Bonawitz-style dropout recovery a masked
    reduction instead of an O(L^2) python loop.
    """
    jj, kk = jnp.triu_indices(L, k=1)

    def draw(j, k):
        kk_ = jax.random.fold_in(jax.random.fold_in(key, j), k)
        return jax.random.normal(kk_, (dim,), dtype)

    vals = jax.vmap(draw)(jj, kk) * scale                    # [L(L-1)/2, dim]
    S = jnp.zeros((L, L, dim), dtype)
    S = S.at[jj, kk].set(vals)
    return S - jnp.swapaxes(S, 0, 1)


def pairwise_masks_vec(key: jax.Array, L: int, dim: int, scale: float,
                       dtype=jnp.float32) -> jax.Array:
    """Vectorized pairwise secure-agg masks [L, dim]; columns sum to exactly 0.

    S[j,k] = PRG(j,k) for j<k, S[k,j] = -S[j,k]; mask_j = sum_k S[j,k].
    """
    return pair_stream_matrix(key, L, dim, scale, dtype).sum(axis=1)


def masked_client_mean_dropout_vec(updates: jax.Array, key: jax.Array,
                                   alive: jax.Array,
                                   mask_scale: float = 1.0) -> jax.Array:
    """Vectorized, jit-able survivor-renormalized aggregation (7).

    Bonawitz-style recovery when clients drop out mid-round: masks between
    two survivors cancel in the sum by themselves, masks between two dead
    clients never arrive, and each orphaned alive<->dead stream is
    reconstructed from the survivors' seed shares and subtracted.  The mean
    is then RESCALED over the survivor count — the result equals the exact
    mean over alive clients, so the server still only learns an aggregate.

    updates: [L, D]; alive: [L] bool.  This is the production path; the
    O(L^2) python-loop :func:`masked_client_mean_with_dropout` is kept only
    as the reference the hypothesis test compares against.
    """
    L, D = updates.shape
    S = pair_stream_matrix(key, L, D, mask_scale, updates.dtype)
    masks = S.sum(axis=1)
    total = jnp.where(alive[:, None], updates + masks, 0.0).sum(axis=0)
    orphan = alive[:, None] & ~alive[None, :]        # j alive, k dead
    repair = jnp.where(orphan[..., None], S, 0.0).sum(axis=(0, 1))
    n_alive = jnp.maximum(alive.sum(), 1)
    return (total - repair) / n_alive


def masked_client_mean_with_dropout(updates: jax.Array, key: jax.Array,
                                    alive: jax.Array,
                                    mask_scale: float = 1.0) -> jax.Array:
    """Aggregation (7) when some clients DROP OUT mid-round.

    Bonawitz-style recovery: the server collects the surviving clients'
    shares of each dropped client's pair seeds and subtracts the orphaned
    mask contributions.  In our additive model that means: sum the masked
    updates of alive clients, then remove every mask stream between an
    alive and a dead client (streams between two dead clients never arrive;
    streams between two alive clients cancel by themselves).

    updates: [L, D]; alive: [L] bool.  Returns the mean over ALIVE clients,
    exactly (the privacy property survives dropout).

    O(L^2) python-loop REFERENCE implementation — production call sites
    (the hybrid-family mechanisms and the resilience runtime) use the
    vectorized :func:`masked_client_mean_dropout_vec`.
    """
    L, D = updates.shape
    masks = pairwise_masks(key, L, D, mask_scale, updates.dtype)
    masked = jnp.where(alive[:, None], updates + masks, 0.0)
    total = masked.sum(axis=0)
    # recovery round: subtract orphaned pair streams (alive<->dead pairs)
    for j in range(L):
        for k in range(j + 1, L):
            m = mask_scale * jax.random.normal(_pair_key(key, j, k),
                                               (D,), updates.dtype)
            orphan_j = alive[j] & ~alive[k]      # +m arrived without -m
            orphan_k = alive[k] & ~alive[j]      # -m arrived without +m
            total = total - jnp.where(orphan_j, m, 0.0) \
                + jnp.where(orphan_k, m, 0.0)
    n_alive = jnp.maximum(alive.sum(), 1)
    return total / n_alive


def masked_client_mean(updates: jax.Array, key: jax.Array,
                       mask_scale: float = 1.0) -> jax.Array:
    """Server aggregation (7) with secure-agg masks.

    updates: [L, dim].  Returns the mean over clients of (update + mask).
    Because the masks cancel exactly, this equals ``updates.mean(0)`` up to
    float addition order — which is precisely the privacy guarantee: the
    server learns only the aggregate.
    """
    L, dim = updates.shape
    masks = pairwise_masks_vec(key, L, dim, mask_scale, updates.dtype)
    return jnp.mean(updates + masks, axis=0)
