from repro.core.privacy.noise import (
    get_sampler,
    laplace_from_uniform,
    sample_gaussian,
    sample_laplace,
)
from repro.core.privacy.secure_agg import (
    masked_client_mean,
    masked_client_mean_dropout_vec,
    masked_client_mean_with_dropout,
    pair_stream_matrix,
    pairwise_masks,
    pairwise_masks_vec,
)
from repro.core.privacy.homomorphic import (
    homomorphic_noise_matrix,
    homomorphic_combine_noise,
)
from repro.core.privacy.accountant import (
    PrivacyAccountant,
    amplified_release_epsilon,
    epsilon_at,
    gaussian_epsilon_at,
    gaussian_sigma_for_epsilon,
    scheduled_epsilon_spent,
    scheduled_sigma_at,
    sensitivity,
    sigma_for_epsilon,
)
from repro.core.privacy.mechanism import (
    NoiseProfile,
    PrivacyMechanism,
    RoundContext,
    get_mechanism,
    list_mechanisms,
    mechanism_for,
    register_mechanism,
)

__all__ = [
    "laplace_from_uniform",
    "sample_laplace",
    "sample_gaussian",
    "get_sampler",
    "pairwise_masks",
    "pairwise_masks_vec",
    "pair_stream_matrix",
    "masked_client_mean",
    "masked_client_mean_dropout_vec",
    "masked_client_mean_with_dropout",
    "homomorphic_noise_matrix",
    "homomorphic_combine_noise",
    "PrivacyAccountant",
    "amplified_release_epsilon",
    "epsilon_at",
    "gaussian_epsilon_at",
    "gaussian_sigma_for_epsilon",
    "scheduled_epsilon_spent",
    "scheduled_sigma_at",
    "sensitivity",
    "sigma_for_epsilon",
    "NoiseProfile",
    "PrivacyMechanism",
    "RoundContext",
    "get_mechanism",
    "list_mechanisms",
    "mechanism_for",
    "register_mechanism",
]
