from repro.core.privacy.noise import laplace_from_uniform, sample_laplace
from repro.core.privacy.secure_agg import (
    pairwise_masks,
    masked_client_mean,
)
from repro.core.privacy.homomorphic import (
    homomorphic_noise_matrix,
    homomorphic_combine_noise,
)
from repro.core.privacy.accountant import PrivacyAccountant, sensitivity, sigma_for_epsilon

__all__ = [
    "laplace_from_uniform",
    "sample_laplace",
    "pairwise_masks",
    "masked_client_mean",
    "homomorphic_noise_matrix",
    "homomorphic_combine_noise",
    "PrivacyAccountant",
    "sensitivity",
    "sigma_for_epsilon",
]
