"""The Graph Federated Learning protocol (eqs. 6-8) over flat parameter vectors.

This is the canonical, mesh-free implementation used by the paper-scale
simulator, the small-scale trainers and as the semantic oracle for the
mesh-sharded implementation in :mod:`repro.launch.steps`.

One round:
  (6) client update:      w_{p,k} = w_{p} - mu * clip_B(grad Q(w_p; batch_{p,k}))
  (7) server aggregation: psi_p   = (1/L) sum_k (w_{p,k} + g_{p,k})
  (8) server combination: w_p     = sum_m a_mp (psi_m + g_{mp})

Privacy schemes
  none    g == 0 everywhere.
  iid_dp  independent Laplace at both levels (the paper's baseline).
  hybrid  secure-agg pairwise masks at the client level (cancel exactly,
          eq. 23) + graph-homomorphic Laplace at the server level (eq. 24-25).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import GFLConfig
from repro.core.privacy.homomorphic import (
    combine_nonprivate,
    homomorphic_combine_noise,
    iid_noise_combine,
)
from repro.core.privacy.noise import sample_laplace


class GFLState(NamedTuple):
    params: jax.Array   # [P, D] per-server flat models
    step: jax.Array     # scalar int32
    key: jax.Array      # PRNG key


def clip_to_bound(g: jax.Array, bound: float) -> jax.Array:
    """Project gradient onto the B-ball (enforces Assumption 3 / eq. 14)."""
    if bound <= 0:
        return g
    nrm = jnp.linalg.norm(g)
    return g * jnp.minimum(1.0, bound / jnp.maximum(nrm, 1e-12))


def pairwise_masks_vec(key: jax.Array, L: int, dim: int, scale: float,
                       dtype=jnp.float32) -> jax.Array:
    """Vectorized pairwise secure-agg masks [L, dim]; columns sum to exactly 0.

    S[j,k] = PRG(j,k) for j<k, S[k,j] = -S[j,k]; mask_j = sum_k S[j,k].
    """
    jj, kk = jnp.triu_indices(L, k=1)

    def draw(j, k):
        kk_ = jax.random.fold_in(jax.random.fold_in(key, j), k)
        return jax.random.normal(kk_, (dim,), dtype)

    vals = jax.vmap(draw)(jj, kk) * scale                    # [L(L-1)/2, dim]
    S = jnp.zeros((L, L, dim), dtype)
    S = S.at[jj, kk].set(vals)
    S = S - jnp.swapaxes(S, 0, 1)
    return S.sum(axis=1)


def server_aggregate(w_clients: jax.Array, key: jax.Array, cfg: GFLConfig
                     ) -> jax.Array:
    """Aggregation step (7) for one server. w_clients: [L, D]."""
    L, D = w_clients.shape
    if cfg.privacy == "hybrid" and cfg.secure_agg:
        if cfg.use_kernels:
            from repro.kernels import ops as kops
            seed = jax.random.randint(key, (1,), 0, 2**31 - 1).astype(
                jnp.uint32)
            return kops.secure_agg_mean(w_clients, seed,
                                        scale=float(cfg.sigma_g))
        masks = pairwise_masks_vec(key, L, D, cfg.sigma_g, w_clients.dtype)
        return jnp.mean(w_clients + masks, axis=0)
    if cfg.privacy == "iid_dp":
        noise = sample_laplace(key, (L, D), cfg.sigma_g, w_clients.dtype)
        return jnp.mean(w_clients + noise, axis=0)
    return jnp.mean(w_clients, axis=0)


def server_combine(psi: jax.Array, key: jax.Array, A: jax.Array,
                   cfg: GFLConfig) -> jax.Array:
    """Combination step (8) across all servers. psi: [P, D]."""
    if cfg.privacy == "hybrid":
        if cfg.use_kernels:
            from repro.core.privacy.noise import sample_laplace
            from repro.kernels import ops as kops
            g = sample_laplace(key, psi.shape, cfg.sigma_g, psi.dtype)
            # fused Pallas kernel computes A^T (psi+g) - g (eq. 8 + 24)
            return kops.graph_combine(A, psi, g)
        return homomorphic_combine_noise(key, A, psi, cfg.sigma_g)
    if cfg.privacy == "iid_dp":
        return iid_noise_combine(key, A, psi, cfg.sigma_g)
    return combine_nonprivate(A, psi)


def gfl_round(params: jax.Array, batch, key: jax.Array, *, A: jax.Array,
              grad_fn: Callable, cfg: GFLConfig) -> jax.Array:
    """One full GFL round.

    params: [P, D]; batch: pytree whose leaves have leading dims [P, L, ...];
    grad_fn(w, client_batch) -> flat gradient [D].
    """
    P, D = params.shape
    key_round, key_combine = jax.random.split(key)
    server_keys = jax.random.split(key_round, P)

    def one_server(w_p, batch_p, key_p):
        def one_client(client_batch):
            g = grad_fn(w_p, client_batch)
            g = clip_to_bound(g, cfg.grad_bound)
            return w_p - cfg.mu * g

        w_clients = jax.vmap(one_client)(batch_p)            # [L, D]
        return server_aggregate(w_clients, key_p, cfg)

    psi = jax.vmap(one_server)(params, batch, server_keys)   # [P, D]
    return server_combine(psi, key_combine, A, cfg)


def make_gfl_step(A: jax.Array, grad_fn: Callable, cfg: GFLConfig):
    """jit-ready (state, batch) -> state transition.

    combine_every=tau > 1 amortizes the server combination over tau local
    rounds (clients keep updating; servers only exchange every tau steps) —
    a beyond-paper communication/utility tradeoff knob."""
    A = jnp.asarray(A)

    @jax.jit
    def step(state: GFLState, batch) -> GFLState:
        key, sub = jax.random.split(state.key)
        if cfg.combine_every > 1:
            local_cfg = cfg
            do_combine = state.step % cfg.combine_every == cfg.combine_every - 1

            def round_with(params, combine: bool):
                import dataclasses
                c = cfg if combine else dataclasses.replace(
                    cfg, privacy="none" if cfg.privacy == "none" else cfg.privacy)
                key_r, key_c = jax.random.split(sub)
                P = params.shape[0]
                server_keys = jax.random.split(key_r, P)

                def one_server(w_p, batch_p, key_p):
                    def one_client(client_batch):
                        g = grad_fn(w_p, client_batch)
                        g = clip_to_bound(g, cfg.grad_bound)
                        return w_p - cfg.mu * g
                    w_clients = jax.vmap(one_client)(batch_p)
                    return server_aggregate(w_clients, key_p, cfg)

                psi = jax.vmap(one_server)(params, batch, server_keys)
                if combine:
                    return server_combine(psi, key_c, A, cfg)
                return psi

            new_params = jax.lax.cond(
                do_combine, lambda p: round_with(p, True),
                lambda p: round_with(p, False), state.params)
        else:
            new_params = gfl_round(state.params, batch, sub, A=A,
                                   grad_fn=grad_fn, cfg=cfg)
        return GFLState(new_params, state.step + 1, key)

    return step


def init_state(key: jax.Array, P: int, dim: int, init_scale: float = 0.0
               ) -> GFLState:
    k1, k2 = jax.random.split(key)
    params = init_scale * jax.random.normal(k1, (P, dim))
    return GFLState(params, jnp.zeros((), jnp.int32), k2)


def centroid(params: jax.Array) -> jax.Array:
    """Network centroid w_c = (1/P) sum_p w_p (eq. 15)."""
    return params.mean(axis=0)
