"""The Graph Federated Learning protocol (eqs. 6-8) over flat parameter vectors.

This is the canonical, mesh-free implementation used by the paper-scale
simulator, the small-scale trainers and as the semantic oracle for the
mesh-sharded implementation in :mod:`repro.launch.steps`.

One round:
  (6) client update:      w_{p,k} = w_{p} - mu * clip_B(grad Q(w_p; batch_{p,k}))
  (7) server aggregation: psi_p   = (1/L) sum_k (w_{p,k} + g_{p,k})
  (8) server combination: w_p     = sum_m a_mp (psi_m + g_{mp})

Privacy
  Both noise insertions are owned by a pluggable
  :class:`~repro.core.privacy.mechanism.PrivacyMechanism` resolved from the
  string-keyed registry via ``GFLConfig.privacy`` — this module never
  branches on the scheme name.  A mechanism supplies ``client_protect``
  (step 7), ``server_combine`` (step 8) and a declarative
  ``noise_profile()`` the ``PrivacyAccountant`` consumes; the Pallas-kernel
  vs reference backend choice lives inside the mechanism.  Registered
  schemes include the paper's three (``none``, ``iid_dp``, ``hybrid``) plus
  ``gaussian_dp`` and the accountant-driven ``scheduled`` wrapper — see
  docs/privacy_mechanisms.md for the API and how to add one.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GFLConfig
from repro.core.privacy.mechanism import (
    PrivacyMechanism,
    RoundContext,
    mechanism_for,
)
from repro.core.privacy.secure_agg import pairwise_masks_vec  # noqa: F401  (re-export)


class GFLState(NamedTuple):
    params: jax.Array   # [P, D] per-server flat models
    step: jax.Array     # scalar int32
    key: jax.Array      # PRNG key


def clip_to_bound(g: jax.Array, bound: float) -> jax.Array:
    """Project gradient onto the B-ball (enforces Assumption 3 / eq. 14)."""
    if bound <= 0:
        return g
    nrm = jnp.linalg.norm(g)
    return g * jnp.minimum(1.0, bound / jnp.maximum(nrm, 1e-12))


def server_aggregate(w_clients: jax.Array, key: jax.Array, cfg: GFLConfig,
                     mechanism: Optional[PrivacyMechanism] = None,
                     ctx: Optional[RoundContext] = None) -> jax.Array:
    """Aggregation step (7) for one server. w_clients: [L, D]."""
    mech = mechanism if mechanism is not None else mechanism_for(cfg)
    return mech.client_protect(w_clients, key, ctx)


def server_combine(psi: jax.Array, key: jax.Array, A: jax.Array,
                   cfg: GFLConfig,
                   mechanism: Optional[PrivacyMechanism] = None,
                   ctx: Optional[RoundContext] = None) -> jax.Array:
    """Combination step (8) across all servers. psi: [P, D]."""
    mech = mechanism if mechanism is not None else mechanism_for(cfg)
    return mech.server_combine(psi, key, A, ctx)


def _fused_client_fold(w, grads, server_keys, cfg: GFLConfig, mech, ctx, *,
                       pre_w=None, fold_w=None, noise_w=None):
    """(6)+(7) through the fused round-fold kernel, or None when the fused
    path doesn't apply (``use_kernels`` off, or the mechanism's client
    level has no static :meth:`~repro.core.privacy.mechanism.
    PrivacyMechanism.fold_spec`).

    ``w``: [P, D] base models or [P, L, D] per-client stale bases;
    ``grads``: [P, L, D] raw per-client gradients; ``pre_w`` [P, L]
    importance weights (applied BEFORE the sensitivity clip), ``fold_w``
    unnormalized fold weights (staleness x alive), ``noise_w`` per-client
    noise/mask fold weight (None -> uniform 1/L).  Returns (psi [P, D],
    sq [P, L] raw squared grad norms) — this is THE call the dense round,
    the population executor and the event engine share; backend dispatch
    (ref-jnp vs Pallas, auto-interpret on CPU) lives in
    :mod:`repro.kernels.ops` (docs/kernels.md).
    """
    if not cfg.use_kernels:
        return None
    spec = mech.fold_spec(ctx)
    if spec is None:
        return None
    from repro.core.privacy.noise import get_sampler
    from repro.kernels import ops as kops
    P, L, D = grads.shape
    seeds = noise = None
    if spec.mode == "mask":
        seeds = jax.vmap(
            lambda k: jax.random.randint(k, (1,), 0, 2**31 - 1)[0]
        )(server_keys).astype(jnp.uint32)
    elif spec.mode == "laplace":
        # the reference sampler on the same per-server keys: identical
        # draws to the client_protect path, streamed once by the kernel
        noise = jax.vmap(
            lambda k: get_sampler("laplace")(k, (L, D), spec.sigma,
                                             grads.dtype)
        )(server_keys)
    return kops.round_fold(w, grads, mu=cfg.mu, bound=cfg.grad_bound,
                           pre_w=pre_w, fold_w=fold_w, noise_w=noise_w,
                           mode=spec.mode, sigma=spec.sigma, seeds=seeds,
                           noise=noise)


def _client_grads(params, batch, grad_fn):
    """Raw per-client gradients [P, L, D] (the fused kernel's input)."""
    return jax.vmap(lambda w_p, b_p: jax.vmap(
        lambda cb: grad_fn(w_p, cb))(b_p))(params, batch)


def _survivor_weights(alive):
    """(fold_w, noise_w) for a [P, L] participation mask: survivors fold
    uniformly and the noise/mask term enters at the survivor mean (the
    dropout-safe semantics of docs/resilience.md).  None -> (None, None),
    the all-alive uniform fold."""
    if alive is None:
        return None, None
    af = alive.astype(jnp.float32)
    return af, af / jnp.maximum(af.sum(axis=1, keepdims=True), 1.0)


def _client_updates(params, batch, server_keys, grad_fn, cfg, mech, ctx,
                    alive=None):
    """(6)+(7): per-server client updates and protected aggregation.

    ``alive`` ([P, L] bool, optional) marks the clients that survived the
    round; when given, aggregation routes through the mechanism's
    dropout-safe ``client_protect_masked`` hook.  With ``cfg.use_kernels``
    the whole pass runs as one fused round-fold kernel call."""
    if cfg.use_kernels and mech.fold_spec(ctx) is not None:
        grads = _client_grads(params, batch, grad_fn)
        fold_w, noise_w = _survivor_weights(alive)
        psi, _ = _fused_client_fold(params, grads, server_keys, cfg, mech,
                                    ctx, fold_w=fold_w, noise_w=noise_w)
        return psi

    def updates(w_p, batch_p):
        def one_client(client_batch):
            g = grad_fn(w_p, client_batch)
            g = clip_to_bound(g, cfg.grad_bound)
            return w_p - cfg.mu * g

        return jax.vmap(one_client)(batch_p)                 # [L, D]

    if alive is None:
        def one_server(w_p, batch_p, key_p):
            return mech.client_protect(updates(w_p, batch_p), key_p, ctx)

        return jax.vmap(one_server)(params, batch, server_keys)  # [P, D]

    def one_server(w_p, batch_p, key_p, alive_p):
        return mech.client_protect_masked(updates(w_p, batch_p), key_p,
                                          alive_p, ctx)

    return jax.vmap(one_server)(params, batch, server_keys, alive)


def gfl_round(params: jax.Array, batch, key: jax.Array, *, A,
              grad_fn: Callable, cfg: GFLConfig,
              mechanism: Optional[PrivacyMechanism] = None,
              step=0) -> jax.Array:
    """One full GFL round.

    params: [P, D]; batch: pytree whose leaves have leading dims [P, L, ...];
    grad_fn(w, client_batch) -> flat gradient [D].  `step` (python int or
    traced scalar) feeds step-dependent mechanisms (``scheduled``).

    ``A`` is either a fixed [P, P] combination matrix or a
    :class:`~repro.core.resilience.process.TopologyProcess`, in which case
    the round's effective A_i and client participation mask are realized
    from ``step`` (which must then be concrete).  Stragglers are stateful
    across rounds and therefore live only in the step functions
    (:func:`make_gfl_step` with a process / the resilience runtime).
    """
    P, D = params.shape
    mech = mechanism if mechanism is not None else mechanism_for(cfg)
    alive = None
    from repro.core.resilience.process import TopologyProcess
    if isinstance(A, TopologyProcess):
        proc, i = A, int(step)
        real = proc.realize(i)
        A = jnp.asarray(real.A, jnp.float32)
        if proc.fault.client_dropout > 0:
            from repro.core.resilience.runtime import ensure_dropout_safe
            ensure_dropout_safe(mech.noise_profile())
            L = jax.tree_util.tree_leaves(batch)[0].shape[1]
            alive = jnp.asarray(proc.client_alive(i, L))
    ctx = RoundContext(step=step)
    key_round, key_combine = jax.random.split(key)
    server_keys = jax.random.split(key_round, P)
    psi = _client_updates(params, batch, server_keys, grad_fn, cfg, mech, ctx,
                          alive)
    return mech.server_combine(psi, key_combine, A, ctx)


def make_gfl_step(A, grad_fn: Callable, cfg: GFLConfig):
    """jit-ready (state, batch) -> state transition.

    ``A`` may be a fixed combination matrix or a
    :class:`~repro.core.resilience.process.TopologyProcess` — the latter
    dispatches to the resilience runtime (per-round effective A_i, client
    dropout, stragglers; see repro.core.resilience).

    combine_every=tau > 1 amortizes the server combination over tau local
    rounds (clients keep updating; servers only exchange every tau steps) —
    a beyond-paper communication/utility tradeoff knob.  Non-combine rounds
    never invoke the mechanism's server level, so no combine noise is
    injected on them (the client level still runs)."""
    from repro.core.resilience.process import TopologyProcess
    if isinstance(A, TopologyProcess):
        from repro.core.resilience.runtime import make_resilient_gfl_step
        return make_resilient_gfl_step(A, grad_fn, cfg)
    A = jnp.asarray(A)
    mech = mechanism_for(cfg)

    @jax.jit
    def step(state: GFLState, batch) -> GFLState:
        key, sub = jax.random.split(state.key)
        if cfg.combine_every > 1:
            do_combine = state.step % cfg.combine_every == cfg.combine_every - 1
            ctx = RoundContext(step=state.step)
            key_r, key_c = jax.random.split(sub)
            server_keys = jax.random.split(key_r, state.params.shape[0])
            psi = _client_updates(state.params, batch, server_keys, grad_fn,
                                  cfg, mech, ctx)
            new_params = jax.lax.cond(
                do_combine,
                lambda p: mech.server_combine(p, key_c, A, ctx),
                lambda p: p, psi)
        else:
            new_params = gfl_round(state.params, batch, sub, A=A,
                                   grad_fn=grad_fn, cfg=cfg, mechanism=mech,
                                   step=state.step)
        # read-only in-graph tap (repro.telemetry): nothing is inserted
        # when no session is active — `step` is re-jitted per make_gfl_step
        # call, so the emit decision is taken fresh for every run
        from repro.telemetry import emit
        emit("step", {
            "step": state.step + 1,
            "update_norm": jnp.linalg.norm(new_params - state.params),
            "param_norm": jnp.linalg.norm(new_params)})
        return GFLState(new_params, state.step + 1, key)

    return step


def init_state(key: jax.Array, P: int, dim: int, init_scale: float = 0.0
               ) -> GFLState:
    k1, k2 = jax.random.split(key)
    params = init_scale * jax.random.normal(k1, (P, dim))
    return GFLState(params, jnp.zeros((), jnp.int32), k2)


def centroid(params: jax.Array) -> jax.Array:
    """Network centroid w_c = (1/P) sum_p w_p (eq. 15)."""
    return params.mean(axis=0)
