"""Server-graph topologies and combination matrices.

The paper (Assumption 1) requires the combination matrix ``A`` to be symmetric
and doubly stochastic with spectral gap ``lambda = rho(A - 11^T/P) < 1``.
We build such matrices with Metropolis-Hastings weights over several graph
families and expose the spectral gap so experiments can report it.
"""
from __future__ import annotations

import numpy as np


def _metropolis(adj: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings weights: symmetric, doubly stochastic for any
    undirected graph; self-loops absorb the residual mass."""
    P = adj.shape[0]
    deg = adj.sum(axis=1)
    A = np.zeros((P, P))
    for p in range(P):
        for m in range(P):
            if p != m and adj[p, m]:
                A[p, m] = 1.0 / (1.0 + max(deg[p], deg[m]))
    for p in range(P):
        A[p, p] = 1.0 - A[p].sum()
    return A


def ring_adjacency(P: int) -> np.ndarray:
    adj = np.zeros((P, P), dtype=bool)
    for p in range(P):
        adj[p, (p + 1) % P] = adj[p, (p - 1) % P] = True
    np.fill_diagonal(adj, False)
    return adj


def torus_adjacency(rows: int, cols: int) -> np.ndarray:
    """2-D torus (wrap-around grid): used for the multi-pod (pod x data) graph."""
    P = rows * cols
    adj = np.zeros((P, P), dtype=bool)

    def idx(r, c):
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            i = idx(r, c)
            for j in (idx(r + 1, c), idx(r - 1, c), idx(r, c + 1), idx(r, c - 1)):
                if j != i:
                    adj[i, j] = True
    return adj


def full_adjacency(P: int) -> np.ndarray:
    adj = np.ones((P, P), dtype=bool)
    np.fill_diagonal(adj, False)
    return adj


def erdos_adjacency(P: int, prob: float = 0.4, seed: int = 0) -> np.ndarray:
    """Erdos-Renyi; resampled until connected."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        u = rng.random((P, P)) < prob
        adj = np.triu(u, 1)
        adj = adj | adj.T
        if _connected(adj):
            return adj
    raise RuntimeError("could not sample a connected ER graph")


def _connected(adj: np.ndarray) -> bool:
    P = adj.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        p = frontier.pop()
        for m in np.nonzero(adj[p])[0]:
            if m not in seen:
                seen.add(m)
                frontier.append(int(m))
    return len(seen) == P


def hypercube_adjacency(P: int) -> np.ndarray:
    """d-dimensional hypercube (P must be a power of two): degree log2(P)
    with O(1/log P) spectral gap decay — much better mixing than a ring at
    the same per-node collective cost scaling."""
    d = int(np.log2(P))
    if 2 ** d != P:
        raise ValueError(f"hypercube needs a power of two, got {P}")
    adj = np.zeros((P, P), dtype=bool)
    for p in range(P):
        for b in range(d):
            adj[p, p ^ (1 << b)] = True
    return adj


def expander_adjacency(P: int, degree: int = 4, seed: int = 0) -> np.ndarray:
    """Random regular-ish expander (union of `degree`/2 random ring
    permutations): near-constant spectral gap."""
    rng = np.random.default_rng(seed)
    adj = np.zeros((P, P), dtype=bool)
    for _ in range(max(degree // 2, 1)):
        perm = rng.permutation(P)
        for i in range(P):
            a, b = perm[i], perm[(i + 1) % P]
            if a != b:
                adj[a, b] = adj[b, a] = True
    if not _connected(adj):
        adj |= ring_adjacency(P)
    return adj


def combination_matrix(topology: str, P: int, *, rows: int = 0, seed: int = 0
                       ) -> np.ndarray:
    """Build the doubly-stochastic combination matrix for ``topology``."""
    if topology == "ring":
        adj = ring_adjacency(P)
    elif topology == "torus":
        r = rows or int(np.floor(np.sqrt(P)))
        while P % r:
            r -= 1
        adj = torus_adjacency(r, P // r)
    elif topology == "full":
        adj = full_adjacency(P)
    elif topology == "erdos":
        adj = erdos_adjacency(P, seed=seed)
    elif topology == "hypercube":
        adj = hypercube_adjacency(P)
    elif topology == "expander":
        adj = expander_adjacency(P, seed=seed)
    else:
        raise ValueError(f"unknown topology {topology!r}")
    A = _metropolis(adj)
    validate_combination_matrix(A)
    return A


def spectral_gap(A: np.ndarray) -> float:
    """lambda = rho(A - 11^T/P); Assumption 1 requires < 1."""
    P = A.shape[0]
    M = A - np.ones((P, P)) / P
    return float(np.max(np.abs(np.linalg.eigvals(M))))


def validate_combination_matrix(A: np.ndarray, atol: float = 1e-10, *,
                                gap: float | None = None) -> None:
    """Assert Assumption 1.  Pass a precomputed ``gap`` to skip the O(P^3)
    eigendecomposition (per-round fault realizations already have it)."""
    P = A.shape[0]
    if not np.allclose(A, A.T, atol=atol):
        raise ValueError("combination matrix must be symmetric")
    if not np.allclose(A.sum(axis=0), np.ones(P), atol=atol):
        raise ValueError("combination matrix must be doubly stochastic")
    if np.any(A < -atol):
        raise ValueError("combination matrix must be nonnegative")
    if P > 1:
        if gap is None:
            gap = spectral_gap(A)
        if gap >= 1.0 - 1e-12:
            raise ValueError("graph must be connected (spectral gap >= 1)")


def neighbor_lists(A: np.ndarray) -> list[list[int]]:
    """Non-self neighbours of each server (for sparse combine schedules)."""
    P = A.shape[0]
    return [[m for m in range(P) if m != p and A[m, p] > 0] for p in range(P)]


def permute_schedule(topology: str, P: int, *, rows: int = 0) -> list[list[tuple[int, int]]]:
    """Rounds of (src, dst) pairs for collective_permute-based sparse combine.

    Each round is a permutation (every device sends to exactly one device and
    receives from exactly one).  A ring needs 2 rounds (left, right); a torus
    (r x c) needs 4 (up/down/left/right).
    """
    if topology == "ring":
        fwd = [(p, (p + 1) % P) for p in range(P)]
        bwd = [(p, (p - 1) % P) for p in range(P)]
        return [fwd, bwd]
    if topology == "torus":
        r = rows or int(np.floor(np.sqrt(P)))
        while P % r:
            r -= 1
        c = P // r

        def idx(i, j):
            return (i % r) * c + (j % c)

        rounds = []
        for di, dj in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            rounds.append([(idx(i, j), idx(i + di, j + dj))
                           for i in range(r) for j in range(c)])
        # drop degenerate self-rounds (e.g. rows==1 makes up==down==self or dup)
        uniq, seen = [], set()
        for rd in rounds:
            key = tuple(sorted(rd))
            if all(s != d for s, d in rd) and key not in seen:
                seen.add(key)
                uniq.append(rd)
        return uniq
    raise ValueError(f"no permute schedule for topology {topology!r}")
