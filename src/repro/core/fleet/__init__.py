"""Multi-process server fleet: the event engine as a real deployment.

Each of the P servers runs as its own worker (OS process, or tier-1-safe
in-process thread) driving its local slice of the buffered event engine;
psi exchanges and cohort dispatches travel over a pluggable
:class:`~repro.core.fleet.transport.Transport` (``inproc`` | ``filelog``
| ``socket``) selected by the ``fleet`` spec grammar.  A coordinator
owns the namebook, dispatches cohorts with timeout + bounded retry +
exponential backoff, SIGKILL-realizes ``outage ... kill=1`` faults, and
elastically restarts killed workers from their crash-atomic write-ahead
checkpoints.  See docs/fleet.md.
"""
from repro.core.fleet.chaos import ChaosOutcome, chaos_run, plan_kills
from repro.core.fleet.coordinator import (Coordinator, Fleet,
                                          FleetRunResult, fleet_cohort,
                                          reference_solution, run_fleet)
from repro.core.fleet.namebook import (COORDINATOR, Namebook, WorkerEntry,
                                       worker_name)
from repro.core.fleet.spec import TRANSPORTS, FleetSpec, parse_fleet_spec
from repro.core.fleet.transport import (FileLogTransport, InprocHub,
                                        InprocTransport, Message,
                                        SocketTransport, Transport,
                                        TransportError, make_transport,
                                        send_with_retry)
from repro.core.fleet.worker import (FleetProblem, FleetWorker,
                                     load_worker_checkpoint,
                                     worker_process_main)

__all__ = [
    "ChaosOutcome", "chaos_run", "plan_kills",
    "Coordinator", "Fleet", "FleetRunResult", "fleet_cohort",
    "reference_solution", "run_fleet",
    "COORDINATOR", "Namebook", "WorkerEntry", "worker_name",
    "TRANSPORTS", "FleetSpec", "parse_fleet_spec",
    "FileLogTransport", "InprocHub", "InprocTransport", "Message",
    "SocketTransport", "Transport", "TransportError", "make_transport",
    "send_with_retry",
    "FleetProblem", "FleetWorker", "load_worker_checkpoint",
    "worker_process_main",
]
