"""Pluggable fleet transports: the one seam every byte crosses.

Psi exchanges, cohort dispatches, heartbeats and restart hellos all
travel as :class:`Message` envelopes over a :class:`Transport` — the
fleet's coordinator and workers never touch a queue, file or socket
directly (gflint GFL008 enforces that raw ``socket``/``subprocess`` use
stays inside ``core/fleet/``).  Three realizations, selected by the
``fleet`` spec grammar (:mod:`repro.core.fleet.spec`):

``inproc``
    per-endpoint ``queue.Queue`` behind a shared :class:`InprocHub` —
    workers run as threads in one process.  The tier-1-safe realization:
    chaos tests "kill" a worker by halting its thread and restart it
    from its checkpoint, no subprocesses involved.

``filelog``
    one append-only JSONL log per endpoint under a shared directory;
    ``send`` appends one line to the destination's log (O_APPEND
    single-write, so concurrent senders interleave whole records),
    ``recv`` tails the endpoint's own log from a cursor.  A restarted
    endpoint re-reads its log from offset 0 — delivery is *replay*, and
    the receiver-side idempotent dedup (tick / ``(server, version)``
    keys) turns at-least-once replay into exactly-once effect.  The
    cursor distance to the end of the log is the ``replay_lag``
    telemetry.

``socket``
    length-prefixed JSON over TCP: each endpoint owns a listening socket
    (an acceptor thread drains connections into a local queue) and
    ``send`` opens a short-lived connection to the destination address
    from the namebook.  Connection failures surface as
    :class:`TransportError` for the retry/backoff layer.

Delivery contract shared by all three: **at-least-once, sender-retried,
receiver-deduped**.  :func:`send_with_retry` implements the bounded
retry + exponential backoff send path; receivers must tolerate
duplicates (the protocol keys — dispatch tick, psi ``(server,
version)`` — make every handler idempotent).
"""
from __future__ import annotations

import json
import os
import queue
import socket
import struct
import threading
import time
from abc import ABC, abstractmethod
from typing import Callable, Dict, NamedTuple, Optional

import numpy as np

from repro.core.fleet.spec import FleetSpec


class TransportError(RuntimeError):
    """A send/recv attempt failed (the retry layer's signal)."""


class Message(NamedTuple):
    """One fleet protocol envelope.

    ``kind``     hello | cohort | psi | heartbeat | stop | bye
    ``sender``   endpoint name ("coordinator", "worker3")
    ``version``  sender's protocol clock: the dispatch tick for cohort
                 messages, the flush count for psi messages
    ``payload``  JSON-serializable dict; arrays travel as nested lists
    """
    kind: str
    sender: str
    version: int
    payload: dict

    def encode(self) -> bytes:
        return json.dumps({"kind": self.kind, "sender": self.sender,
                           "version": self.version,
                           "payload": self.payload}).encode("utf-8")

    @classmethod
    def decode(cls, blob: bytes) -> "Message":
        doc = json.loads(blob.decode("utf-8"))
        return cls(doc["kind"], doc["sender"], int(doc["version"]),
                   doc.get("payload", {}))


def pack_array(a) -> list:
    """Arrays -> nested lists (the JSON wire form)."""
    return np.asarray(a, np.float64).tolist()


def unpack_array(v) -> np.ndarray:
    return np.asarray(v, np.float64)


class Transport(ABC):
    """One endpoint's view of the message substrate."""

    name: str = "?"        # this endpoint's name
    kind: str = "?"        # inproc | filelog | socket

    @abstractmethod
    def send(self, dest: str, msg: Message) -> None:
        """Deliver ``msg`` to ``dest``'s inbox (raises TransportError)."""

    @abstractmethod
    def recv(self, timeout: Optional[float] = None) -> Optional[Message]:
        """Next inbound message, or None after ``timeout`` seconds."""

    def stats(self) -> dict:
        """Transport health counters (``replay_lag`` = records queued or
        logged but not yet consumed by this endpoint)."""
        return {"replay_lag": 0}

    def close(self) -> None:
        pass


def send_with_retry(transport: Transport, dest: str, msg: Message,
                    spec: FleetSpec,
                    on_retry: Optional[Callable[[int], None]] = None
                    ) -> bool:
    """Bounded-retry + backoff send (the fleet's only send path).

    Attempts ``1 + spec.retry`` sends, sleeping ``spec.backoff_delay(a)``
    between attempts; ``on_retry(attempt)`` lets the caller count retries
    into telemetry.  Returns True on success, False when the budget is
    exhausted — the caller decides whether that means a lost worker.
    Duplicated deliveries from earlier half-failed attempts are the
    receiver's (idempotent) problem, by design.
    """
    for attempt in range(1 + spec.retry):
        try:
            transport.send(dest, msg)
            return True
        except TransportError:
            if attempt >= spec.retry:
                return False
            if on_retry is not None:
                on_retry(attempt)
            time.sleep(min(spec.backoff_delay(attempt), 2.0))
    return False


# ---------------------------------------------------------------------------
# inproc: shared-hub queues (threads in one process; tier-1-safe)
# ---------------------------------------------------------------------------


class InprocHub:
    """Shared endpoint registry for one in-process fleet: name -> queue."""

    def __init__(self):
        self._queues: Dict[str, queue.Queue] = {}
        self._lock = threading.Lock()

    def register(self, name: str) -> "InprocTransport":
        with self._lock:
            # a restarted endpoint re-registers: it gets a FRESH queue, so
            # messages addressed to its dead incarnation are dropped (the
            # coordinator re-dispatches — at-least-once end to end)
            self._queues[name] = queue.Queue()
        return InprocTransport(self, name)

    def queue_for(self, name: str) -> queue.Queue:
        with self._lock:
            q = self._queues.get(name)
        if q is None:
            raise TransportError(f"inproc endpoint {name!r} not registered")
        return q


class InprocTransport(Transport):
    kind = "inproc"

    def __init__(self, hub: InprocHub, name: str):
        self.hub = hub
        self.name = name

    def send(self, dest: str, msg: Message) -> None:
        self.hub.queue_for(dest).put(msg.encode())

    def recv(self, timeout: Optional[float] = None) -> Optional[Message]:
        try:
            blob = self.hub.queue_for(self.name).get(timeout=timeout)
        except queue.Empty:
            return None
        return Message.decode(blob)

    def stats(self) -> dict:
        try:
            return {"replay_lag": self.hub.queue_for(self.name).qsize()}
        except TransportError:
            return {"replay_lag": 0}


# ---------------------------------------------------------------------------
# filelog: per-endpoint append-only replay logs
# ---------------------------------------------------------------------------


class FileLogTransport(Transport):
    """Append-only JSONL per endpoint under ``root``; recv tails own log.

    The log IS the delivery history: a restarted endpoint replays it from
    offset 0, and receiver-side dedup makes the replay idempotent.  A
    send is one ``write()`` of one newline-terminated record on an
    O_APPEND descriptor, so concurrent senders never tear each other's
    lines.
    """
    kind = "filelog"

    def __init__(self, root: str, name: str, *, poll: float = 0.02,
                 replay: bool = True):
        self.root = root
        self.name = name
        self.poll = poll
        os.makedirs(root, exist_ok=True)
        self._path = self._log_path(name)
        # touch own log so lag/replay reads never race creation
        with open(self._path, "a", encoding="utf-8"):
            pass
        self._fh = open(self._path, "r", encoding="utf-8")
        if not replay:
            self._fh.seek(0, os.SEEK_END)

    def _log_path(self, endpoint: str) -> str:
        return os.path.join(self.root, f"{endpoint}.log")

    def send(self, dest: str, msg: Message) -> None:
        line = msg.encode() + b"\n"
        try:
            fd = os.open(self._log_path(dest),
                         os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
            try:
                os.write(fd, line)
            finally:
                os.close(fd)
        except OSError as e:
            raise TransportError(f"filelog append to {dest!r} failed: {e}")

    def recv(self, timeout: Optional[float] = None) -> Optional[Message]:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            line = self._fh.readline()
            if line:
                line = line.strip()
                if not line:
                    continue
                try:
                    return Message.decode(line.encode("utf-8"))
                except (json.JSONDecodeError, KeyError):
                    continue   # torn tail line: wait for the full record
            if deadline is not None and time.monotonic() >= deadline:
                return None
            time.sleep(self.poll)

    def stats(self) -> dict:
        try:
            behind = os.path.getsize(self._path) - self._fh.tell()
        except OSError:
            behind = 0
        # records, not bytes: count unconsumed newline-terminated lines
        lag = 0
        if behind > 0:
            with open(self._path, "rb") as fh:
                fh.seek(self._fh.tell())
                lag = fh.read().count(b"\n")
        return {"replay_lag": lag}

    def close(self) -> None:
        self._fh.close()


# ---------------------------------------------------------------------------
# socket: length-prefixed JSON over TCP
# ---------------------------------------------------------------------------


_LEN = struct.Struct(">I")


class SocketTransport(Transport):
    """TCP endpoint: own listener + short-lived connections per send.

    The acceptor thread drains inbound connections into a local queue so
    ``recv`` has queue semantics like the other transports.  Destination
    addresses come from the ``addresses`` map (the namebook's transport
    view) which the coordinator keeps current as workers register and
    restart.
    """
    kind = "socket"

    def __init__(self, name: str, addresses: Dict[str, tuple], *,
                 host: str = "127.0.0.1", port: int = 0):
        self.name = name
        self.addresses = addresses     # name -> (host, port), shared/mutated
        self._inbox: queue.Queue = queue.Queue()
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(64)
        self.address = self._srv.getsockname()
        addresses[name] = tuple(self.address)
        self._closing = threading.Event()
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          daemon=True,
                                          name=f"fleet-accept-{name}")
        self._acceptor.start()

    def _accept_loop(self) -> None:
        self._srv.settimeout(0.2)
        while not self._closing.is_set():
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            try:
                with conn:
                    conn.settimeout(5.0)
                    header = _recv_exact(conn, _LEN.size)
                    if header is None:
                        continue
                    (n,) = _LEN.unpack(header)
                    blob = _recv_exact(conn, n)
                    if blob is not None:
                        self._inbox.put(blob)
            except OSError:
                continue

    def send(self, dest: str, msg: Message) -> None:
        addr = self.addresses.get(dest)
        if addr is None:
            raise TransportError(f"no address registered for {dest!r}")
        blob = msg.encode()
        try:
            with socket.create_connection(tuple(addr), timeout=2.0) as conn:
                conn.sendall(_LEN.pack(len(blob)) + blob)
        except OSError as e:
            raise TransportError(f"socket send to {dest!r}{addr} "
                                 f"failed: {e}")

    def recv(self, timeout: Optional[float] = None) -> Optional[Message]:
        try:
            blob = self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None
        return Message.decode(blob)

    def stats(self) -> dict:
        return {"replay_lag": self._inbox.qsize()}

    def close(self) -> None:
        self._closing.set()
        try:
            self._srv.close()
        except OSError:
            pass


def _recv_exact(conn: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        chunk = conn.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------


def make_transport(spec: FleetSpec, name: str, *, hub=None, root=None,
                   addresses=None, replay: bool = True) -> Transport:
    """Build this endpoint's transport for the spec'd substrate.

    ``hub`` (inproc), ``root`` (filelog) and ``addresses`` (socket) are
    the substrate-shared rendezvous objects — the coordinator creates
    them and hands the relevant one to each worker.
    """
    if spec.transport == "inproc":
        if hub is None:
            raise ValueError("inproc transport needs the shared hub")
        return hub.register(name)
    if spec.transport == "filelog":
        if root is None:
            raise ValueError("filelog transport needs a log directory")
        return FileLogTransport(root, name, replay=replay)
    if addresses is None:
        raise ValueError("socket transport needs the shared address map")
    return SocketTransport(name, addresses)
