"""Coordinator-owned namebook: the fleet's single membership ledger.

Following the DGL ``KVServer`` pattern, the coordinator is the one place
that knows who is in the fleet: every worker's name, liveness, transport
address (socket mode), last heartbeat, protocol progress (last
acknowledged tick, flush version) and restart count live in one
:class:`Namebook` the dispatch loop consults each tick.  Workers never
talk to each other — psi flows worker -> coordinator -> graph combine ->
worker, so membership changes (loss, elastic rejoin) are a single-writer
update here rather than a distributed agreement problem.

The namebook is also where the dedup ledger lives: ``record_reply``
accepts a ``(server, version)``-keyed contribution exactly once and
reports duplicates (re-delivered replies from retried dispatches) so the
caller folds each flush exactly once — the receiver half of the
at-least-once delivery contract (docs/fleet.md).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass
class WorkerEntry:
    """One worker's ledger row."""
    name: str
    server: int                       # its row p of the combination matrix
    alive: bool = False
    address: Optional[tuple] = None   # (host, port) in socket mode
    pid: Optional[int] = None         # OS pid (process realizations)
    last_heartbeat: float = 0.0       # monotonic receive time
    tick_done: int = -1               # last tick it acknowledged
    version: int = 0                  # its flush count (the dedup clock)
    restarts: int = 0
    retries: int = 0                  # send/collect retries spent on it

    def heartbeat_age(self, now: Optional[float] = None) -> float:
        if self.last_heartbeat <= 0.0:
            return float("inf")
        return max(0.0, (time.monotonic() if now is None else now)
                   - self.last_heartbeat)


class Namebook:
    """name -> :class:`WorkerEntry`, plus the ``(server, version)`` dedup
    set.  Single-writer: only the coordinator mutates it."""

    def __init__(self, num_servers: int):
        self.P = num_servers
        self.workers: Dict[str, WorkerEntry] = {
            worker_name(p): WorkerEntry(worker_name(p), p)
            for p in range(num_servers)
        }
        self._seen: set = set()       # (server, version) flushes folded

    def entry(self, name: str) -> WorkerEntry:
        return self.workers[name]

    def by_server(self, p: int) -> WorkerEntry:
        return self.workers[worker_name(p)]

    # ------------------------------------------------------------ membership

    def hello(self, name: str, *, address=None, pid=None,
              tick_done: int = -1, version: int = 0) -> WorkerEntry:
        """Register (or re-register after an elastic restart) a worker.

        A re-registration of a name that was already alive is counted as a
        restart too: it means the worker lost state and came back without
        the coordinator noticing the death first.
        """
        e = self.workers[name]
        if e.last_heartbeat > 0.0:       # not the first hello ever
            e.restarts += 1
        e.alive = True
        e.address = tuple(address) if address is not None else e.address
        e.pid = pid if pid is not None else e.pid
        e.last_heartbeat = time.monotonic()
        e.tick_done = tick_done
        e.version = version
        return e

    def mark_lost(self, name: str) -> None:
        self.workers[name].alive = False

    def heartbeat(self, name: str) -> None:
        e = self.workers.get(name)
        if e is not None:
            e.last_heartbeat = time.monotonic()

    # ------------------------------------------------------------ liveness

    def live_servers(self) -> list:
        return sorted(e.server for e in self.workers.values() if e.alive)

    def down_servers(self) -> list:
        return sorted(e.server for e in self.workers.values() if not e.alive)

    def heartbeat_ages(self) -> list:
        """[P] heartbeat age per server row (inf before first contact)."""
        now = time.monotonic()
        out = [0.0] * self.P
        for e in self.workers.values():
            out[e.server] = e.heartbeat_age(now)
        return out

    # ------------------------------------------------------------ dedup

    def record_reply(self, server: int, version: int) -> bool:
        """True the FIRST time this ``(server, version)`` flush is seen;
        False for re-deliveries (the caller must not fold them again)."""
        key = (int(server), int(version))
        if key in self._seen:
            return False
        self._seen.add(key)
        return True

    # ------------------------------------------------------------ telemetry

    def totals(self) -> Tuple[int, int]:
        """(total retries, total restarts) across the fleet."""
        return (sum(e.retries for e in self.workers.values()),
                sum(e.restarts for e in self.workers.values()))


def worker_name(p: int) -> str:
    return f"worker{p}"


COORDINATOR = "coordinator"
