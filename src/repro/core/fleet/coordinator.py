"""Fleet coordinator: namebook owner, cohort dispatcher, failure detector.

The coordinator process owns the :class:`~repro.core.fleet.namebook.
Namebook` and drives the protocol tick by tick (the TF
``ClusterCoordinator`` schedule-and-retry pattern over the DGL
``KVServer`` membership model):

1. realize the tick's chaos plan (SIGKILL / abrupt-halt injections);
2. draw every server's cohort from the shared deterministic fault-stream
   rng (``STREAM_ARRIVAL``) — realizations are pure in ``(seed, tick)``
   so faulted and unfaulted runs dispatch identical cohorts;
3. dispatch ``(tick, w_p, cohort)`` to each live worker and collect
   replies with per-attempt timeout, bounded retry and exponential
   backoff (``FleetSpec``); a worker that exhausts the budget is marked
   lost in the namebook, its links are folded out of the combination
   matrix for the tick (``fold_dropped_links`` — the same repaired
   effective A_i the simulated resilience runtime uses), and an elastic
   restart is launched from its last checkpoint;
4. fold the replies — deduped per tick (first reply wins) and per
   ``(server, version)`` (a re-delivered flush is charged exactly once) —
   run the eq.-8 graph combine when anyone flushed, and emit the tick's
   ``fleet`` telemetry record (heartbeat ages, retries, restarts, replay
   lag, down servers).

Privacy accounting is worker-authoritative: each worker's q-ledger rides
its checkpoints and its ``bye`` message; the coordinator also records the
``(flushed, q)`` schedule it OBSERVED, and the two agree whenever every
flush reply was collected (the tier-1 chaos test pins this).
"""
from __future__ import annotations

import os
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.fleet.namebook import (COORDINATOR, Namebook, WorkerEntry,
                                       worker_name)
from repro.core.fleet.spec import FleetSpec, parse_fleet_spec
from repro.core.fleet.transport import (InprocHub, Message, make_transport,
                                        pack_array, send_with_retry,
                                        unpack_array)
from repro.core.fleet.worker import (FleetProblem, FleetWorker,
                                     client_shard, logistic_grad,
                                     worker_process_main)
from repro.core.resilience.faults import STREAM_ARRIVAL, fault_stream_rng
from repro.core.resilience.process import fold_dropped_links


@dataclass
class FleetRunResult:
    """One fleet run's trajectory and resilience ledger."""
    msd: np.ndarray                 # [T] centroid MSD vs w_ref
    params: np.ndarray              # final [P, D]
    flushed: np.ndarray             # [T, P] observed release schedule
    q: np.ndarray                   # [T, P] observed per-flush rates
    versions: np.ndarray            # [P] final flush counts
    q_ledgers: List[list]           # per-server worker-authoritative ledger
    retries: int = 0
    restarts: int = 0
    kills: int = 0
    recovery_s: List[float] = field(default_factory=list)  # loss->rejoin
    ticks_per_s: float = 0.0

    @property
    def releases(self) -> np.ndarray:
        return self.flushed.sum(axis=0)


def reference_solution(prob: FleetProblem, iters: int = 3000,
                       lr: float = 0.5) -> np.ndarray:
    """w_ref: full-batch GD on the pooled fleet population (pure numpy
    twin of ``simulate._solve_global``)."""
    hs, gs = [], []
    for p in range(prob.P):
        for k in range(prob.K):
            h, g = client_shard(prob, p, k)
            hs.append(h)
            gs.append(g)
    h = np.concatenate(hs)
    g = np.concatenate(gs)
    w = np.zeros(prob.dim)
    for _ in range(iters):
        w = w - lr * logistic_grad(w, h, g, prob.rho)
    return w


def fleet_cohort(prob: FleetProblem, tick: int) -> np.ndarray:
    """[P, E] cohort draw of the tick — the shared fault-stream rng
    discipline, pure in ``(seed, tick)`` and independent of fleet state
    (a chaos run and its unfaulted twin dispatch identical cohorts)."""
    rng = fault_stream_rng(prob.seed, STREAM_ARRIVAL, tick)
    return np.stack([rng.choice(prob.K, prob.events, replace=False)
                     for _ in range(prob.P)])


class Fleet:
    """Worker lifecycle across the three transport realizations.

    inproc workers are threads sharing an :class:`InprocHub` (a "kill" is
    an abrupt halt flag — no checkpoint, no goodbye — the tier-1-safe
    SIGKILL twin); filelog and socket workers are spawned OS processes
    and a kill is a real ``SIGKILL``.
    """

    def __init__(self, prob: FleetProblem, spec: FleetSpec, ckpt_root: str):
        self.prob = prob
        self.spec = spec
        self.ckpt_root = ckpt_root
        self.hub = InprocHub() if spec.transport == "inproc" else None
        self.log_root = (os.path.join(ckpt_root, "logs")
                         if spec.transport == "filelog" else None)
        self.addresses: Optional[dict] = ({} if spec.transport == "socket"
                                          else None)
        self._members: Dict[int, object] = {}   # p -> thread | Process
        self._inproc_workers: Dict[int, FleetWorker] = {}
        self.coordinator_transport = make_transport(
            spec, COORDINATOR, hub=self.hub, root=self.log_root,
            addresses=self.addresses, replay=False)

    def ckpt_dir(self, p: int) -> str:
        return os.path.join(self.ckpt_root, worker_name(p))

    def spawn(self, p: int) -> None:
        """Start (or elastically restart) server ``p``'s worker from its
        checkpoint directory."""
        if self.spec.transport == "inproc":
            transport = make_transport(self.spec, worker_name(p),
                                       hub=self.hub)
            w = FleetWorker(p, self.prob, self.spec, transport,
                            self.ckpt_dir(p))
            t = threading.Thread(target=w.run, daemon=True,
                                 name=f"fleet-{worker_name(p)}")
            t.start()
            self._inproc_workers[p] = w
            self._members[p] = t
            return
        import multiprocessing as mp
        ctx = mp.get_context("spawn")    # never fork a jax-initialized host
        coord_addr = (self.addresses[COORDINATOR]
                      if self.addresses is not None else None)
        proc = ctx.Process(
            target=worker_process_main,
            args=(p, self.prob.to_dict(), self.spec.to_spec(),
                  self.ckpt_dir(p), self.spec.transport, self.log_root,
                  coord_addr),
            daemon=True, name=f"fleet-{worker_name(p)}")
        proc.start()
        self._members[p] = proc

    def spawn_all(self) -> None:
        for p in range(self.prob.P):
            self.spawn(p)

    def kill(self, p: int) -> None:
        """The ``outage ... kill`` realization: SIGKILL the worker process
        (abrupt-halt flag for inproc threads) — no checkpoint, no
        goodbye."""
        member = self._members.get(p)
        if member is None:
            return
        if self.spec.transport == "inproc":
            self._inproc_workers[p].kill_flag.set()
            member.join(timeout=5.0)
        else:
            if member.pid is not None:
                try:
                    os.kill(member.pid, signal.SIGKILL)
                except ProcessLookupError:
                    pass
            member.join(timeout=5.0)

    def shutdown(self) -> None:
        for p in list(self._members):
            member = self._members[p]
            if self.spec.transport == "inproc":
                self._inproc_workers[p].kill_flag.set()
            member.join(timeout=2.0)
            if self.spec.transport != "inproc" and member.is_alive():
                member.terminate()
        self.coordinator_transport.close()


class Coordinator:
    """The dispatch / collect / repair / combine loop."""

    def __init__(self, fleet: Fleet, *, A: Optional[np.ndarray] = None,
                 w_ref: Optional[np.ndarray] = None,
                 kill_at: Optional[Dict[int, list]] = None,
                 await_rejoin: bool = False):
        from repro.core.topology import combination_matrix
        self.fleet = fleet
        self.prob = fleet.prob
        self.spec = fleet.spec
        self.transport = fleet.coordinator_transport
        self.namebook = Namebook(self.prob.P)
        self.A = (np.asarray(A, np.float64) if A is not None
                  else combination_matrix("ring", self.prob.P))
        self.w_ref = (w_ref if w_ref is not None
                      else reference_solution(self.prob))
        self.kill_at = dict(kill_at or {})
        # barrier-on-rejoin: block the next dispatch until every killed
        # worker's elastic restart has said hello.  Off by default (the
        # fleet degrades to the repaired topology and the straggler
        # rejoins whenever it is back); on for chaos runs that pin
        # EXACT recovery — a process restart costs seconds while ticks
        # cost milliseconds, so without the barrier a short run can end
        # before the rejoin lands.
        self.await_rejoin = await_rejoin
        self.w = np.zeros((self.prob.P, self.prob.dim))
        self.psi_cache = np.zeros((self.prob.P, self.prob.dim))
        self.q_ledgers: Dict[int, list] = {}
        self.kills = 0
        self.recovery_s: List[float] = []
        self._lost_at: Dict[int, float] = {}

    # ------------------------------------------------------------ inbound

    def _handle_admin(self, msg: Message) -> None:
        """Track hellos / heartbeats / byes in the namebook."""
        nb = self.namebook
        if msg.kind == "hello":
            addr = msg.payload.get("address") or None
            e = nb.hello(msg.sender, address=addr,
                         pid=msg.payload.get("pid"),
                         tick_done=int(msg.payload.get("tick_done", -1)),
                         version=msg.version)
            if self.fleet.addresses is not None and addr:
                self.fleet.addresses[msg.sender] = tuple(addr)
            lost = self._lost_at.pop(e.server, None)
            if lost is not None:
                self.recovery_s.append(time.monotonic() - lost)
        elif msg.kind == "heartbeat":
            nb.heartbeat(msg.sender)
        elif msg.kind == "bye":
            e = nb.entry(msg.sender)
            self.q_ledgers[e.server] = list(msg.payload.get("q_history", []))
            nb.mark_lost(msg.sender)

    def _await_hellos(self, deadline_s: float = 30.0) -> None:
        """Block until every worker has said hello once."""
        deadline = time.monotonic() + deadline_s
        while len(self.namebook.live_servers()) < self.prob.P:
            msg = self.transport.recv(timeout=0.1)
            if msg is not None:
                self._handle_admin(msg)
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"fleet failed to assemble: live="
                    f"{self.namebook.live_servers()} of P={self.prob.P}")

    # ------------------------------------------------------------ dispatch

    def _dispatch(self, p: int, tick: int, cohort: np.ndarray) -> bool:
        e = self.namebook.by_server(p)
        msg = Message("cohort", COORDINATOR, tick, {
            "tick": tick, "w": pack_array(self.w[p]),
            "cohort": [int(k) for k in cohort[p]]})
        return send_with_retry(
            self.transport, e.name, msg, self.spec,
            on_retry=lambda a: self._count_retry(e))

    @staticmethod
    def _count_retry(e: WorkerEntry) -> None:
        e.retries += 1

    def _collect(self, tick: int, cohort: np.ndarray,
                 expect: set) -> Dict[int, dict]:
        """Replies for ``tick`` from ``expect``, with retry + backoff;
        servers still missing after the budget are marked lost (and
        elastically restarted)."""
        replies: Dict[int, dict] = {}
        nb = self.namebook
        for attempt in range(1 + self.spec.retry):
            deadline = time.monotonic() + self.spec.timeout
            while replies.keys() < expect and time.monotonic() < deadline:
                msg = self.transport.recv(timeout=0.05)
                if msg is None:
                    continue
                if msg.kind != "psi":
                    self._handle_admin(msg)
                    if msg.kind == "hello":
                        # elastic rejoin mid-collect: fold it back in NOW
                        p = nb.entry(msg.sender).server
                        if p not in replies and self._dispatch(p, tick,
                                                               cohort):
                            expect.add(p)
                    continue
                e = nb.entry(msg.sender)
                nb.heartbeat(msg.sender)
                payload = msg.payload
                if int(payload.get("tick", -1)) != tick:
                    continue              # stale straggler reply: dropped
                if e.server in replies:
                    continue              # duplicate delivery this tick
                if payload.get("flushed") and not nb.record_reply(
                        e.server, msg.version):
                    # replayed flush we already folded under an earlier
                    # tick: treat as a cached announcement, charge nothing
                    payload = dict(payload, flushed=0, q=0.0)
                e.tick_done = tick
                e.version = max(e.version, msg.version)
                replies[e.server] = payload
            missing = expect - replies.keys()
            if not missing:
                return replies
            if attempt < self.spec.retry:
                time.sleep(min(self.spec.backoff_delay(attempt), 2.0))
                for p in sorted(missing):
                    e = nb.by_server(p)
                    e.retries += 1
                    self._dispatch(p, tick, cohort)
        for p in sorted(expect - replies.keys()):
            # loss: elastic restart from the checkpoint; the restarted
            # worker's hello bumps the namebook restart count
            nb.mark_lost(worker_name(p))
            self._lost_at[p] = time.monotonic()
            self.fleet.spawn(p)
        return replies

    # ------------------------------------------------------------ the loop

    def run(self, ticks: int) -> FleetRunResult:
        from repro.telemetry import emit, telemetry_active
        self.fleet.spawn_all()
        self._await_hellos()
        P, T = self.prob.P, ticks
        msd = np.zeros(T)
        flushed = np.zeros((T, P), bool)
        q = np.zeros((T, P))
        t0 = time.monotonic()
        for t in range(T):
            for p in self.kill_at.pop(t, []):
                self.fleet.kill(p)
                self.namebook.mark_lost(worker_name(p))
                self._lost_at[p] = time.monotonic()
                self.kills += 1
                self.fleet.spawn(p)       # elastic restart begins at once
            if self.await_rejoin and self._lost_at:
                self._await_rejoins()
            cohort = fleet_cohort(self.prob, t)
            expect = set()
            for p in self.namebook.live_servers():
                if self._dispatch(p, t, cohort):
                    expect.add(p)
                else:
                    self.namebook.mark_lost(worker_name(p))
                    self._lost_at[p] = time.monotonic()
                    self.fleet.spawn(p)
            replies = self._collect(t, cohort, expect)

            psi = self.psi_cache.copy()
            for p, payload in replies.items():
                psi[p] = unpack_array(payload["psi"])
                flushed[t, p] = bool(payload["flushed"])
                q[t, p] = float(payload["q"])
            down = sorted(set(range(P)) - replies.keys())
            if flushed[t].any():
                # eq. 8 over the repaired topology: a down server keeps
                # only its self-loop, its lost link mass folds back into
                # the surviving endpoints' diagonals (Metropolis)
                mask = ~np.eye(P, dtype=bool) & (self.A > 0)
                if down:
                    mask[down, :] = False
                    mask[:, down] = False
                A_eff = fold_dropped_links(self.A, mask)
                self.w = A_eff.T @ psi
            self.psi_cache = psi
            centroid = self.w.mean(axis=0)
            msd[t] = float(np.sum((centroid - self.w_ref) ** 2))

            if telemetry_active():
                total_retries, total_restarts = self.namebook.totals()
                emit("fleet", {
                    "tick": t,
                    "heartbeat_age": [
                        min(a, 1e6) for a in self.namebook.heartbeat_ages()],
                    "retries": total_retries,
                    "restarts": total_restarts,
                    "replay_lag": int(self.transport.stats().get(
                        "replay_lag", 0)),
                    "down": [int(p in down) for p in range(P)],
                    "flushes": int(flushed[t].sum()),
                    "msd": msd[t],
                })
        wall = max(time.monotonic() - t0, 1e-9)
        self._stop_workers()
        total_retries, total_restarts = self.namebook.totals()
        return FleetRunResult(
            msd=msd, params=self.w.copy(), flushed=flushed, q=q,
            versions=np.asarray([self.namebook.by_server(p).version
                                 for p in range(P)]),
            q_ledgers=[self.q_ledgers.get(p, []) for p in range(P)],
            retries=total_retries, restarts=total_restarts,
            kills=self.kills, recovery_s=list(self.recovery_s),
            ticks_per_s=T / wall)

    def _await_rejoins(self, deadline_s: float = 60.0) -> None:
        """Barrier-on-rejoin: drain admin traffic until every restarted
        worker has said hello (or the deadline passes — then the tick
        proceeds on the repaired topology as usual)."""
        deadline = time.monotonic() + deadline_s
        while self._lost_at and time.monotonic() < deadline:
            msg = self.transport.recv(timeout=0.1)
            if msg is not None and msg.kind != "psi":
                self._handle_admin(msg)

    def _stop_workers(self) -> None:
        """Graceful drain: stop every live worker, harvest bye ledgers."""
        live = set(self.namebook.live_servers())
        for p in sorted(live):
            send_with_retry(self.transport, worker_name(p),
                            Message("stop", COORDINATOR, 0, {}), self.spec)
        deadline = time.monotonic() + max(2.0, self.spec.timeout)
        while live - set(self.q_ledgers) and time.monotonic() < deadline:
            msg = self.transport.recv(timeout=0.1)
            if msg is not None:
                self._handle_admin(msg)
        self.fleet.shutdown()


def run_fleet(prob: FleetProblem, spec: "FleetSpec | str", ticks: int, *,
              ckpt_root: str, A: Optional[np.ndarray] = None,
              w_ref: Optional[np.ndarray] = None,
              kill_at: Optional[Dict[int, list]] = None,
              await_rejoin: bool = False) -> FleetRunResult:
    """Assemble a fleet, run ``ticks`` protocol ticks, tear it down."""
    if isinstance(spec, str):
        spec = parse_fleet_spec(spec)
    fleet = Fleet(prob, spec, ckpt_root)
    coord = Coordinator(fleet, A=A, w_ref=w_ref, kill_at=kill_at,
                        await_rejoin=await_rejoin)
    try:
        return coord.run(ticks)
    finally:
        fleet.shutdown()
