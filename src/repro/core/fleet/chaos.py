"""Chaos harness: kill k < P servers mid-run, prove the fleet recovers.

Two entry points:

:func:`plan_kills`
    realizes a :class:`~repro.core.resilience.faults.FaultModel`'s
    ``outage ... kill=1`` component as a tick -> servers kill schedule,
    drawn from the SAME ``fault_stream_rng(seed, STREAM_TOPOLOGY, tick)``
    uniforms the simulated :class:`~repro.core.resilience.process.
    TopologyProcess` consumes — a ``kill`` realization downs exactly the
    servers a masked realization would have downed, so the simulated and
    the process-level fault injections are the same experiment at two
    fidelities.

:func:`chaos_run`
    runs the faulted fleet and its unfaulted twin (same seeds, same
    cohorts — dispatch draws are pure in ``(seed, tick)``) and reports
    both trajectories plus the recovery ledger.  Acceptance: with
    ``k < P`` kills and elastic restart the faulted run converges to the
    same MSD neighborhood, and when every killed worker restores within
    the retry budget the run is *exactly* the unfaulted one (fold counts,
    release schedule and per-server q-ledgers identical — the tier-1
    chaos test).

Usage (the nightly ``fleet_chaos`` job drives exactly this)::

    from repro.core.fleet import FleetProblem, chaos_run
    out = chaos_run(FleetProblem(P=4), "fleet:transport=filelog",
                    ticks=30, kill_at={9: [2]}, ckpt_root=tmpdir)
    assert out.faulted.restarts >= 1
    assert abs(out.faulted.msd[-1] - out.clean.msd[-1]) < tol
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core.fleet.coordinator import (FleetRunResult, reference_solution,
                                          run_fleet)
from repro.core.fleet.spec import FleetSpec, parse_fleet_spec
from repro.core.fleet.worker import FleetProblem
from repro.core.resilience.faults import (STREAM_TOPOLOGY, FaultModel,
                                          fault_stream_rng,
                                          parse_fault_spec)


def plan_kills(fault: "FaultModel | str", P: int, ticks: int, *,
               seed: int = 0, max_down: Optional[int] = None
               ) -> Dict[int, list]:
    """tick -> [servers to SIGKILL], the process-level ``outage`` twin.

    Uses the topology stream's outage draw (``up = u >= outage``, the
    first P uniforms of the tick — the exact draw order of
    ``TopologyProcess._realize``), gated on ``outage_kill``.  ``max_down``
    caps simultaneous kills at ``P - 1`` by default: the chaos contract
    is k < P (a fully dead fleet has nothing to recover from).
    """
    f = parse_fault_spec(fault) if isinstance(fault, str) else fault
    if not f.outage_kill or f.outage <= 0:
        return {}
    cap = (P - 1) if max_down is None else min(max_down, P - 1)
    plan: Dict[int, list] = {}
    for t in range(ticks):
        rng = fault_stream_rng(seed, STREAM_TOPOLOGY, t)
        down = [p for p, u in enumerate(rng.random(P)) if u < f.outage]
        if down:
            plan[t] = down[:cap]
    return plan


@dataclass
class ChaosOutcome:
    """A faulted run and its unfaulted twin."""
    clean: FleetRunResult
    faulted: FleetRunResult
    kill_plan: Dict[int, list]

    @property
    def msd_gap(self) -> float:
        """|final faulted MSD - final clean MSD| (the convergence-
        neighborhood acceptance metric)."""
        return float(abs(self.faulted.msd[-1] - self.clean.msd[-1]))


def chaos_run(prob: FleetProblem, spec: "FleetSpec | str", *, ticks: int,
              ckpt_root: str, kill_at: Optional[Dict[int, list]] = None,
              fault: "FaultModel | str | None" = None,
              A: Optional[np.ndarray] = None,
              await_rejoin: bool = True) -> ChaosOutcome:
    """Run the unfaulted twin, then the killed run, under one w_ref.

    ``kill_at`` pins an explicit schedule (the deterministic tests /
    demo); ``fault`` derives one from an ``outage:p,kill=1`` spec via
    :func:`plan_kills`.  Separate checkpoint roots keep the two runs'
    write-ahead state apart.  ``await_rejoin`` (default on: the chaos
    contract wants exact recovery) barriers each killed tick on the
    elastic restart's hello so no tick is skipped; turn it off to
    measure degraded-topology behavior instead.
    """
    if isinstance(spec, str):
        spec = parse_fleet_spec(spec)
    plan = dict(kill_at or {})
    if fault is not None:
        merged = plan_kills(fault, prob.P, ticks, seed=prob.seed)
        for t, servers in merged.items():
            plan.setdefault(t, []).extend(
                p for p in servers if p not in plan.get(t, []))
    w_ref = reference_solution(prob)
    clean = run_fleet(prob, spec, ticks, A=A, w_ref=w_ref,
                      ckpt_root=os.path.join(ckpt_root, "clean"))
    faulted = run_fleet(prob, spec, ticks, A=A, w_ref=w_ref,
                        ckpt_root=os.path.join(ckpt_root, "faulted"),
                        kill_at={t: list(s) for t, s in plan.items()},
                        await_rejoin=await_rejoin)
    return ChaosOutcome(clean=clean, faulted=faulted, kill_plan=plan)
