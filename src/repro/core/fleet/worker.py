"""Fleet worker: one server's slice of the event engine, crash-consistent.

A worker owns row ``p`` of the protocol: it folds its dispatched cohort's
client updates into a :class:`~repro.core.events.buffer.
BufferedServerState`-shaped numpy buffer, flushes (announces a protected
psi and charges its privacy ledger) when the buffer fills, and replies to
the coordinator.  Everything it computes is a pure function of
``(seeds, server, tick)`` — client shards, cohort updates and the flush
noise are all derived from counter-based generators — which is what makes
crash recovery *exact*: a restarted worker that replays a tick recomputes
bit-identical results.

Crash consistency is write-ahead checkpointing through
:mod:`repro.checkpoint.io` (crash-atomic ``os.replace`` publish): every
``ckpt_every`` ticks the worker persists ``(params, buffer state,
version, tick_done, accountant q-ledger, last reply)`` BEFORE sending its
reply.  Combined with the dedup keys this yields exactly-once folding
across kills:

* killed before the checkpoint — the coordinator never saw the reply; the
  re-dispatched tick is recomputed deterministically, same fold;
* killed between checkpoint and send — the restored worker sees the
  re-dispatched tick is ``<= tick_done`` and resends the CHECKPOINTED
  reply without re-folding (idempotent replay);
* duplicate delivery (a retried dispatch whose original did arrive) hits
  the same ``tick <= tick_done`` guard.

At ``ckpt_every = 1`` recovery loses nothing; at larger cadences at most
``ckpt_every - 1`` ticks of buffer fold are recomputed-or-lost, as
documented in the ``fleet`` spec grammar.

The module is import-light on purpose (numpy + checkpoint io): it is the
entry point of spawned worker processes (filelog / socket transports) and
of in-process worker threads (the tier-1-safe ``inproc`` realization).
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import asdict, dataclass
from typing import Optional

import numpy as np

from repro.core.fleet.namebook import COORDINATOR, worker_name
from repro.core.fleet.spec import FleetSpec, parse_fleet_spec
from repro.core.fleet.transport import (Message, Transport, make_transport,
                                        pack_array, send_with_retry,
                                        unpack_array)

_SHARD_TAG = 0xDA7A     # client data stream
_NOISE_TAG = 0x4015E    # flush (release) noise stream


@dataclass(frozen=True)
class FleetProblem:
    """The fleet's shared protocol constants (picklable; rides the spawn
    args of every worker process).  Mirrors the Section V logistic setup
    at fleet scale: client ``(p, k)``'s shard is a pure function of
    ``(data_seed, p, k)``."""
    P: int = 4
    K: int = 20            # clients per server
    n: int = 20            # samples per client
    dim: int = 2
    rho: float = 0.01
    mu: float = 0.05
    grad_bound: float = 5.0
    buffer: int = 8        # arrivals per flush (AsyncSpec.buffer analogue)
    events: int = 4        # cohort size per dispatch tick
    sigma_g: float = 0.0   # flush Laplace noise std (0 = noiseless)
    data_seed: int = 0
    seed: int = 0          # protocol seed (noise stream)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FleetProblem":
        return cls(**d)


def client_shard(prob: FleetProblem, p: int, k: int):
    """(h [n, dim], gamma [n]) of client ``(p, k)`` — pure in
    ``(data_seed, p, k)`` (the population-engine sharding discipline)."""
    rng = np.random.default_rng((_SHARD_TAG, prob.data_seed, p, k))
    gamma = np.where(rng.random(prob.n) < 0.5, -1.0, 1.0)
    sigma_h = 0.5 + rng.random()            # heterogeneous client noise
    h = gamma[:, None] + rng.normal(0.0, sigma_h, (prob.n, prob.dim))
    return h, gamma


def logistic_grad(w: np.ndarray, h: np.ndarray, gamma: np.ndarray,
                  rho: float) -> np.ndarray:
    """grad of the rho-regularized mean logistic loss (numpy twin of
    ``simulate.logistic_loss``)."""
    margins = gamma * (h @ w)
    sig = 1.0 / (1.0 + np.exp(np.clip(margins, -50.0, 50.0)))
    return -(gamma * sig) @ h / len(gamma) + rho * w


def clip_to_bound(g: np.ndarray, bound: float) -> np.ndarray:
    if bound <= 0:
        return g
    nrm = float(np.linalg.norm(g))
    return g * min(1.0, bound / max(nrm, 1e-12))


def client_update(prob: FleetProblem, w: np.ndarray, p: int, k: int
                  ) -> np.ndarray:
    """One client's eq.-6 step against the dispatched model."""
    h, gamma = client_shard(prob, p, k)
    grad = clip_to_bound(logistic_grad(w, h, gamma, prob.rho),
                         prob.grad_bound)
    return w - prob.mu * grad


def flush_noise(prob: FleetProblem, p: int, version: int) -> np.ndarray:
    """Release ``version``'s Laplace draw, ``Lap(0, sigma_g/sqrt 2)`` per
    coordinate (std sigma_g, the homomorphic-mechanism convention) — pure
    in ``(seed, p, version)`` so a replayed flush re-draws identically."""
    if prob.sigma_g <= 0:
        return np.zeros(prob.dim)
    rng = np.random.default_rng((_NOISE_TAG, prob.seed, p, version))
    return rng.laplace(0.0, prob.sigma_g / np.sqrt(2.0), prob.dim)


# ---------------------------------------------------------------------------
# checkpoint pytree (variable-shape q ledger => manifest-driven "like")
# ---------------------------------------------------------------------------


def _state_tree(state: dict) -> dict:
    return {k: np.asarray(v) for k, v in state.items()}


def load_worker_checkpoint(path: str) -> Optional[dict]:
    """Restore a worker state dict, or None when no checkpoint exists.

    The state carries a variable-length ``q_history`` ledger, so the
    ``like`` tree :func:`repro.checkpoint.io.load_checkpoint` validates
    against is built from the manifest's own recorded shapes/dtypes."""
    manifest_path = os.path.join(path, "manifest.json")
    if not os.path.exists(manifest_path):
        return None
    from repro.checkpoint.io import load_checkpoint
    with open(manifest_path) as f:
        manifest = json.load(f)
    like = {k: np.zeros(manifest["shapes"][k],
                        np.dtype(manifest["dtypes"][k]))
            for k in manifest["keys"]}
    tree, _ = load_checkpoint(path, like)
    return {k: np.asarray(v) for k, v in tree.items()}


class FleetWorker:
    """The worker event loop (one per server row).

    Runs until a ``stop`` message (graceful: final checkpoint + ``bye``)
    or until ``kill_flag`` is set (the inproc chaos realization of
    SIGKILL: the loop aborts WITHOUT checkpointing, exactly like a killed
    process).  Process realizations are killed for real — see
    :mod:`repro.core.fleet.coordinator`.
    """

    def __init__(self, p: int, prob: FleetProblem, spec: FleetSpec,
                 transport: Transport, ckpt_dir: str):
        self.p = p
        self.prob = prob
        self.spec = spec
        self.transport = transport
        self.ckpt_dir = ckpt_dir
        self.name = worker_name(p)
        self.kill_flag = threading.Event()

        restored = load_worker_checkpoint(ckpt_dir)
        if restored is not None:
            self.params = restored["params"]
            self.buf_sum = restored["buf_sum"]
            self.buf_wsum = float(restored["buf_wsum"])
            self.buf_n = int(restored["buf_n"])
            self.version = int(restored["version"])
            self.psi_cache = restored["psi_cache"]
            self.tick_done = int(restored["tick_done"])
            self.q_history = [float(v) for v in
                              np.atleast_1d(restored["q_history"])
                              [:self.version]]
            self.last_reply = {
                "tick": int(restored["last_tick"]),
                "psi": pack_array(restored["last_psi"]),
                "flushed": int(restored["last_flushed"]),
                "q": float(restored["last_q"]),
            }
        else:
            self.params = np.zeros(prob.dim)
            self.buf_sum = np.zeros(prob.dim)
            self.buf_wsum = 0.0
            self.buf_n = 0
            self.version = 0
            self.psi_cache = np.zeros(prob.dim)
            self.tick_done = -1
            self.q_history: list = []
            self.last_reply: Optional[dict] = None

    # ------------------------------------------------------------ protocol

    def compute_tick(self, tick: int, w: np.ndarray, cohort: list) -> dict:
        """Fold the dispatched cohort, maybe flush; returns the reply
        payload.  Deterministic in ``(prob, tick, w, cohort)``."""
        self.params = np.asarray(w, np.float64)
        updates = [client_update(self.prob, self.params, self.p, int(k))
                   for k in cohort]
        n = len(updates)
        if n:
            # age-0 fold: every staleness weight is 1, mass == count
            self.buf_sum = self.buf_sum + np.sum(updates, axis=0)
            self.buf_wsum += float(n)
            self.buf_n += n
        flushed = self.buf_n >= self.prob.buffer
        if flushed:
            psi = self.buf_sum / max(self.buf_wsum, 1e-12)
            self.version += 1
            psi = psi + flush_noise(self.prob, self.p, self.version)
            q = min(1.0, self.buf_n / self.prob.K)
            self.q_history.append(q)
            self.buf_sum = np.zeros(self.prob.dim)
            self.buf_wsum = 0.0
            self.buf_n = 0
            self.psi_cache = psi
        else:
            psi = self.psi_cache
            q = 0.0
        self.tick_done = tick
        return {"tick": tick, "psi": pack_array(psi),
                "flushed": int(flushed), "q": q}

    def checkpoint(self) -> None:
        """Write-ahead checkpoint (crash-atomic via checkpoint/io.py)."""
        from repro.checkpoint.io import save_checkpoint
        last = self.last_reply or {"tick": -1,
                                   "psi": pack_array(self.psi_cache),
                                   "flushed": 0, "q": 0.0}
        save_checkpoint(self.ckpt_dir, _state_tree({
            "params": self.params,
            "buf_sum": self.buf_sum,
            "buf_wsum": np.float64(self.buf_wsum),
            "buf_n": np.int64(self.buf_n),
            "version": np.int64(self.version),
            "psi_cache": self.psi_cache,
            "tick_done": np.int64(self.tick_done),
            "q_history": np.asarray(self.q_history, np.float64),
            "last_tick": np.int64(last["tick"]),
            "last_psi": unpack_array(last["psi"]),
            "last_flushed": np.int64(last["flushed"]),
            "last_q": np.float64(last["q"]),
        }), step=self.tick_done)

    def _reply(self, payload: dict) -> None:
        send_with_retry(self.transport, COORDINATOR,
                        Message("psi", self.name, self.version, payload),
                        self.spec)

    # ------------------------------------------------------------ main loop

    def run(self) -> None:
        hello = Message("hello", self.name, self.version, {
            "tick_done": self.tick_done, "pid": os.getpid(),
            "address": list(getattr(self.transport, "address", ()) or []),
        })
        send_with_retry(self.transport, COORDINATOR, hello, self.spec)
        stop_beats = threading.Event()
        beats = threading.Thread(target=self._heartbeat_loop,
                                 args=(stop_beats,), daemon=True,
                                 name=f"fleet-beats-{self.name}")
        beats.start()
        try:
            while not self.kill_flag.is_set():
                msg = self.transport.recv(timeout=min(self.spec.heartbeat,
                                                      0.1))
                if msg is None:
                    continue
                if msg.kind == "stop":
                    self.checkpoint()
                    send_with_retry(
                        self.transport, COORDINATOR,
                        Message("bye", self.name, self.version,
                                {"q_history": list(self.q_history)}),
                        self.spec)
                    return
                if msg.kind != "cohort":
                    continue
                tick = int(msg.payload["tick"])
                if tick <= self.tick_done:
                    # duplicate / replayed dispatch: resend the stored
                    # reply, fold NOTHING (exactly-once effect)
                    if self.last_reply is not None \
                            and self.last_reply["tick"] == tick:
                        self._reply(self.last_reply)
                    continue
                payload = self.compute_tick(
                    tick, unpack_array(msg.payload["w"]),
                    msg.payload["cohort"])
                self.last_reply = payload
                if tick % self.spec.ckpt_every == 0:
                    self.checkpoint()     # WRITE-AHEAD: persist, THEN reply
                if self.kill_flag.is_set():
                    return                # killed between checkpoint & send
                self._reply(payload)
        finally:
            stop_beats.set()
            self.transport.close()

    def _heartbeat_loop(self, stop: threading.Event) -> None:
        while not stop.wait(self.spec.heartbeat):
            if self.kill_flag.is_set():
                return
            try:
                self.transport.send(
                    COORDINATOR,
                    Message("heartbeat", self.name, self.version,
                            {"tick_done": self.tick_done}))
            except Exception:
                pass                      # missed beats ARE the signal


def worker_process_main(p: int, prob_dict: dict, spec_str: str,
                        ckpt_dir: str, transport_kind: str,
                        root: Optional[str],
                        coordinator_addr: Optional[tuple]) -> None:
    """Spawned-process entry point (filelog / socket transports).

    Arguments are plain picklable values; the transport is rebuilt inside
    the child.  In socket mode the worker binds an ephemeral port and
    reports its address in the hello — the coordinator's namebook is the
    only place addresses accumulate.
    """
    prob = FleetProblem.from_dict(prob_dict)
    spec = parse_fleet_spec(spec_str)
    name = worker_name(p)
    if transport_kind == "filelog":
        transport = make_transport(spec, name, root=root)
    else:
        addresses = {} if coordinator_addr is None else \
            {COORDINATOR: tuple(coordinator_addr)}
        transport = make_transport(spec, name, addresses=addresses)
    FleetWorker(p, prob, spec, transport, ckpt_dir).run()
