"""Fleet-deployment specification: the ``fleet`` spec grammar.

The multi-process server fleet (see docs/fleet.md) is configured by a
compact spec string so deployments stay flat and hashable, exactly like
``fault`` / ``cohort`` / ``async``::

    fleet
    fleet:transport=filelog
    fleet:transport=socket,retry=3,timeout=2.0,backoff=exp
    fleet:transport=inproc,retry=5,timeout=0.5,backoff=const,heartbeat=0.2

Fields
  ``transport``   message substrate every psi exchange and cohort dispatch
                  travels over: ``inproc`` (in-process queues — the
                  tier-1-safe realization), ``filelog`` (append-only
                  per-endpoint replay logs) or ``socket`` (TCP);
  ``retry``       bounded send/collect retry budget (attempts beyond the
                  first) before a worker is declared lost;
  ``timeout``     per-attempt receive timeout in seconds;
  ``backoff``     retry pacing: ``exp`` doubles the wait per attempt
                  (ClusterCoordinator-style schedule-and-retry), ``const``
                  keeps it fixed;
  ``heartbeat``   worker heartbeat period in seconds (feeds the
                  coordinator's heartbeat-age telemetry and loss
                  detection);
  ``ckpt_every``  write-ahead checkpoint cadence in ticks (1 = every tick;
                  crash recovery can lose at most this many ticks of
                  buffer fold — the chaos tests pin it to 1).

``fleet_to_spec`` is the canonical inverse of :func:`parse_fleet_spec`
(round-trip tested through the GFL005 spec-grammar registry).
"""
from __future__ import annotations

from dataclasses import dataclass

TRANSPORTS = ("inproc", "filelog", "socket")
_BACKOFFS = ("exp", "const")

_DEFAULTS = {"transport": "inproc", "retry": 3, "timeout": 5.0,
             "backoff": "exp", "heartbeat": 0.5, "ckpt_every": 1}


@dataclass(frozen=True)
class FleetSpec:
    """Parsed ``fleet`` spec (see module docstring)."""
    transport: str = "inproc"
    retry: int = 3
    timeout: float = 5.0
    backoff: str = "exp"
    heartbeat: float = 0.5
    ckpt_every: int = 1

    def __post_init__(self):
        if self.transport not in TRANSPORTS:
            raise ValueError(f"unknown fleet transport {self.transport!r}; "
                             f"expected one of {TRANSPORTS}")
        if self.backoff not in _BACKOFFS:
            raise ValueError(f"unknown fleet backoff {self.backoff!r}; "
                             f"expected one of {_BACKOFFS}")
        if self.retry < 0:
            raise ValueError(f"retry must be >= 0, got {self.retry}")
        if self.timeout <= 0:
            raise ValueError(f"timeout must be > 0, got {self.timeout}")
        if self.heartbeat <= 0:
            raise ValueError(f"heartbeat must be > 0, got {self.heartbeat}")
        if self.ckpt_every < 1:
            raise ValueError(f"ckpt_every must be >= 1, "
                             f"got {self.ckpt_every}")

    def backoff_delay(self, attempt: int) -> float:
        """Wait before retry ``attempt`` (0-indexed): ``timeout * 2^a``
        under ``exp`` (TF ClusterCoordinator's schedule-and-retry pacing),
        flat ``timeout`` under ``const``."""
        if self.backoff == "exp":
            return self.timeout * (2.0 ** attempt)
        return self.timeout

    def to_spec(self) -> str:
        """Inverse of :func:`parse_fleet_spec` (canonical form: keys in
        declaration order, defaults omitted, bare ``fleet`` when every
        field is default)."""
        parts = []
        for key in ("transport", "retry", "timeout", "backoff", "heartbeat",
                    "ckpt_every"):
            val = getattr(self, key)
            if val == _DEFAULTS[key]:
                continue
            parts.append(f"{key}={val:g}" if isinstance(val, float)
                         else f"{key}={val}")
        return "fleet:" + ",".join(parts) if parts else "fleet"


def parse_fleet_spec(spec: str) -> FleetSpec:
    """``fleet[:key=value,...]`` -> :class:`FleetSpec`."""
    spec = (spec or "fleet").strip()
    head, sep, rest = spec.partition(":")
    if head != "fleet":
        raise ValueError(f"fleet spec must start with 'fleet', got {spec!r}")
    if sep and not rest:
        raise ValueError(f"empty fleet argument list in {spec!r}")
    kw: dict = {}
    if rest:
        for part in rest.split(","):
            key, eq, val = part.partition("=")
            key = key.strip()
            if not eq or key not in _DEFAULTS:
                raise ValueError(
                    f"bad fleet argument {part!r} in {spec!r}; expected "
                    f"key=value with key in {tuple(_DEFAULTS)}")
            if key in kw:
                raise ValueError(f"duplicate fleet argument {key!r} in "
                                 f"{spec!r}")
            if key in ("transport", "backoff"):
                kw[key] = val.strip()
            elif key in ("retry", "ckpt_every"):
                kw[key] = int(val)
            else:
                kw[key] = float(val)
    return FleetSpec(**kw)
