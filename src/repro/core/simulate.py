"""Paper-scale GFL simulator: Section V experiment (Fig. 2).

P = 10 servers x K = 50 clients, binary logistic regression on synthetic
2-D Gaussian data: gamma = +/-1, h | gamma ~ N(gamma * 1, sigma_h^2 I),
N = 100 samples per client.  Loss is the rho-regularized logistic loss

    Q(w; h, gamma) = ln(1 + exp(-gamma h^T w)) + rho/2 ||w||^2

(rho = 0.01 makes the empirical risks nu-strongly convex, Assumption 2).
The reported metric is the mean-square deviation of the network centroid,
MSD_i = ||w_c,i - w^o||^2, averaged over repeats.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GFLConfig
from repro.core import gfl
from repro.core.topology import combination_matrix


@dataclass(frozen=True)
class LogisticProblem:
    features: jax.Array   # [P, K, N, M]
    labels: jax.Array     # [P, K, N]
    rho: float
    w_opt: jax.Array      # [M] global minimizer


def generate_problem(key: jax.Array, P: int = 10, K: int = 50, N: int = 100,
                     M: int = 2, rho: float = 0.01,
                     sigma_h_range=(0.5, 1.5)) -> LogisticProblem:
    """Synthetic data as in Section V (heterogeneous sigma_h per client)."""
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jnp.where(
        jax.random.bernoulli(k1, 0.5, (P, K, N)), 1.0, -1.0)
    sigma_h = jax.random.uniform(k2, (P, K, 1, 1),
                                 minval=sigma_h_range[0], maxval=sigma_h_range[1])
    noise = jax.random.normal(k3, (P, K, N, M)) * sigma_h
    features = labels[..., None] + noise       # mean gamma * 1-vector
    w_opt = _solve_global(features, labels, rho)
    return LogisticProblem(features, labels, rho, w_opt)


def logistic_loss(w: jax.Array, h: jax.Array, gamma: jax.Array, rho: float
                  ) -> jax.Array:
    """Mean regularized logistic loss over a batch. h: [..., M], gamma: [...]."""
    margins = gamma * (h @ w)
    return jnp.mean(jnp.logaddexp(0.0, -margins)) + 0.5 * rho * jnp.sum(w * w)


def global_risk(w: jax.Array, prob: LogisticProblem) -> jax.Array:
    h = prob.features.reshape(-1, prob.features.shape[-1])
    g = prob.labels.reshape(-1)
    return logistic_loss(w, h, g, prob.rho)


def _solve_global(features, labels, rho, iters: int = 4000, lr: float = 1.0
                  ) -> jax.Array:
    """Full-batch GD to machine precision on the strongly-convex global risk."""
    M = features.shape[-1]
    h = features.reshape(-1, M)
    g = labels.reshape(-1)

    grad = jax.jit(jax.grad(lambda w: logistic_loss(w, h, g, rho)))

    w = jnp.zeros(M)
    for _ in range(iters):
        w = w - lr * grad(w)
    return w


def make_grad_fn(rho: float) -> Callable:
    """grad of Q on a client minibatch: batch = (h [B,M], gamma [B])."""
    def loss(w, batch):
        h, g = batch
        return logistic_loss(w, h, g, rho)
    return jax.grad(loss)


def sample_round_batches(key: jax.Array, prob: LogisticProblem, L: int,
                         batch_size: int):
    """Sample L participating clients per server and a minibatch each.

    Returns pytree (h [P,L,B,M], gamma [P,L,B]).
    """
    P, K, N, M = prob.features.shape
    kc, kb = jax.random.split(key)
    # sampled client indices per server [P, L]
    def pick_clients(k):
        return jax.random.choice(k, K, (L,), replace=False)
    client_idx = jax.vmap(pick_clients)(jax.random.split(kc, P))
    # minibatch indices per (server, client) [P, L, B]
    def pick_batch(k):
        return jax.random.choice(k, N, (batch_size,), replace=False)
    batch_idx = jax.vmap(pick_batch)(
        jax.random.split(kb, P * L)).reshape(P, L, batch_size)

    p_idx = jnp.arange(P)[:, None, None]
    h = prob.features[p_idx, client_idx[:, :, None], batch_idx]      # [P,L,B,M]
    g = prob.labels[p_idx, client_idx[:, :, None], batch_idx]        # [P,L,B]
    return (h, g)


def base_combination_matrix(cfg: GFLConfig, P: int) -> np.ndarray:
    """The config's base A (topology family + seed/rows knobs applied)."""
    return combination_matrix(cfg.topology, P, rows=cfg.torus_rows,
                              seed=cfg.topology_seed)


def run_gfl(prob: LogisticProblem, cfg: GFLConfig, *, iters: int,
            batch_size: int = 10, seed: int = 0, record_every: int = 1,
            A: np.ndarray | None = None,
            process: "TopologyProcess | None" = None,
            record_gaps: bool = False):
    """Run the protocol; return (msd_trace [T], final params [P, D]).

    ``cfg.fault != "none"`` (or an explicit ``process``) routes through the
    resilience runtime: per-round effective A_i, client dropout, straggler
    servers (see repro.core.resilience).  ``record_gaps=True`` additionally
    returns the per-round ``spectral_gap(A_i)`` trajectory.
    """
    from repro.core.resilience import TopologyProcess

    P = prob.features.shape[0]
    if process is None and cfg.fault != "none":
        base = A if A is not None else base_combination_matrix(cfg, P)
        process = TopologyProcess(base, cfg.fault, seed=cfg.topology_seed)
    if process is not None:
        step = gfl.make_gfl_step(process, make_grad_fn(prob.rho), cfg)
    else:
        if A is None:
            A = base_combination_matrix(cfg, P)
        step = gfl.make_gfl_step(jnp.asarray(A), make_grad_fn(prob.rho), cfg)
    L = cfg.effective_clients

    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    state = gfl.init_state(k_init, P, prob.w_opt.shape[0])

    sample = jax.jit(lambda k: sample_round_batches(k, prob, L, batch_size))

    msd = []
    for i in range(iters):
        key, kb = jax.random.split(key)
        state = step(state, sample(kb))
        if i % record_every == 0:
            wc = gfl.centroid(state.params)
            msd.append(float(jnp.sum((wc - prob.w_opt) ** 2)))
    if record_gaps:
        from repro.core.topology import spectral_gap
        gaps = (process.gap_trajectory(iters) if process is not None
                else np.full(iters, spectral_gap(np.asarray(A))))
        return np.asarray(msd), state.params, gaps
    return np.asarray(msd), state.params


def run_gfl_importance(prob: LogisticProblem, cfg: GFLConfig, *, iters: int,
                       batch_size: int = 10, seed: int = 0):
    """GFL with importance-sampled clients ([22],[23]): clients picked with
    probability ~ their running gradient-norm estimate, updates reweighted
    by 1/(K pi_k) to stay unbiased.  Returns (msd trace, final params)."""
    from repro.core import sampling as IS

    P, K, N, M = prob.features.shape
    A = jnp.asarray(base_combination_matrix(cfg, P))
    L = cfg.effective_clients
    grad_fn = make_grad_fn(prob.rho)

    from repro.core.privacy.mechanism import RoundContext, mechanism_for

    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    state = gfl.init_state(k_init, P, M)
    is_state = IS.init_is_state(P, K)
    mech = mechanism_for(cfg)

    @jax.jit
    def round_fn(params, is_state, key, step):
        ctx = RoundContext(step=step)
        k_sel, k_batch, k_priv, k_comb = jax.random.split(key, 4)
        probs = IS.sampling_probs(is_state)
        idx = IS.sample_clients(k_sel, probs, L)               # [P, L]
        w_is = IS.importance_weights(probs, idx)               # [P, L]
        # minibatches for the selected clients
        bidx = jax.vmap(lambda k: jax.random.choice(k, N, (batch_size,),
                                                    replace=False))(
            jax.random.split(k_batch, P * L)).reshape(P, L, batch_size)
        p_ix = jnp.arange(P)[:, None, None]
        h = prob.features[p_ix, idx[:, :, None], bidx]
        g = prob.labels[p_ix, idx[:, :, None], bidx]

        def one_server(w_p, h_p, g_p, w_row, key_p):
            def one_client(hb, gb, wgt):
                grad = grad_fn(w_p, (hb, gb))
                grad = gfl.clip_to_bound(grad, cfg.grad_bound)
                return w_p - cfg.mu * wgt * grad, jnp.linalg.norm(grad)

            w_clients, norms = jax.vmap(one_client)(h_p, g_p, w_row)
            return mech.client_protect(w_clients, key_p, ctx), norms

        psi, norms = jax.vmap(one_server)(
            params, h, g, w_is, jax.random.split(k_priv, P))
        new_params = mech.server_combine(psi, k_comb, A, ctx)
        new_is = IS.update_norm_estimates(is_state, idx, norms)
        return new_params, new_is

    msd = []
    for i in range(iters):
        key, sub = jax.random.split(key)
        params, is_state = round_fn(state.params, is_state, sub, state.step)
        state = gfl.GFLState(params, state.step + 1, key)
        msd.append(float(jnp.sum((gfl.centroid(params) - prob.w_opt) ** 2)))
    return np.asarray(msd), state.params


def run_schemes(key: jax.Array, *, iters: int = 500, sigma_g: float = 0.2,
                P: int = 10, K: int = 50, L: int = 0, mu: float = 0.1,
                repeats: int = 3, topology: str = "full",
                batch_size: int = 10, grad_bound: float = 10.0,
                schemes: tuple | None = None,
                epsilon_target: float | None = None,
                fault: str = "none", topology_seed: int = 0):
    """Fig. 2 harness: run every registered privacy mechanism on the same
    problem (pass `schemes` to restrict).  The ``scheduled`` mechanism
    spends an epsilon budget over the run horizon; by default that budget
    equals what the fixed-sigma Theorem-2 curve spends by `iters`, so its
    row is noise-comparable to the hybrid row.  ``fault`` injects the
    resilience fault model into every scheme's run (same realizations, so
    the rows stay comparable)."""
    from repro.core.privacy.accountant import epsilon_at
    from repro.core.privacy.mechanism import list_mechanisms

    if epsilon_target is None:
        epsilon_target = (epsilon_at(iters, mu, grad_bound, sigma_g)
                          if sigma_g > 0 else 0.0)
    prob = generate_problem(key, P=P, K=K)
    out = {}
    for scheme in schemes if schemes is not None else list_mechanisms():
        cfg = GFLConfig(num_servers=P, clients_per_server=K,
                        clients_sampled=L, topology=topology,
                        privacy=scheme, sigma_g=sigma_g, mu=mu,
                        grad_bound=grad_bound, fault=fault,
                        topology_seed=topology_seed,
                        epsilon_target=epsilon_target, epsilon_horizon=iters)
        traces = []
        for r in range(repeats):
            msd, _ = run_gfl(prob, cfg, iters=iters,
                             batch_size=batch_size, seed=1000 + r)
            traces.append(msd)
        out[scheme] = np.mean(np.stack(traces), axis=0)
    return prob, out


def fault_sweep(prob: LogisticProblem, cfg: GFLConfig, *, iters: int,
                drop_probs, fault_kind: str = "links",
                batch_size: int = 10, seed: int = 0):
    """MSD-vs-failure-rate sweep: run ``cfg`` under ``<fault_kind>:<p>`` for
    every p in ``drop_probs``.  Returns rows of
    ``(p, msd_tail, gap_mean, gap_worst)`` — the realized spectral-gap
    trajectory (lambda_i = rho(A_i - 11^T/P), larger = slower mixing) is
    what connects the failure rate to the convergence hit.
    """
    from dataclasses import replace as dc_replace

    rows = []
    for p in drop_probs:
        spec = "none" if p == 0 else f"{fault_kind}:{p:g}"
        cfg_p = dc_replace(cfg, fault=spec)
        msd, _, gaps = run_gfl(prob, cfg_p, iters=iters,
                               batch_size=batch_size, seed=seed,
                               record_gaps=True)
        tail = float(np.mean(msd[-max(iters // 10, 5):]))
        rows.append((float(p), tail, float(gaps.mean()), float(gaps.max())))
    return rows
