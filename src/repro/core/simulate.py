"""Paper-scale GFL simulator: Section V experiment (Fig. 2).

P = 10 servers x K = 50 clients, binary logistic regression on synthetic
2-D Gaussian data: gamma = +/-1, h | gamma ~ N(gamma * 1, sigma_h^2 I),
N = 100 samples per client.  Loss is the rho-regularized logistic loss

    Q(w; h, gamma) = ln(1 + exp(-gamma h^T w)) + rho/2 ||w||^2

(rho = 0.01 makes the empirical risks nu-strongly convex, Assumption 2).
The reported metric is the mean-square deviation of the network centroid,
MSD_i = ||w_c,i - w^o||^2, averaged over repeats.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GFLConfig
from repro.core import gfl
from repro.core.topology import combination_matrix


@dataclass(frozen=True)
class LogisticProblem:
    features: jax.Array   # [P, K, N, M]
    labels: jax.Array     # [P, K, N]
    rho: float
    w_opt: jax.Array      # [M] global minimizer


def generate_problem(key: jax.Array, P: int = 10, K: int = 50, N: int = 100,
                     M: int = 2, rho: float = 0.01,
                     sigma_h_range=(0.5, 1.5)) -> LogisticProblem:
    """Synthetic data as in Section V (heterogeneous sigma_h per client)."""
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jnp.where(
        jax.random.bernoulli(k1, 0.5, (P, K, N)), 1.0, -1.0)
    sigma_h = jax.random.uniform(k2, (P, K, 1, 1),
                                 minval=sigma_h_range[0], maxval=sigma_h_range[1])
    noise = jax.random.normal(k3, (P, K, N, M)) * sigma_h
    features = labels[..., None] + noise       # mean gamma * 1-vector
    w_opt = _solve_global(features, labels, rho)
    return LogisticProblem(features, labels, rho, w_opt)


def logistic_loss(w: jax.Array, h: jax.Array, gamma: jax.Array, rho: float
                  ) -> jax.Array:
    """Mean regularized logistic loss over a batch. h: [..., M], gamma: [...]."""
    margins = gamma * (h @ w)
    return jnp.mean(jnp.logaddexp(0.0, -margins)) + 0.5 * rho * jnp.sum(w * w)


def global_risk(w: jax.Array, prob: LogisticProblem) -> jax.Array:
    h = prob.features.reshape(-1, prob.features.shape[-1])
    g = prob.labels.reshape(-1)
    return logistic_loss(w, h, g, prob.rho)


def _solve_global(features, labels, rho, iters: int = 4000, lr: float = 1.0
                  ) -> jax.Array:
    """Full-batch GD to machine precision on the strongly-convex global risk."""
    M = features.shape[-1]
    h = features.reshape(-1, M)
    g = labels.reshape(-1)

    grad = jax.jit(jax.grad(lambda w: logistic_loss(w, h, g, rho)))

    w = jnp.zeros(M)
    for _ in range(iters):
        w = w - lr * grad(w)
    return w


def make_grad_fn(rho: float) -> Callable:
    """grad of Q on a client minibatch: batch = (h [B,M], gamma [B])."""
    def loss(w, batch):
        h, g = batch
        return logistic_loss(w, h, g, rho)
    return jax.grad(loss)


def sample_round_batches(key: jax.Array, prob: LogisticProblem, L: int,
                         batch_size: int):
    """Sample L participating clients per server and a minibatch each.

    Returns pytree (h [P,L,B,M], gamma [P,L,B]).  Delegates to the
    population engine's cohort sampler over a dense population — the SAME
    program the engine runs for lazy populations, which is what makes the
    dense path and the population path bit-identical by construction
    (tests/test_population.py).
    """
    from repro.core.population import DensePopulation, uniform_cohort_batch
    return uniform_cohort_batch(
        key, DensePopulation(prob.features, prob.labels, rho=prob.rho), L,
        batch_size)


def base_combination_matrix(cfg: GFLConfig, P: int) -> np.ndarray:
    """The config's base A (topology family + seed/rows knobs applied)."""
    return combination_matrix(cfg.topology, P, rows=cfg.torus_rows,
                              seed=cfg.topology_seed)


def run_gfl(prob: LogisticProblem, cfg: GFLConfig, *, iters: int,
            batch_size: int = 10, seed: int = 0, record_every: int = 1,
            A: np.ndarray | None = None,
            process: "TopologyProcess | None" = None,
            record_gaps: bool = False):
    """Run the protocol; return (msd_trace [T], final params [P, D]).

    ``cfg.fault != "none"`` (or an explicit ``process``) routes through the
    resilience runtime: per-round effective A_i, client dropout, straggler
    servers (see repro.core.resilience).  ``record_gaps=True`` additionally
    returns the per-round ``spectral_gap(A_i)`` trajectory.

    This IS the population engine's pure path over a dense population
    (one loop implementation; docs/population.md): the cohort is always
    the paper's uniform draw here — ``cfg.cohort`` schedulers run through
    :func:`repro.core.population.run_gfl_population`.
    """
    from repro.core.population import DensePopulation
    from repro.core.population.cohort import CohortScheduler
    from repro.core.population.engine import run_gfl_population
    from repro.core.resilience import TopologyProcess

    P = prob.features.shape[0]
    if process is None and cfg.fault != "none":
        base = A if A is not None else base_combination_matrix(cfg, P)
        process = TopologyProcess(base, cfg.fault, seed=cfg.topology_seed)
    pop = DensePopulation.from_problem(prob)
    scheduler = CohortScheduler(pop.num_clients, cfg.effective_clients, P)
    res = run_gfl_population(pop, cfg, iters=iters, batch_size=batch_size,
                             seed=seed, record_every=record_every, A=A,
                             process=process, scheduler=scheduler)
    if record_gaps:
        from repro.core.topology import spectral_gap
        if res.gaps is not None:     # surfaced by the engine (fault runs)
            gaps = res.gaps
        else:
            base = A if A is not None else base_combination_matrix(cfg, P)
            gaps = np.full(iters, spectral_gap(np.asarray(base)))
        return res.msd, res.params, gaps
    return res.msd, res.params


def run_gfl_importance(prob: LogisticProblem, cfg: GFLConfig, *, iters: int,
                       batch_size: int = 10, seed: int = 0):
    """GFL with importance-sampled clients ([22],[23]): clients picked with
    probability ~ their running gradient-norm estimate, updates reweighted
    by 1/(K pi_k) to stay unbiased.  Returns (msd trace, final params).

    One implementation of the weighted round exists — the population
    engine's (repro.core.population.engine); this wrapper runs it over the
    dense problem with an ``importance`` cohort scheduler.
    """
    from dataclasses import replace as dc_replace

    from repro.core.population import run_gfl_population

    res = run_gfl_population(prob, dc_replace(cfg, cohort="importance"),
                             iters=iters, batch_size=batch_size, seed=seed)
    return res.msd, res.params


def run_schemes(key: jax.Array, *, iters: int = 500, sigma_g: float = 0.2,
                P: int = 10, K: int = 50, L: int = 0, mu: float = 0.1,
                repeats: int = 3, topology: str = "full",
                batch_size: int = 10, grad_bound: float = 10.0,
                schemes: tuple | None = None,
                epsilon_target: float | None = None,
                fault: str = "none", topology_seed: int = 0):
    """Fig. 2 harness: run every registered privacy mechanism on the same
    problem (pass `schemes` to restrict).  The ``scheduled`` mechanism
    spends an epsilon budget over the run horizon; by default that budget
    equals what the fixed-sigma Theorem-2 curve spends by `iters`, so its
    row is noise-comparable to the hybrid row.  ``fault`` injects the
    resilience fault model into every scheme's run (same realizations, so
    the rows stay comparable)."""
    from repro.core.privacy.accountant import epsilon_at
    from repro.core.privacy.mechanism import list_mechanisms

    if epsilon_target is None:
        epsilon_target = (epsilon_at(iters, mu, grad_bound, sigma_g)
                          if sigma_g > 0 else 0.0)
    prob = generate_problem(key, P=P, K=K)
    out = {}
    for scheme in schemes if schemes is not None else list_mechanisms():
        cfg = GFLConfig(num_servers=P, clients_per_server=K,
                        clients_sampled=L, topology=topology,
                        privacy=scheme, sigma_g=sigma_g, mu=mu,
                        grad_bound=grad_bound, fault=fault,
                        topology_seed=topology_seed,
                        epsilon_target=epsilon_target, epsilon_horizon=iters)
        traces = []
        for r in range(repeats):
            msd, _ = run_gfl(prob, cfg, iters=iters,
                             batch_size=batch_size, seed=1000 + r)
            traces.append(msd)
        out[scheme] = np.mean(np.stack(traces), axis=0)
    return prob, out


def fault_sweep(prob: LogisticProblem, cfg: GFLConfig, *, iters: int,
                drop_probs, fault_kind: str = "links",
                batch_size: int = 10, seed: int = 0):
    """MSD-vs-failure-rate sweep: run ``cfg`` under ``<fault_kind>:<p>`` for
    every p in ``drop_probs``.  Returns rows of
    ``(p, msd_tail, gap_mean, gap_worst)`` — the realized spectral-gap
    trajectory (lambda_i = rho(A_i - 11^T/P), larger = slower mixing) is
    what connects the failure rate to the convergence hit.
    """
    from dataclasses import replace as dc_replace

    rows = []
    for p in drop_probs:
        spec = "none" if p == 0 else f"{fault_kind}:{p:g}"
        cfg_p = dc_replace(cfg, fault=spec)
        msd, _, gaps = run_gfl(prob, cfg_p, iters=iters,
                               batch_size=batch_size, seed=seed,
                               record_gaps=True)
        tail = float(np.mean(msd[-max(iters // 10, 5):]))
        rows.append((float(p), tail, float(gaps.mean()), float(gaps.max())))
    return rows
