"""Streaming population-scale executor for the GFL protocol.

Runs :func:`repro.core.gfl.gfl_round` semantics over *virtual* client
populations: per round only the sampled ``[P, L]`` cohort is materialized
(``ClientPopulation.gather``), so memory and compute are independent of the
population size K.  Two execution paths:

**Pure path** (``CohortScheduler.pure``: uniform sampler, always-available
trace).  The engine reuses the dense simulator's EXACT programs — the same
jitted cohort sampler (:func:`uniform_cohort_batch`, which
``simulate.sample_round_batches`` itself delegates to) and the same
``gfl.make_gfl_step`` step (including the resilience runtime when
``cfg.fault`` is set) — so at full participation (L = K) trajectories are
bit-identical to the dense path.  This is the regression anchor of
tests/test_population.py.

**Weighted path** (importance sampling and/or availability traces).  Cohorts
are drawn WITH replacement from the scheduler's effective probabilities and
client updates carry the unbiased ``1/(K pi_k)`` reweighting of [23]
(:mod:`repro.core.sampling`); observed gradient norms feed the sampler's
running estimates.  Mid-round dropout routes through the mechanism's
dropout-safe ``client_protect_masked`` hook (same refusal semantics as the
resilience runtime); per-round link faults realize effective matrices from
the ``TopologyProcess``.  Straggler faults need the runtime's psi cache and
are pure-path only.

``run_gfl_population(..., scan=True)`` additionally compiles the whole
pure-path run as one ``lax.scan`` over rounds — cohort batches are
regenerated *inside* the scan body from the round key, so peak memory stays
at one cohort regardless of the horizon (this is the benchmark path:
``benchmarks/population_scale.py``).

Privacy composes through the scheduler's realized sampling rate: pass
``scheduler.realized_q`` (or the per-round ``q`` trace this module returns)
to ``PrivacyAccountant.amplified_epsilon`` — see docs/population.md.
"""
from __future__ import annotations

from contextlib import nullcontext
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GFLConfig
from repro.core import gfl
from repro.core.population.cohort import CohortScheduler
from repro.core.population.population import (
    ClientPopulation,
    DensePopulation,
    population_from_spec,
)
from repro.core.privacy.mechanism import RoundContext, mechanism_for
from repro.core.resilience.process import TopologyProcess
from repro.sanitize import ReleaseLedger, sanitize_enabled, sanitizer_scope
from repro.telemetry import (MetricsStream, RunLog, session_from_config,
                             telemetry_active, trace_span)
from repro.core.simulate import (
    _solve_global,
    base_combination_matrix,
    make_grad_fn,
)


def uniform_cohort_indices(key: jax.Array, P: int, K: int, N: int, L: int,
                           batch_size: int):
    """The dense simulator's cohort index draw: split into (clients,
    batches), choice WITHOUT replacement per server, per-(server, client)
    minibatch indices.  Returns (client_idx [P, L], batch_idx [P, L, B]).

    This is THE cohort-draw program — ``uniform_cohort_batch`` (and through
    it ``simulate.sample_round_batches``) and the event engine's tick
    sampler all call it, which is what makes their sync limits
    bit-identical by construction.
    """
    kc, kb = jax.random.split(key)

    def pick_clients(k):
        return jax.random.choice(k, K, (L,), replace=False)

    client_idx = jax.vmap(pick_clients)(jax.random.split(kc, P))

    def pick_batch(k):
        return jax.random.choice(k, N, (batch_size,), replace=False)

    batch_idx = jax.vmap(pick_batch)(
        jax.random.split(kb, P * L)).reshape(P, L, batch_size)
    return client_idx, batch_idx


def uniform_cohort_batch(key: jax.Array, pop: ClientPopulation, L: int,
                         batch_size: int):
    """The dense simulator's cohort draw, over any population.

    Key discipline and index computation are exactly those of the original
    ``sample_round_batches`` (which now delegates here) — see
    :func:`uniform_cohort_indices`.  Returns (h [P, L, B, M],
    gamma [P, L, B]).
    """
    client_idx, batch_idx = uniform_cohort_indices(
        key, pop.P, pop.num_clients, pop.samples_per_client, L, batch_size)
    return pop.gather(client_idx, batch_idx)


def as_population(source, cfg: GFLConfig) -> ClientPopulation:
    """Coerce the engine's data source: a ClientPopulation passes through, a
    materialized LogisticProblem is wrapped dense, None builds the
    population named by ``cfg.population``."""
    if isinstance(source, ClientPopulation):
        return source
    if source is None:
        return population_from_spec(cfg)
    if hasattr(source, "features") and hasattr(source, "labels"):
        return DensePopulation.from_problem(source)
    raise TypeError(f"cannot interpret {type(source).__name__} as a "
                    "client population")


def estimate_w_ref(pop: ClientPopulation, *, sample_clients: int = 32,
                   seed: int = 0, iters: int = 2000) -> jax.Array:
    """Monte-Carlo reference minimizer for lazy populations: materialize a
    uniform client subsample and solve its strongly-convex empirical risk
    to machine precision (exact when sample_clients >= K)."""
    C = min(sample_clients, pop.num_clients)
    key = jax.random.PRNGKey(seed)
    idx = jax.vmap(
        lambda k: jax.random.choice(k, pop.num_clients, (C,), replace=False)
    )(jax.random.split(key, pop.P))
    N = pop.samples_per_client
    bidx = jnp.broadcast_to(jnp.arange(N)[None, None, :], (pop.P, C, N))
    h, g = pop.gather(idx, bidx)
    return _solve_global(h, g, pop.rho, iters=iters)


class PopulationRunResult(NamedTuple):
    """Trajectory of one population-engine run.

    ``gaps`` / ``staleness`` surface the resilience runtime's per-round
    realizations when a fault process drives the run (None otherwise):
    the realized ``spectral_gap(A_i)`` trajectory and, on the pure path,
    the per-server straggler psi ages after every round."""
    msd: np.ndarray            # centroid MSD vs w_ref, every record_every
    params: jax.Array          # final [P, D] per-server models
    q: np.ndarray              # realized per-round sampling rate
    scheduler: CohortScheduler  # carries IS state + q ledger for reuse
    gaps: Optional[np.ndarray] = None       # [iters] realized spectral gaps
    staleness: Optional[np.ndarray] = None  # [iters, P] straggler psi ages
    accountant: Optional[object] = None     # PrivacyAccountant, charged at
                                            # the realized per-round q


def _make_weighted_round(pop: ClientPopulation, cfg: GFLConfig, grad_fn,
                         mech, batch_size: int, use_alive: bool):
    """jit-ready weighted round: cohort ids/weights (and the dropout mask)
    are traced runtime args, so one compilation serves every round."""
    N = pop.samples_per_client
    tau = cfg.combine_every

    @jax.jit
    def round_fn(params, key, step_i, A_r, idx, weights, alive):
        ctx = RoundContext(step=step_i)
        k_batch, k_priv, k_comb = jax.random.split(key, 3)
        P, L = idx.shape
        bidx = jax.vmap(
            lambda k: jax.random.choice(k, N, (batch_size,), replace=False)
        )(jax.random.split(k_batch, P * L)).reshape(P, L, batch_size)
        h, g = pop.gather(idx, bidx)

        if cfg.use_kernels and mech.fold_spec(ctx) is not None:
            # fused round-fold kernel: importance weights pre-clip, alive
            # masks as fold weights, noise/masks at the survivor mean —
            # one two-pass stream over the [P, L, D] gradients
            grads = gfl._client_grads(params, (h, g), grad_fn)
            fold_w, noise_w = gfl._survivor_weights(
                alive if use_alive else None)
            psi, sq = gfl._fused_client_fold(
                params, grads, jax.random.split(k_priv, P), cfg, mech, ctx,
                pre_w=weights, fold_w=fold_w, noise_w=noise_w)
            # sampler feedback: the unweighted clipped norm, derived from
            # the kernel's norms pass (no extra HBM sweep)
            norms = jnp.sqrt(sq)
            if cfg.grad_bound > 0:
                norms = jnp.minimum(cfg.grad_bound, norms)
        else:
            psi, norms = _ref_round(params, h, g, weights, alive, k_priv,
                                    ctx)
        if tau > 1:
            do_combine = step_i % tau == tau - 1
            new_params = jax.lax.cond(
                do_combine,
                lambda p: mech.server_combine(p, k_comb, A_r, ctx),
                lambda p: p, psi)
        else:
            new_params = mech.server_combine(psi, k_comb, A_r, ctx)
        return new_params, norms

    def _ref_round(params, h, g, weights, alive, k_priv, ctx):
        P, L = weights.shape

        def one_server(w_p, h_p, g_p, w_row, key_p, alive_p):
            def one_client(hb, gb, wgt):
                grad = grad_fn(w_p, (hb, gb))
                # the importance weight is applied BEFORE the sensitivity
                # clip: each client's step stays inside the mu*B ball the
                # privacy calibration (eq. 26) assumes, so heavy cohort
                # weights saturate (clipping bias) instead of silently
                # inflating the sensitivity the noise was scaled for.  The
                # sampler's norm feedback stays the unweighted clipped norm.
                step_g = gfl.clip_to_bound(wgt * grad, cfg.grad_bound)
                clipped = gfl.clip_to_bound(grad, cfg.grad_bound)
                return w_p - cfg.mu * step_g, jnp.linalg.norm(clipped)

            w_clients, norms = jax.vmap(one_client)(h_p, g_p, w_row)
            if use_alive:
                psi = mech.client_protect_masked(w_clients, key_p, alive_p,
                                                 ctx)
            else:
                psi = mech.client_protect(w_clients, key_p, ctx)
            return psi, norms

        alive_arg = (alive if use_alive
                     else jnp.ones(weights.shape, jnp.bool_))
        return jax.vmap(one_server)(
            params, h, g, weights, jax.random.split(k_priv, P), alive_arg)

    return round_fn


def run_gfl_population(source, cfg: GFLConfig, *, iters: int,
                       batch_size: int = 10, seed: int = 0,
                       record_every: int = 1,
                       A: Optional[np.ndarray] = None,
                       process: Optional[TopologyProcess] = None,
                       scheduler: Optional[CohortScheduler] = None,
                       w_ref=None, scan: bool = False
                       ) -> PopulationRunResult:
    """Run the GFL protocol over a (virtual) client population.

    Thin accounting/sanitizing shell around the executor: the returned
    result carries a :class:`PrivacyAccountant` charged once per round at
    that round's *realized* sampling rate (the same q trace the result
    exposes), so every engine run has its budget bookkeeping attached
    rather than left to the caller.  Under sanitize mode
    (``cfg.sanitize`` / ``REPRO_SANITIZE=1``) the run executes inside
    :func:`repro.sanitize.sanitizer_scope` (key-reuse + NaN debugging)
    and the release/charge ledger is cross-checked.
    """
    sanitize = sanitize_enabled(cfg)
    with session_from_config(cfg):
        with sanitizer_scope() if sanitize else nullcontext():
            with trace_span("population_run", iters=iters, scan=scan):
                res = _run_population_impl(
                    source, cfg, iters=iters, batch_size=batch_size,
                    seed=seed, record_every=record_every, A=A,
                    process=process, scheduler=scheduler, w_ref=w_ref,
                    scan=scan)
        acc = mechanism_for(cfg).accountant()
        acc.sampling_rate = res.scheduler.L / res.scheduler.K
        with trace_span("privacy_accounting", releases=iters):
            for qi in np.asarray(res.q):
                acc.advance(1, q=float(qi))
    if sanitize:
        ledger = ReleaseLedger()
        ledger.record_release(iters)   # one client-level release per round
        ledger.charge_from(acc)
        ledger.cross_check()
        if not np.all(np.isfinite(np.asarray(res.msd))):
            from repro.sanitize import SanitizerError
            raise SanitizerError("non-finite MSD trajectory under "
                                 "sanitize mode")
    return res._replace(accountant=acc)


def _run_population_impl(source, cfg: GFLConfig, *, iters: int,
                         batch_size: int = 10, seed: int = 0,
                         record_every: int = 1,
                         A: Optional[np.ndarray] = None,
                         process: Optional[TopologyProcess] = None,
                         scheduler: Optional[CohortScheduler] = None,
                         w_ref=None, scan: bool = False
                         ) -> PopulationRunResult:
    """Run the GFL protocol over a (virtual) client population.

    ``source``: a :class:`ClientPopulation`, a materialized
    ``LogisticProblem`` (wrapped dense), or None (build from
    ``cfg.population``).  Cohort behavior comes from ``cfg.cohort`` (or an
    explicit ``scheduler``), faults from ``cfg.fault`` exactly as in
    ``run_gfl``.  On the pure scheduler path this function IS ``run_gfl``
    modulo the population abstraction — bit-identical at L = K.
    """
    pop = as_population(source, cfg)
    P, K = pop.P, pop.num_clients
    grad_fn = make_grad_fn(pop.rho)
    if scheduler is None:
        scheduler = CohortScheduler.from_config(
            cfg, K=K, L=cfg.clients_sampled or K)
    L = scheduler.L
    if w_ref is None:
        w_ref = pop.w_ref
    if w_ref is None:
        # lazy populations carry no minimizer — estimate one so res.msd is
        # an actual mean-square deviation, not distance-to-origin (pass an
        # explicit w_ref to skip the one-off Monte-Carlo solve)
        w_ref = estimate_w_ref(pop)
    w_ref_j = jnp.asarray(w_ref)

    if process is None and cfg.fault != "none":
        base = A if A is not None else base_combination_matrix(cfg, P)
        process = TopologyProcess(base, cfg.fault, seed=cfg.topology_seed)
    if A is None:
        A = base_combination_matrix(cfg, P)

    if scheduler.pure:
        if scan:
            if process is not None or cfg.combine_every > 1:
                raise ValueError(
                    "scan executor supports the static-topology, "
                    "combine_every=1 pure path; use scan=False")
            msd, params = _run_pure_scan(pop, cfg, A, grad_fn, L,
                                         batch_size, iters, seed, w_ref_j)
            q = np.full(iters, L / K)
            log = RunLog("population")
            log.extend_arrays({"msd": np.asarray(msd), "q": q,
                               "cohort": np.full(iters, L)})
            msd = msd[::record_every]
            scheduler.q_history.extend(q.tolist())
            return PopulationRunResult(np.asarray(msd), params, q, scheduler)
        log, params = _run_pure_loop(
            pop, cfg, A, process, grad_fn, L, batch_size, iters, seed,
            record_every, w_ref_j)
        q = np.full(iters, L / K)
        scheduler.q_history.extend(q.tolist())
        return PopulationRunResult(log.stack("msd"), params, q, scheduler,
                                   gaps=log.stack("gap"),
                                   staleness=log.stack("staleness"))

    # ------------------------------------------------------- weighted path
    if scan:
        raise ValueError(
            "scan executor supports only the pure cohort path (uniform "
            "sampler, always trace); weighted cohorts need per-round host "
            "realizations — use scan=False")
    if process is not None and process.fault.straggler > 0:
        raise ValueError(
            "straggler faults need the resilience runtime's psi cache and "
            "are only supported on the pure cohort path (uniform sampler, "
            "always trace); drop the straggler: component or use "
            "cohort='uniform'")
    mech = mechanism_for(cfg)
    use_alive = scheduler.fault.client_dropout > 0
    if use_alive:
        from repro.core.resilience.runtime import ensure_dropout_safe
        ensure_dropout_safe(mech.noise_profile(),
                            where="population cohort dropout")
    round_fn = _make_weighted_round(pop, cfg, grad_fn, mech, batch_size,
                                    use_alive)
    Aj = jnp.asarray(A, jnp.float32)

    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    state = gfl.init_state(k_init, P, pop.dim)
    params = state.params
    log = RunLog("population")
    for i in range(iters):
        key, sub = jax.random.split(key)
        k_sel, k_round = jax.random.split(sub)
        sel = scheduler.select(k_sel, i)
        A_r = (jnp.asarray(process.realize(i).A, jnp.float32)
               if process is not None and not process.static else Aj)
        gap = process.realize(i).gap if process is not None else None
        weights = (sel.weights if sel.weights is not None
                   else jnp.ones((P, L)))
        alive = (sel.alive if sel.alive is not None
                 else jnp.ones((P, L), jnp.bool_))
        params, norms = round_fn(params, k_round, jnp.asarray(i, jnp.int32),
                                 A_r, sel.client_idx, weights, alive)
        scheduler.observe(sel.client_idx, norms)
        msd = None
        if i % record_every == 0:
            wc = gfl.centroid(params)
            msd = float(jnp.sum((wc - w_ref_j) ** 2))
        norm_mean = norm_max = None
        if telemetry_active():      # extra device syncs only when observed
            norm_mean = float(jnp.mean(norms))
            norm_max = float(jnp.max(norms))
        log.row(i, msd=msd, gap=gap, q=scheduler.q_history[-1],
                cohort=L, grad_norm_mean=norm_mean, grad_norm_max=norm_max)
    return PopulationRunResult(log.stack("msd"), params,
                               np.asarray(scheduler.q_history[-iters:]),
                               scheduler, gaps=log.stack("gap"))


def _run_pure_loop(pop, cfg, A, process, grad_fn, L, batch_size, iters,
                   seed, record_every, w_ref_j):
    """The dense simulator's loop verbatim, over the population gather.

    Returns (:class:`~repro.telemetry.RunLog`, params): per-round records
    carry msd (every ``record_every``) and, with a fault process, the
    resilience runtime's realizations (gap, per-server psi ages) — the
    result's legacy ``gaps``/``staleness`` fields are stacked views over
    these rows, and the same rows feed the ``round`` telemetry stream."""
    with trace_span("population_compile", faulted=process is not None):
        if process is not None:
            step = gfl.make_gfl_step(process, grad_fn, cfg)
        else:
            step = gfl.make_gfl_step(jnp.asarray(A), grad_fn, cfg)
        sample = jax.jit(
            lambda k: uniform_cohort_batch(k, pop, L, batch_size))
    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    state = gfl.init_state(k_init, pop.P, pop.dim)
    log = RunLog("population")
    for i in range(iters):
        key, kb = jax.random.split(key)
        state = step(state, sample(kb))
        gap = age = None
        if process is not None:
            gap = process.realize(i).gap          # memoized with the step's
            age = np.asarray(state.psi_age)       # own realization
        msd = None
        if i % record_every == 0:
            wc = gfl.centroid(state.params)
            msd = float(jnp.sum((wc - w_ref_j) ** 2))
        log.row(i, msd=msd, gap=gap, staleness=age,
                q=L / pop.num_clients, cohort=L)
    return log, state.params


def _run_pure_scan(pop, cfg, A, grad_fn, L, batch_size, iters, seed,
                   w_ref_j):
    """Whole-run lax.scan: one compilation, cohort regenerated per round
    inside the body — peak memory is ONE [P, L, B, M] cohort."""
    mech = mechanism_for(cfg)
    Aj = jnp.asarray(A)

    # in-graph tap: constructed ONLY when a session is active, so the
    # off-path carry/program is exactly the uninstrumented one; at
    # flush_every > 1 (REPRO_TELEMETRY_FLUSH_EVERY) rows buffer N rounds
    # per ordered io_callback flush — the scan stays fused either way
    ms = (MetricsStream("step", fields=("step", "msd"))
          if telemetry_active() else None)

    def body(carry, _):
        loop_key, state = carry[0], carry[1]
        loop_key, kb = jax.random.split(loop_key)
        batch = uniform_cohort_batch(kb, pop, L, batch_size)
        key, sub = jax.random.split(state.key)
        new_params = gfl.gfl_round(state.params, batch, sub, A=Aj,
                                   grad_fn=grad_fn, cfg=cfg, mechanism=mech,
                                   step=state.step)
        new_state = gfl.GFLState(new_params, state.step + 1, key)
        msd = jnp.sum((gfl.centroid(new_params) - w_ref_j) ** 2)
        if ms is None:
            return (loop_key, new_state), msd
        acc = ms.tap(carry[2], {"step": new_state.step, "msd": msd})
        return (loop_key, new_state, acc), msd

    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    state = gfl.init_state(k_init, pop.P, pop.dim)
    carry0 = ((key, state) if ms is None else (key, state, ms.init()))
    with trace_span("population_scan", iters=iters):
        final, msd = jax.lax.scan(body, carry0, None, length=iters)
    state = final[1]
    if ms is not None:
        jax.effects_barrier()       # in-scan flushes land before the tail
        ms.drain(final[2] if len(final) > 2 else None)
    return np.asarray(msd), state.params
