"""Cohort scheduling: who participates in round i, and at what rate.

A :class:`CohortScheduler` draws each round's ``[P, L]`` cohort by
composing three processes:

  1. an **availability trace** — per-client availability probabilities
     (diurnal phase patterns, device classes) realized deterministically in
     ``(seed, round)`` exactly like the resilience runtime's fault draws;
  2. the **sampler** — uniform, or the importance sampler of
     :mod:`repro.core.sampling` (probabilities ~ running gradient-norm
     estimates, sampled WITH replacement per [23] so the 1/(K pi)
     reweighting stays unbiased);
  3. **mid-round dropout** from the ``dropout:`` component of the
     ``GFLConfig.fault`` spec (same stream constants as
     ``TopologyProcess.client_alive``, so a scheduler and a topology
     process given the same seed realize the same masks).

The scheduler also reports the **realized sampling rate q** of every round
— the quantity subsampling amplification is accounted against
(``PrivacyAccountant.amplified_epsilon``; arXiv:2301.06412): under uniform
sampling q_i = L / K_avail, under importance sampling the conservative
per-client bound q_i = min(1, L * max_k pi_k).

Specs live in ``GFLConfig.cohort`` (flat, hashable)::

    uniform
    importance,floor=0.1
    uniform+trace:diurnal,period=24,min=0.2
    importance+trace:devclass,slow=0.4,p=0.3

The plain ``uniform`` scheduler with an ``always`` trace is the *pure*
path: the engine then reuses the dense simulator's exact sampling program
and trajectories stay bit-identical (docs/population.md).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sampling as IS
from repro.core.resilience.faults import (
    STREAM_AVAILABILITY,
    FaultModel,
    client_dropout_mask,
    fault_stream_rng,
    parse_fault_spec,
)

_TRACES = ("always", "diurnal", "devclass")


@dataclass(frozen=True)
class AvailabilityTrace:
    """Per-client availability probabilities as a function of the round.

    ``always``    every client available every round (prob 1);
    ``diurnal``   sinusoidal day/night pattern: client k's phase is
                  ``(k mod period) / period`` (clients spread around the
                  clock), availability in [min, 1];
    ``devclass``  two device classes: a ``slow`` fraction of clients
                  (chosen by a golden-ratio hash of k, not by id order) is
                  available with probability ``p``, the rest always.
    """
    kind: str = "always"
    period: int = 24        # diurnal: rounds per simulated day
    min_avail: float = 0.2  # diurnal: trough availability
    slow_frac: float = 0.3  # devclass: fraction of constrained clients
    slow_p: float = 0.5     # devclass: their availability probability

    def __post_init__(self):
        if self.kind not in _TRACES:
            raise ValueError(f"unknown availability trace {self.kind!r}; "
                             f"expected one of {_TRACES}")

    @property
    def always_on(self) -> bool:
        return self.kind == "always"

    def probs(self, round_idx: int, K: int) -> np.ndarray:
        """[K] availability probabilities for this round."""
        k = np.arange(K)
        if self.kind == "always":
            return np.ones(K)
        if self.kind == "diurnal":
            phase = (k % self.period) / self.period
            wave = 0.5 * (1.0 + np.sin(
                2.0 * np.pi * (round_idx / self.period + phase)))
            return self.min_avail + (1.0 - self.min_avail) * wave
        # devclass: golden-ratio hash decorrelates class from client id
        u = ((k * 2654435761) % (1 << 32)) / float(1 << 32)
        return np.where(u < self.slow_frac, self.slow_p, 1.0)

    def to_spec(self) -> str:
        """Inverse of :func:`parse_trace_spec` (canonical form)."""
        if self.kind == "always":
            return "always"
        if self.kind == "diurnal":
            return f"diurnal,period={self.period},min={self.min_avail:g}"
        return f"devclass,slow={self.slow_frac:g},p={self.slow_p:g}"


def parse_trace_spec(spec: str) -> AvailabilityTrace:
    """``always`` | ``diurnal[,period=..][,min=..]`` |
    ``devclass[,slow=..][,p=..]``."""
    name, *parts = (spec or "always").strip().split(",")
    kw: dict = {}
    keys = {"diurnal": {"period": ("period", int), "min": ("min_avail", float)},
            "devclass": {"slow": ("slow_frac", float), "p": ("slow_p", float)},
            "always": {}}
    if name not in keys:
        raise ValueError(f"unknown availability trace {name!r}; "
                         f"expected one of {_TRACES}")
    for part in parts:
        k, sep, v = part.partition("=")
        if not sep or k not in keys[name]:
            raise ValueError(
                f"unknown argument {part!r} for trace {name!r}")
        fname, conv = keys[name][k]
        kw[fname] = conv(v)
    return AvailabilityTrace(kind=name, **kw)


def parse_cohort_spec(spec: str):
    """``sampler[+trace:<trace spec>]`` -> (sampler, floor, trace)."""
    spec = (spec or "uniform").strip()
    trace = AvailabilityTrace()
    sampler, floor = "uniform", 0.1
    for part in spec.split("+"):
        part = part.strip()
        if part.startswith("trace:"):
            trace = parse_trace_spec(part[len("trace:"):])
            continue
        name, *args = part.split(",")
        if name not in ("uniform", "importance"):
            raise ValueError(
                f"bad cohort component {part!r} in spec {spec!r}; expected "
                "'uniform' or 'importance[,floor=f]' plus optional "
                "'trace:<spec>'")
        sampler = name
        for a in args:
            k, sep, v = a.partition("=")
            if name == "importance" and k == "floor" and sep:
                floor = float(v)
            else:
                raise ValueError(
                    f"unknown argument {a!r} for cohort sampler {name!r}")
    return sampler, floor, trace


def cohort_to_spec(sampler: str, floor: float,
                   trace: AvailabilityTrace) -> str:
    """Inverse of :func:`parse_cohort_spec` (canonical form): the floor is
    an importance-sampler knob and is only serialized there."""
    if sampler == "importance":
        out = f"importance,floor={floor:g}"
    elif sampler == "uniform":
        out = "uniform"
    else:
        raise ValueError(f"unknown cohort sampler {sampler!r}")
    if not trace.always_on:
        out += "+trace:" + trace.to_spec()
    return out


class CohortSelection(NamedTuple):
    """One round's realized cohort."""
    client_idx: jax.Array            # [P, L] population client ids
    weights: Optional[jax.Array]     # [P, L] unbiased 1/(K pi); None = all-1
    alive: Optional[jax.Array]       # [P, L] bool dropout mask; None = all
    q: float                         # realized per-round sampling rate


class CohortScheduler:
    """Draws per-round cohorts; owns the IS state and the realized-q ledger.

    Deterministic in ``(seed, round)`` on the host side (availability and
    dropout realizations), with the jax key passed to :meth:`select`
    driving the actual client draws — mirroring how the resilience runtime
    splits host realizations from traced computation.
    """

    def __init__(self, K: int, L: int, P: int, *, sampler: str = "uniform",
                 floor: float = 0.1, trace: AvailabilityTrace | str = "always",
                 fault: FaultModel | str = "none", seed: int = 0):
        if not 1 <= L <= K:
            raise ValueError(f"cohort size L={L} not in [1, K={K}]")
        self.K, self.L, self.P = K, L, P
        self.sampler = sampler
        self.floor = floor
        self.trace = (parse_trace_spec(trace) if isinstance(trace, str)
                      else trace)
        self.fault = (parse_fault_spec(fault) if isinstance(fault, str)
                      else fault)
        self.seed = seed
        self.is_state = IS.init_is_state(P, K) if sampler == "importance" \
            else None
        self.q_history: list = []

    @classmethod
    def from_config(cls, cfg, *, K: Optional[int] = None,
                    L: Optional[int] = None) -> "CohortScheduler":
        sampler, floor, trace = parse_cohort_spec(cfg.cohort)
        K = K or cfg.clients_per_server
        return cls(K, L or cfg.clients_sampled or K, cfg.num_servers,
                   sampler=sampler, floor=floor, trace=trace,
                   fault=cfg.fault, seed=cfg.topology_seed)

    @property
    def pure(self) -> bool:
        """True when cohort selection is exactly the dense simulator's
        uniform-without-replacement draw (bit-identical trajectories)."""
        return self.sampler == "uniform" and self.trace.always_on

    # ------------------------------------------------------- realizations

    def _rng(self, round_idx: int, stream: int) -> np.random.Generator:
        # the SHARED stream helper: drawing STREAM_DROPOUT with the
        # scheduler's seed realizes the same masks as
        # TopologyProcess.client_alive given the same seed
        return fault_stream_rng(self.seed, stream, round_idx)

    def availability(self, round_idx: int) -> np.ndarray:
        """[P, K] bool availability mask for the round (all-True for the
        ``always`` trace).  At least one client per server is forced
        available — a server with an empty candidate set cannot run."""
        if self.trace.always_on:
            return np.ones((self.P, self.K), bool)
        probs = self.trace.probs(round_idx, self.K)
        rng = self._rng(round_idx, stream=STREAM_AVAILABILITY)
        avail = rng.random((self.P, self.K)) < probs[None, :]
        dead = ~avail.any(axis=1)
        if dead.any():
            forced = rng.integers(0, self.K, size=self.P)
            avail[dead, forced[dead]] = True
        return avail

    def client_alive(self, round_idx: int) -> Optional[np.ndarray]:
        """[P, L] mid-round dropout mask over the *sampled* cohort, or None
        when the fault spec has no dropout component.  THE same realization
        as ``TopologyProcess.client_alive`` for a shared seed (one
        implementation: ``resilience.faults.client_dropout_mask``)."""
        if self.fault.client_dropout <= 0:
            return None
        return client_dropout_mask(self.seed, round_idx, self.P, self.L,
                                   self.fault.client_dropout)

    # ---------------------------------------------------------- selection

    def effective_probs(self, avail: np.ndarray) -> jax.Array:
        """[P, K] per-client sampling probabilities after masking by
        availability (rows renormalized)."""
        if self.sampler == "importance":
            base = IS.sampling_probs(self.is_state, floor=self.floor)
        else:
            base = jnp.full((self.P, self.K), 1.0 / self.K)
        eff = base * jnp.asarray(avail, jnp.float32)
        return eff / eff.sum(axis=1, keepdims=True)

    def select(self, key: jax.Array, round_idx: int) -> CohortSelection:
        """Draw the round's cohort.  On the pure path this is the dense
        simulator's exact program: choice WITHOUT replacement per server,
        weights None."""
        avail = self.availability(round_idx)
        alive = self.client_alive(round_idx)
        alive_j = None if alive is None else jnp.asarray(alive)
        if self.pure:
            idx = jax.vmap(
                lambda k: jax.random.choice(k, self.K, (self.L,),
                                            replace=False)
            )(jax.random.split(key, self.P))
            q = self.L / self.K
            self.q_history.append(q)
            return CohortSelection(idx, None, alive_j, q)

        probs = self.effective_probs(avail)
        idx = jax.vmap(
            lambda k, p: jax.random.choice(k, self.K, (self.L,),
                                           replace=True, p=p)
        )(jax.random.split(key, self.P), probs)
        k_avail = avail.sum(axis=1)
        weights = IS.importance_weights(probs, idx,
                                        k_norm=jnp.asarray(k_avail,
                                                           jnp.float32))
        if self.sampler == "importance":
            q = float(min(1.0, self.L * float(probs.max())))
        else:
            q = float(min(1.0, self.L / k_avail.min()))
        self.q_history.append(q)
        return CohortSelection(idx, weights, alive_j, q)

    def observe(self, client_idx: jax.Array, grad_norms: jax.Array) -> None:
        """Feed observed per-client gradient norms back into the importance
        sampler (no-op for the uniform sampler)."""
        if self.is_state is not None:
            self.is_state = IS.update_norm_estimates(self.is_state,
                                                     client_idx, grad_norms)

    @property
    def realized_q(self) -> float:
        """Mean realized per-round sampling rate so far (1.0 before any
        round has been drawn — the conservative no-amplification answer)."""
        return float(np.mean(self.q_history)) if self.q_history else 1.0
