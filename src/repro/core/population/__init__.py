"""Population-scale client engine: lazy populations, cohort scheduling,
streaming execution.  See docs/population.md."""
from repro.core.population.cohort import (
    AvailabilityTrace,
    CohortScheduler,
    CohortSelection,
    cohort_to_spec,
    parse_cohort_spec,
    parse_trace_spec,
)
from repro.core.population.engine import (
    PopulationRunResult,
    as_population,
    estimate_w_ref,
    run_gfl_population,
    uniform_cohort_batch,
    uniform_cohort_indices,
)
from repro.core.population.population import (
    ClientPopulation,
    DensePopulation,
    DirichletPopulation,
    PopulationSpec,
    SyntheticPopulation,
    parse_population_spec,
    population_from_spec,
)

__all__ = [
    "AvailabilityTrace", "CohortScheduler", "CohortSelection",
    "cohort_to_spec", "parse_cohort_spec", "parse_trace_spec",
    "PopulationRunResult", "as_population", "estimate_w_ref",
    "run_gfl_population", "uniform_cohort_batch",
    "uniform_cohort_indices",
    "ClientPopulation", "DensePopulation", "DirichletPopulation",
    "PopulationSpec", "SyntheticPopulation", "parse_population_spec",
    "population_from_spec",
]
