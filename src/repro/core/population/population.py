"""Lazy client populations: any client's shard from (data_seed, server, client).

The dense simulator materializes every client's data as one ``[P, K, N, M]``
tensor, which caps the reproduction at P=10 x K=50 even though the ROADMAP
north-star is millions of users.  This module makes K a *virtual* quantity:
a :class:`ClientPopulation` regenerates any client's shard on demand, so
memory and compute scale with the sampled cohort ``[P, L]`` rather than the
population ``[P, K]`` — the partial-participation regime analyzed by
Privatized Graph Federated Learning (arXiv:2203.07105).

Three families:

``DensePopulation``
    wraps already-materialized ``[P, K, N, M]`` arrays (the Section-V
    problem).  This is the regression anchor: the population engine over a
    dense population at full participation is bit-identical to the dense
    simulator path (`tests/test_population.py`).

``SyntheticPopulation``
    the Section-V generative model evaluated lazily per client: client
    ``(p, k)``'s shard is a pure function of ``(data_seed, p, k)`` via
    ``jax.random.fold_in`` chains (the counter-based discipline of
    repro.data.synthetic).  Heterogeneity is pluggable: ``iid`` (one global
    sigma_h), ``hetero`` (per-client sigma_h as in the paper's Section V),
    ``mixture`` (cluster drift: clients belong to latent clusters whose
    class-conditional means drift away from the global +-1 mean).

``DirichletPopulation``
    non-IID label skew over a finite labeled pool via
    :func:`repro.data.partition.dirichlet_partition`: the pool stays
    materialized once (``[n, M]``) and only an int32 index tensor
    ``[P, K, N]`` is built — never a ``[P, K, N, M]`` data tensor.

Specs are compact strings stored in ``GFLConfig.population`` so configs stay
flat and hashable (grammar in docs/population.md), parsed by
:func:`parse_population_spec`::

    dense
    synthetic:iid,sigma=1.0
    synthetic:hetero,lo=0.5,hi=1.5
    synthetic:mixture,clusters=4,drift=0.5
    dirichlet:0.3,pool=4000
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_KINDS = ("dense", "synthetic", "iid", "hetero", "mixture", "dirichlet")


@dataclass(frozen=True)
class PopulationSpec:
    """Parsed ``GFLConfig.population`` string."""
    kind: str                      # dense | iid | hetero | mixture | dirichlet
    args: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown population kind {self.kind!r}; "
                             f"expected one of {_KINDS}")


# keys each population kind accepts (shared: n, dim, rho); misspelled keys
# are rejected rather than silently falling back to defaults — same
# strictness as the cohort/trace/fault parsers
_ALLOWED_KEYS = {
    "dense": frozenset(),
    "iid": frozenset({"sigma", "n", "dim", "rho"}),
    "hetero": frozenset({"lo", "hi", "n", "dim", "rho"}),
    "mixture": frozenset({"clusters", "drift", "sigma", "n", "dim", "rho"}),
    "dirichlet": frozenset({"alpha", "pool", "sigma", "n", "dim", "rho"}),
}


def parse_population_spec(spec: str) -> PopulationSpec:
    """Parse a ``GFLConfig.population`` string.

    Form: ``name[:variant][,key=value]*`` — ``synthetic:<variant>`` selects
    the heterogeneity model, ``dirichlet:<alpha>`` passes alpha positionally.
    """
    spec = (spec or "dense").strip()
    head, _, rest = spec.partition(",")
    name, _, variant = head.partition(":")
    args: dict = {}
    if rest:
        for part in rest.split(","):
            k, sep, v = part.partition("=")
            if not sep:
                raise ValueError(
                    f"bad population argument {part!r} in spec {spec!r}; "
                    "expected key=value")
            try:
                args[k.strip()] = float(v) if "." in v or "e" in v.lower() \
                    else int(v)
            except ValueError:
                raise ValueError(
                    f"bad population argument value {v!r} in {spec!r}"
                ) from None
    if name == "dense":
        if variant:
            raise ValueError(f"dense population takes no variant: {spec!r}")
        kind = "dense"
    elif name == "synthetic":
        kind = variant or "hetero"
        if kind not in ("iid", "hetero", "mixture"):
            raise ValueError(
                f"unknown synthetic variant {variant!r} in {spec!r}; "
                "expected iid | hetero | mixture")
    elif name == "dirichlet":
        kind = "dirichlet"
        if variant:
            args["alpha"] = float(variant)
    else:
        raise ValueError(f"unknown population spec {spec!r}; expected "
                         "dense | synthetic:<variant> | dirichlet:<alpha>")
    unknown = set(args) - _ALLOWED_KEYS[kind]
    if unknown:
        raise ValueError(
            f"unknown argument(s) {sorted(unknown)} for population kind "
            f"{kind!r} in {spec!r}; allowed: "
            f"{sorted(_ALLOWED_KEYS[kind])}")
    return PopulationSpec(kind, args)


def _fmt_arg(v) -> str:
    # repr keeps the "." / "e" marker the parser uses to pick float vs
    # int, so values survive the round trip with their types intact
    return repr(v) if isinstance(v, float) else str(v)


def population_to_spec(spec: PopulationSpec) -> str:
    """Inverse of :func:`parse_population_spec` (canonical form).

    ``parse_population_spec(population_to_spec(s)) == s`` for every valid
    :class:`PopulationSpec`; shorthand inputs (``synthetic``,
    ``dirichlet,alpha=0.3``) re-render in canonical long form.
    """
    args = dict(spec.args)
    if spec.kind == "dense":
        head = "dense"
    elif spec.kind == "dirichlet":
        # alpha renders positionally only when float — the positional
        # slot always re-parses as float, so an int alpha (legal via the
        # keyword form) must stay a keyword to round-trip its type
        if isinstance(args.get("alpha"), float):
            head = f"dirichlet:{_fmt_arg(args.pop('alpha'))}"
        else:
            head = "dirichlet"
    else:
        head = f"synthetic:{spec.kind}"
    tail = ",".join(f"{k}={_fmt_arg(v)}" for k, v in sorted(args.items()))
    return head + ("," + tail if tail else "")


class ClientPopulation:
    """A virtual fleet of P x K clients with deterministic shard access.

    Shapes: ``num_clients`` = K clients per server (virtual — never
    materialized), ``samples_per_client`` = N, ``dim`` = M.  ``gather`` is
    the only hot-path method: it materializes exactly the requested cohort
    ``[P, L, B, M]`` and is jax-traceable for every built-in population, so
    it can live inside a jitted sampler or a lax.scan over rounds.

    ``rho`` is the regularization of the client risk (the population is the
    data side of the Section-V logistic problem); ``w_ref`` an optional
    reference minimizer for MSD traces (exact for dense populations,
    Monte-Carlo for lazy ones — see ``engine.estimate_w_ref``).
    """

    P: int
    num_clients: int
    samples_per_client: int
    dim: int
    rho: float = 0.01
    w_ref: Optional[jax.Array] = None
    traceable: bool = True

    def gather(self, client_idx: jax.Array, batch_idx: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
        """Cohort minibatches.  client_idx: [P, L] in [0, K); batch_idx:
        [P, L, B] in [0, N).  Returns (h [P, L, B, M], gamma [P, L, B])."""
        raise NotImplementedError

    def client_shard(self, p: int, k: int) -> Tuple[jax.Array, jax.Array]:
        """One client's full shard (h [N, M], gamma [N]) — debug/host access."""
        N = self.samples_per_client
        cid = jnp.asarray([[k]])
        bidx = jnp.arange(N).reshape(1, 1, N)
        h, g = self.gather(jnp.broadcast_to(cid, (self.P, 1)),
                           jnp.broadcast_to(bidx, (self.P, 1, N)))
        return h[p, 0], g[p, 0]


class DensePopulation(ClientPopulation):
    """Materialized arrays as a population — the regression anchor.

    ``gather`` is the exact fancy-indexing of the dense simulator's
    ``sample_round_batches`` (same index expression, same dtypes), so the
    population engine at full participation reproduces the dense trajectory
    bit-for-bit."""

    def __init__(self, features: jax.Array, labels: jax.Array,
                 rho: float = 0.01, w_ref: Optional[jax.Array] = None):
        P, K, N, M = features.shape
        self.features = features
        self.labels = labels
        self.P, self.num_clients = P, K
        self.samples_per_client, self.dim = N, M
        self.rho = rho
        self.w_ref = w_ref

    @classmethod
    def from_problem(cls, prob) -> "DensePopulation":
        """Wrap a :class:`repro.core.simulate.LogisticProblem`."""
        return cls(prob.features, prob.labels, rho=prob.rho,
                   w_ref=prob.w_opt)

    def gather(self, client_idx, batch_idx):
        p_idx = jnp.arange(self.P)[:, None, None]
        h = self.features[p_idx, client_idx[:, :, None], batch_idx]
        g = self.labels[p_idx, client_idx[:, :, None], batch_idx]
        return h, g


class SyntheticPopulation(ClientPopulation):
    """Section-V generative model, lazily per client.

    Client ``(p, k)``'s shard is a pure function of ``(data_seed, p, k)``:
    labels gamma = +-1 Bernoulli(1/2), features h | gamma ~ N(gamma * m_k,
    sigma_k^2 I).  Heterogeneity mode picks (m_k, sigma_k):

    ``iid``      m_k = 1-vector, sigma_k = sigma (one global value);
    ``hetero``   m_k = 1-vector, sigma_k ~ U[lo, hi] per client (the
                 paper's Section-V heterogeneity);
    ``mixture``  client k belongs to cluster ``k mod clusters``; the
                 cluster's class mean is the 1-vector plus a drift-scaled
                 Gaussian offset (cluster/mixture drift — clients inside a
                 cluster agree, clusters disagree), sigma_k = sigma.

    No [P, K, ...] tensor exists anywhere: ``gather`` vmaps the per-client
    generator over the cohort only.
    """

    def __init__(self, P: int, K: int, *, mode: str = "hetero",
                 N: int = 100, M: int = 2, data_seed: int = 0,
                 sigma: float = 1.0, lo: float = 0.5, hi: float = 1.5,
                 clusters: int = 4, drift: float = 0.5, rho: float = 0.01):
        if mode not in ("iid", "hetero", "mixture"):
            raise ValueError(f"unknown synthetic mode {mode!r}")
        self.P, self.num_clients = P, K
        self.samples_per_client, self.dim = N, M
        self.mode, self.data_seed = mode, data_seed
        self.sigma, self.lo, self.hi = sigma, lo, hi
        self.clusters, self.drift = max(int(clusters), 1), drift
        self.rho = rho
        self.w_ref = None

    def _client_key(self, p, k):
        base = jax.random.PRNGKey(self.data_seed)
        return jax.random.fold_in(jax.random.fold_in(base, p), k)

    def _client_mean(self, k):
        """Class-conditional mean direction m_k (the +-1 '1-vector' of the
        paper, drifted per latent cluster in mixture mode)."""
        ones = jnp.ones((self.dim,), jnp.float32)
        if self.mode != "mixture":
            return ones
        cluster = jnp.mod(k, self.clusters)
        # dedicated cluster stream (disjoint from the per-client fold_in
        # chain, which only ever folds in ids < K)
        ckey = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.data_seed),
                               0x7FFF_FFFF), cluster)
        return ones + self.drift * jax.random.normal(ckey, (self.dim,))

    def _client_sigma(self, key_sigma):
        if self.mode == "hetero":
            return jax.random.uniform(key_sigma, (), minval=self.lo,
                                      maxval=self.hi)
        return jnp.asarray(self.sigma, jnp.float32)

    def _shard(self, p, k):
        """(h [N, M], gamma [N]) for client (p, k); p, k may be traced."""
        N, M = self.samples_per_client, self.dim
        kl, ks, kn = jax.random.split(self._client_key(p, k), 3)
        gamma = jnp.where(jax.random.bernoulli(kl, 0.5, (N,)), 1.0, -1.0)
        sigma = self._client_sigma(ks)
        mean = gamma[:, None] * self._client_mean(k)[None, :]
        h = mean + sigma * jax.random.normal(kn, (N, M))
        return h, gamma

    def gather(self, client_idx, batch_idx):
        P, L = client_idx.shape
        p_ids = jnp.broadcast_to(jnp.arange(P)[:, None], (P, L))

        def one(p, k, bidx):
            h, g = self._shard(p, k)
            return h[bidx], g[bidx]

        return jax.vmap(jax.vmap(one))(p_ids, client_idx, batch_idx)


class DirichletPopulation(ClientPopulation):
    """Label-skew shards over a finite pool via ``dirichlet_partition``.

    The pool ([n, M] features, [n] +-1 labels) is materialized ONCE and
    shared; each client owns an index list from the Dirichlet split, cycled
    out to a fixed per-client length N so the gather stays rectangular and
    traceable.  Total extra memory is the [P, K, N] int32 index tensor —
    suitable for materialized datasets at modest K (for virtual-K scale use
    a synthetic population).
    """

    def __init__(self, features, labels, P: int, K: int, *,
                 alpha: float = 0.5, N: int = 0, data_seed: int = 0,
                 rho: float = 0.01):
        from repro.data.partition import dirichlet_partition

        features = jnp.asarray(features)
        labels = jnp.asarray(labels)
        shards = dirichlet_partition(np.asarray(labels), P, K, alpha=alpha,
                                     seed=data_seed, min_per_client=1)
        n_max = max(len(shards[p][k]) for p in range(P) for k in range(K))
        N = int(N) or n_max
        idx = np.zeros((P, K, N), np.int32)
        for p in range(P):
            for k in range(K):
                # cycle the client's indices out to length N (rectangular
                # gather); every original index appears at least once when
                # N >= len(shard)
                idx[p, k] = np.resize(shards[p][k], N)
        self.pool_h, self.pool_g = features, labels
        self.index = jnp.asarray(idx)
        self.P, self.num_clients = P, K
        self.samples_per_client, self.dim = N, int(features.shape[-1])
        self.alpha, self.rho = alpha, rho
        self.w_ref = None

    @classmethod
    def synthetic_pool(cls, P: int, K: int, *, alpha: float = 0.5,
                       pool: int = 0, M: int = 2, sigma: float = 1.0,
                       N: int = 0, data_seed: int = 0, rho: float = 0.01
                       ) -> "DirichletPopulation":
        """Section-V-style pool (gamma = +-1, h ~ N(gamma*1, sigma^2 I)) of
        ``pool`` samples, Dirichlet-split across the P x K clients."""
        n = int(pool) or P * K * 20
        key = jax.random.PRNGKey(data_seed)
        k1, k2 = jax.random.split(key)
        g = jnp.where(jax.random.bernoulli(k1, 0.5, (n,)), 1.0, -1.0)
        h = g[:, None] + sigma * jax.random.normal(k2, (n, M))
        return cls(h, g, P, K, alpha=alpha, N=N, data_seed=data_seed,
                   rho=rho)

    def gather(self, client_idx, batch_idx):
        p_idx = jnp.arange(self.P)[:, None, None]
        sample_idx = self.index[p_idx, client_idx[:, :, None], batch_idx]
        return self.pool_h[sample_idx], self.pool_g[sample_idx]


def population_from_spec(cfg, *, P: Optional[int] = None,
                         K: Optional[int] = None) -> ClientPopulation:
    """Build the population named by ``cfg.population`` for a GFLConfig.

    ``dense`` has no lazy generator — callers hold the materialized problem
    and wrap it with :meth:`DensePopulation.from_problem`; asking the spec
    registry for it is an error that names the fix.
    """
    spec = parse_population_spec(cfg.population)
    P = P or cfg.num_servers
    K = K or cfg.clients_per_server
    a = spec.args
    if spec.kind == "dense":
        raise ValueError(
            "population='dense' wraps a materialized problem — pass the "
            "problem to the engine (DensePopulation.from_problem) instead "
            "of building it from the spec")
    if spec.kind in ("iid", "hetero", "mixture"):
        return SyntheticPopulation(
            P, K, mode=spec.kind,
            N=int(a.get("n", 100)), M=int(a.get("dim", 2)),
            data_seed=cfg.data_seed,
            sigma=float(a.get("sigma", 1.0)),
            lo=float(a.get("lo", 0.5)), hi=float(a.get("hi", 1.5)),
            clusters=int(a.get("clusters", 4)),
            drift=float(a.get("drift", 0.5)),
            rho=float(a.get("rho", 0.01)))
    # dirichlet
    return DirichletPopulation.synthetic_pool(
        P, K, alpha=float(a.get("alpha", 0.5)),
        pool=int(a.get("pool", 0)), M=int(a.get("dim", 2)),
        sigma=float(a.get("sigma", 1.0)), N=int(a.get("n", 0)),
        data_seed=cfg.data_seed, rho=float(a.get("rho", 0.01)))
