"""Time-varying topology process: per-round effective combination matrices.

A :class:`TopologyProcess` owns a base doubly-stochastic combination matrix
``A`` (Assumption 1) and a :class:`~repro.core.resilience.faults.FaultModel`
and, for each round ``i``, realizes an *effective* matrix ``A_i``:

  1. sample server outages (a down server loses all incident links) and
     i.i.d. link drops over the base edges;
  2. repair connectivity: re-add a minimal random set of the dropped edges
     until the realized graph is connected again.  A partitioned graph has
     spectral gap 1 and the collective cannot complete at all — production
     runtimes block and retry such links, so the repair models the retry
     path while the realized gap still degrades with the failure rate;
  3. fold each dropped edge's weight back into BOTH endpoint diagonals
     (Metropolis re-normalization): ``A_i[p, p] = A[p, p] + sum of the
     dropped weights in row p``.  Surviving entries keep their base weights
     bit-exactly, so a zero-probability fault model realizes ``A_i == A``
     exactly and dead links are zero-weight entries the mesh combine can
     skip or permute with weight 0.

Every realized ``A_i`` is therefore symmetric, doubly stochastic, has a
strictly positive diagonal (Metropolis max-degree weights leave slack) and
is connected — i.e. Assumption 1 (``spectral_gap(A_i) < 1``) holds every
round, matching the time-varying analysis of arXiv:2203.07105.  The gap
*trajectory* ``spectral_gap(A_i)`` is exposed so experiments can report how
failures slow consensus (and, per arXiv:2312.07956, shift the realized
privacy bound).

Realizations are a pure function of ``(seed, round)`` — re-running a round
re-realizes the identical topology, which is what makes fault-injected runs
reproducible and resumable.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.resilience.faults import (
    STREAM_DROPOUT,
    STREAM_STRAGGLER,
    STREAM_TOPOLOGY,
    FaultModel,
    fault_stream_rng,
    parse_fault_spec,
)
from repro.core.topology import spectral_gap, validate_combination_matrix


class RoundRealization(NamedTuple):
    """One round's effective topology."""
    A: np.ndarray          # [P, P] effective doubly-stochastic matrix
    link_mask: np.ndarray  # [P, P] bool, True where the base edge survived
    straggler: np.ndarray  # [P] bool, servers re-announcing stale psi
    gap: float             # spectral_gap(A)


class _UnionFind:
    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, x: int) -> int:
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        self.parent[ra] = rb
        return True


def fold_dropped_links(A: np.ndarray, link_mask: np.ndarray) -> np.ndarray:
    """Zero the dropped off-diagonal entries of ``A`` and fold their weight
    into the diagonal.  Exact: surviving entries are untouched and the
    all-True mask returns ``A`` bit-for-bit (the folded correction is a sum
    of exact zeros)."""
    off = ~np.eye(A.shape[0], dtype=bool)
    dropped = off & ~link_mask
    A_i = np.where(dropped, 0.0, A)
    # symmetric drop => each row's lost mass returns to its own diagonal
    np.fill_diagonal(A_i, np.diagonal(A) + np.where(dropped, A, 0.0).sum(1))
    return A_i


class TopologyProcess:
    """Per-round fault realizations over a fixed base combination matrix.

    ``base_A`` must satisfy Assumption 1 (use
    :func:`repro.core.topology.combination_matrix`); the base edge set is
    read off its nonzero off-diagonal entries, so product graphs (the mesh
    trainer's ``kron(A_pod, A_data)``) work unchanged.
    """

    def __init__(self, base_A: np.ndarray, fault: FaultModel | str = "none",
                 *, seed: int = 0, validate: bool = True):
        self.base_A = np.asarray(base_A, np.float64)
        self.fault = (parse_fault_spec(fault) if isinstance(fault, str)
                      else fault)
        self.seed = seed
        self._validate = validate
        P = self.base_A.shape[0]
        off = ~np.eye(P, dtype=bool)
        self.base_mask = off & (self.base_A > 0)
        iu, ju = np.nonzero(np.triu(self.base_mask))
        self._edges = list(zip(iu.tolist(), ju.tolist()))  # base edge list
        # realizations are pure in (seed, round) and include an O(P^3)
        # eigendecomposition — memoize so the training loop and the gap
        # trajectory (run_gfl(record_gaps=True)) share one realization
        self._memo: dict[int, RoundRealization] = {}
        self._base_gap: float | None = None

    @property
    def P(self) -> int:
        return self.base_A.shape[0]

    @property
    def static(self) -> bool:
        """True when every round realizes the base matrix exactly."""
        return not self.fault.perturbs_topology

    # ------------------------------------------------------------ sampling

    def _rng(self, round_idx: int, stream: int) -> np.random.Generator:
        """Deterministic per-(round, stream) generator (shared stream
        discipline — see repro.core.resilience.faults.fault_stream_rng)."""
        return fault_stream_rng(self.seed, stream, round_idx)

    def realize(self, round_idx: int) -> RoundRealization:
        """Effective topology for round ``round_idx`` (memoized)."""
        round_idx = int(round_idx)
        hit = self._memo.get(round_idx)
        if hit is not None:
            return hit
        real = self._realize(round_idx)
        if len(self._memo) >= self._MEMO_CAP:   # FIFO bound: [P,P] arrays
            self._memo.pop(next(iter(self._memo)))
        self._memo[round_idx] = real
        return real

    _MEMO_CAP = 4096

    def _realize(self, round_idx: int) -> RoundRealization:
        f = self.fault
        straggler = self._straggler_proposal(round_idx)
        if self.static:
            if self._base_gap is None:   # one eigendecomposition, not
                self._base_gap = (spectral_gap(self.base_A)  # one per round
                                  if self.P > 1 else 0.0)
            return RoundRealization(self.base_A, self.base_mask.copy(),
                                    straggler, self._base_gap)

        rng = self._rng(round_idx, stream=STREAM_TOPOLOGY)
        P = self.P
        up = (rng.random(P) >= f.outage) if f.outage > 0 else np.ones(P, bool)
        alive: list[tuple[int, int]] = []
        dropped: list[tuple[int, int]] = []
        # one uniform draw per base edge, in fixed edge order (deterministic)
        edge_u = rng.random(len(self._edges))
        for (j, k), u in zip(self._edges, edge_u):
            if up[j] and up[k] and u >= f.link_drop:
                alive.append((j, k))
            else:
                dropped.append((j, k))

        # connectivity repair: re-add a minimal random set of dropped edges
        uf = _UnionFind(P)
        components = P
        for j, k in alive:
            components -= uf.union(j, k)
        if components > 1:
            order = rng.permutation(len(dropped))
            for idx in order:
                j, k = dropped[idx]
                if uf.union(j, k):
                    alive.append((j, k))
                    components -= 1
                    if components == 1:
                        break

        mask = np.zeros((P, P), bool)
        for j, k in alive:
            mask[j, k] = mask[k, j] = True
        A_i = fold_dropped_links(self.base_A, mask)
        gap = spectral_gap(A_i) if P > 1 else 0.0
        if self._validate:
            validate_combination_matrix(A_i, gap=gap)
        return RoundRealization(A_i, mask, straggler, gap)

    def _straggler_proposal(self, round_idx: int) -> np.ndarray:
        """Servers *proposing* to straggle this round (the runtime may
        force a refresh once a server's psi hits the staleness bound)."""
        if self.fault.straggler <= 0:
            return np.zeros(self.P, bool)
        rng = self._rng(round_idx, stream=STREAM_STRAGGLER)
        return rng.random(self.P) < self.fault.straggler

    def client_alive(self, round_idx: int, L: int) -> np.ndarray:
        """[P, L] participation mask for the round's sampled clients (the
        shared realization — see
        :func:`repro.core.resilience.faults.client_dropout_mask`)."""
        if self.fault.client_dropout <= 0:
            return np.ones((self.P, L), bool)
        from repro.core.resilience.faults import client_dropout_mask
        return client_dropout_mask(self.seed, round_idx, self.P, L,
                                   self.fault.client_dropout)

    # ---------------------------------------------------------- trajectory

    def gap_trajectory(self, rounds: int) -> np.ndarray:
        """``spectral_gap(A_i)`` for rounds ``0..rounds-1``."""
        return np.asarray([self.realize(i).gap for i in range(rounds)])
