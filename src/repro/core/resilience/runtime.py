"""Resilient GFL execution: time-varying A_i, stragglers, client dropout.

This is the stateful driver the fault-injected paths share.  Per round it

  1. realizes the round topology ``A_i`` from the
     :class:`~repro.core.resilience.process.TopologyProcess` (host-side,
     deterministic in ``(topology_seed, round)``) and feeds it to the jitted
     step as a *traced* argument — one compilation serves every round;
  2. applies mid-round client dropout through the mechanism's
     ``client_protect_masked`` hook (Bonawitz survivor renormalization for
     the secure-agg family), after checking the mechanism DECLARES dropout
     safety (``noise_profile().client_dropout_safe``);
  3. lets straggling servers re-announce their most recent psi instead of
     running the round's client work, bounded by ``FaultModel.staleness``
     consecutive rounds (a server at the bound is forced to refresh — the
     runtime waits for it, production-style bounded staleness).

Key-splitting mirrors :func:`repro.core.gfl.gfl_round` exactly, and each
piece of fault machinery is only traced in when its probability is nonzero,
so a zero-probability fault model produces BIT-IDENTICAL trajectories to
the static path (regression-tested).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import GFLConfig
from repro.core import gfl
from repro.core.privacy.mechanism import RoundContext, mechanism_for
from repro.core.resilience.process import TopologyProcess


class ResilientGFLState(NamedTuple):
    params: jax.Array     # [P, D] per-server flat models
    step: jax.Array       # scalar int32
    key: jax.Array        # PRNG key
    psi_cache: jax.Array  # [P, D] most recent psi each server announced
    psi_age: jax.Array    # [P] int32: consecutive rounds spent straggling


def ensure_dropout_safe(profile, *, where: str = "client dropout") -> None:
    """Refuse to run client dropout through a mechanism that does not
    declare ``client_dropout_safe``.  Cancelling mechanisms would leave
    orphaned pair masks in the aggregate; non-cancelling mechanisms with
    client noise would silently fall back to the NOISE-FREE base
    ``client_protect_masked`` — either way the accountant keeps claiming a
    budget the released aggregate no longer pays for."""
    if not profile.client_dropout_safe:
        raise ValueError(
            f"{where}: mechanism does not declare client_dropout_safe — "
            "its client level is not guaranteed honest once a sampled "
            "client vanishes mid-round (orphaned secure-agg masks, or a "
            "noise-free fallback survivor mean).  Implement "
            "client_protect_masked for the scheme and declare "
            "client_dropout_safe=True in noise_profile(), or run fault "
            "specs without a dropout: component.")


def init_resilient_state(key: jax.Array, P: int, dim: int,
                         init_scale: float = 0.0) -> ResilientGFLState:
    """Same draws as :func:`repro.core.gfl.init_state` (bit-compatible),
    plus the straggler psi cache seeded with the initial params."""
    base = gfl.init_state(key, P, dim, init_scale)
    return ResilientGFLState(base.params, base.step, base.key,
                             psi_cache=base.params,
                             psi_age=jnp.zeros((P,), jnp.int32))


def make_resilient_gfl_step(process: TopologyProcess, grad_fn: Callable,
                            cfg: GFLConfig) -> Callable:
    """(state, batch) -> state under the process's fault model.

    The returned callable realizes the round topology on the host, then
    runs one jitted step with ``(A_i, client_alive, straggler)`` as traced
    inputs.  It accepts either a :class:`ResilientGFLState` or a plain
    :class:`~repro.core.gfl.GFLState` (promoted on first use).
    """
    mech = mechanism_for(cfg)
    fault = process.fault
    use_dropout = fault.client_dropout > 0
    use_straggler = fault.straggler > 0
    if use_dropout:
        ensure_dropout_safe(mech.noise_profile())

    @jax.jit
    def inner(state: ResilientGFLState, batch, A, alive, straggler):
        key, sub = jax.random.split(state.key)
        ctx = RoundContext(step=state.step)
        key_r, key_c = jax.random.split(sub)
        Pn = state.params.shape[0]
        server_keys = jax.random.split(key_r, Pn)
        # the SAME (6)+(7) implementation as the static path — bit-identity
        # under a null fault model is by construction, not by parallel code
        psi = gfl._client_updates(state.params, batch, server_keys, grad_fn,
                                  cfg, mech, ctx,
                                  alive if use_dropout else None)

        if use_straggler:
            # bounded staleness: a server may straggle only while its
            # cached psi is younger than the staleness bound
            stale_ok = straggler & (state.psi_age < fault.staleness)
            psi = jnp.where(stale_ok[:, None], state.psi_cache, psi)
            new_age = jnp.where(stale_ok, state.psi_age + 1, 0)
            new_cache = psi
        else:
            new_cache, new_age = state.psi_cache, state.psi_age

        if cfg.combine_every > 1:
            do_combine = (state.step % cfg.combine_every
                          == cfg.combine_every - 1)
            new_params = jax.lax.cond(
                do_combine,
                lambda p: mech.server_combine(p, key_c, A, ctx),
                lambda p: p, psi)
        else:
            new_params = mech.server_combine(psi, key_c, A, ctx)
        return ResilientGFLState(new_params, state.step + 1, key,
                                 new_cache, new_age)

    def step(state, batch) -> ResilientGFLState:
        if not isinstance(state, ResilientGFLState):
            state = ResilientGFLState(
                state.params, state.step, state.key,
                psi_cache=state.params,
                psi_age=jnp.zeros((state.params.shape[0],), jnp.int32))
        i = int(state.step)
        real = process.realize(i)
        L = jax.tree_util.tree_leaves(batch)[0].shape[1]
        alive = jnp.asarray(process.client_alive(i, L))
        return inner(state, batch, jnp.asarray(real.A, jnp.float32),
                     alive, jnp.asarray(real.straggler))

    return step
