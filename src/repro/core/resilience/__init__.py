"""Resilience runtime: fault injection, time-varying topologies, and
dropout-safe execution of the GFL protocol.

The paper's stated motivation for the graph-federated architecture is
robustness to communication failures and server overload; this package
makes that regime executable.  See docs/resilience.md for the fault-spec
grammar, the per-round re-normalization that keeps Assumption 1 true under
failures, and the dropout semantics of secure aggregation.
"""
from repro.core.resilience.faults import FaultModel, parse_fault_spec
from repro.core.resilience.process import (
    RoundRealization,
    TopologyProcess,
    fold_dropped_links,
)
from repro.core.resilience.runtime import (
    ResilientGFLState,
    ensure_dropout_safe,
    init_resilient_state,
    make_resilient_gfl_step,
)

__all__ = [
    "FaultModel",
    "parse_fault_spec",
    "RoundRealization",
    "TopologyProcess",
    "fold_dropped_links",
    "ResilientGFLState",
    "ensure_dropout_safe",
    "init_resilient_state",
    "make_resilient_gfl_step",
]
