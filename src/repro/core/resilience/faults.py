"""Fault-model specification for the resilience runtime.

The paper motivates the graph-federated architecture with robustness: "the
current architecture of a server connected to multiple clients is highly
sensitive to communication failures and computational overloads at the
server".  A :class:`FaultModel` makes that regime testable — it names the
per-round failure processes the :class:`~repro.core.resilience.process.
TopologyProcess` realizes:

  ``link_drop``       i.i.d. per-edge link failures (each surviving base
                      edge drops with this probability, independently per
                      round — the arXiv:2203.07105 random-A_i regime);
  ``outage``          correlated server outages: a down server loses ALL
                      incident links at once for the round;
  ``straggler``       computational overload: a straggling server skips the
                      round's client work and re-announces its most recent
                      psi, up to ``staleness`` consecutive rounds;
  ``client_dropout``  per-(server, client) mid-round dropout — the case
                      that breaks naive secure aggregation (see
                      docs/resilience.md and secure_agg dropout recovery).

Specs are compact strings stored in ``GFLConfig.fault`` so configs stay
flat and hashable::

    none
    links:0.1
    outage:0.05
    outage:0.05,kill=1
    straggler:0.2,stale=3
    dropout:0.25
    links:0.1+outage:0.02+straggler:0.1,stale=2+dropout:0.2

Components are joined with ``+``; each is ``name:<prob>`` with optional
``,key=value`` arguments (``straggler`` takes ``stale``; ``outage`` takes
``kill`` — ``kill=1`` asks the fleet runtime to realize the drawn outages
as real worker-process SIGKILLs, see ``repro.core.fleet.chaos``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

_COMPONENTS = ("links", "outage", "straggler", "dropout")

# named streams of the per-round fault/availability realizations; every
# consumer MUST draw through fault_stream_rng so realizations agree across
# components (e.g. a CohortScheduler and a TopologyProcess sharing a seed
# realize identical client-dropout masks — stream 3)
STREAM_TOPOLOGY = 1
STREAM_STRAGGLER = 2
STREAM_DROPOUT = 3
STREAM_AVAILABILITY = 4
STREAM_ARRIVAL = 5    # event engine: per-event arrival uniforms
STREAM_LATENCY = 6    # event engine: per-event latency/age draws


def fault_stream_rng(seed: int, stream: int, round_idx: int
                     ) -> np.random.Generator:
    """Deterministic per-(seed, stream, round) generator shared by every
    host-side fault realization (TopologyProcess, CohortScheduler).
    Streams keep the topology / straggler / dropout / availability draws
    independent while staying pure functions of (seed, round)."""
    return np.random.default_rng((0x5EED, seed, stream, int(round_idx)))


def client_dropout_mask(seed: int, round_idx: int, P: int, L: int,
                        dropout: float) -> np.ndarray:
    """[P, L] participation mask for the round's sampled clients — THE
    dropout realization, shared by ``TopologyProcess.client_alive`` and
    ``CohortScheduler.client_alive`` so both sides of the contract (fault
    execution and cohort accounting) see identical masks for a seed.

    Each sampled client drops with probability ``dropout``; at least one
    client per server always survives (a server whose whole cohort
    vanished has nothing to aggregate and simply re-runs the round —
    modeled as one forced survivor)."""
    rng = fault_stream_rng(seed, STREAM_DROPOUT, round_idx)
    alive = rng.random((P, L)) >= dropout
    dead_rows = ~alive.any(axis=1)
    if dead_rows.any():
        survivor = rng.integers(0, L, size=P)
        alive[dead_rows, survivor[dead_rows]] = True
    return alive


@dataclass(frozen=True)
class FaultModel:
    """Per-round failure probabilities (all independent across rounds)."""
    link_drop: float = 0.0       # i.i.d. per-edge drop probability
    outage: float = 0.0          # per-server correlated outage probability
    outage_kill: bool = False    # realize outages as real worker SIGKILLs
                                 # (core/fleet chaos) instead of A-row masks
    straggler: float = 0.0       # per-server straggler probability
    staleness: int = 1           # max consecutive rounds a straggler may
                                 # reuse the same stale psi
    client_dropout: float = 0.0  # per-(server, client) dropout probability

    def __post_init__(self):
        for name in ("link_drop", "outage", "straggler", "client_dropout"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"fault probability {name}={p} not in [0, 1]")
        if self.staleness < 1:
            raise ValueError(f"staleness must be >= 1, got {self.staleness}")

    @property
    def is_null(self) -> bool:
        """True when no failure process is active (probabilities all 0)."""
        return (self.link_drop == 0.0 and self.outage == 0.0
                and self.straggler == 0.0 and self.client_dropout == 0.0)

    @property
    def perturbs_topology(self) -> bool:
        """True when the effective combination matrix varies round-to-round."""
        return self.link_drop > 0.0 or self.outage > 0.0

    def to_spec(self) -> str:
        """Inverse of :func:`parse_fault_spec` (canonical form)."""
        parts = []
        if self.link_drop:
            parts.append(f"links:{self.link_drop:g}")
        if self.outage:
            parts.append(f"outage:{self.outage:g}"
                         + (",kill=1" if self.outage_kill else ""))
        if self.straggler:
            parts.append(f"straggler:{self.straggler:g},stale={self.staleness}")
        if self.client_dropout:
            parts.append(f"dropout:{self.client_dropout:g}")
        return "+".join(parts) or "none"


def parse_fault_spec(spec: str) -> FaultModel:
    """Parse a ``GFLConfig.fault`` string into a :class:`FaultModel`."""
    spec = (spec or "none").strip()
    if spec == "none":
        return FaultModel()
    kw: dict = {}
    for part in spec.split("+"):
        name, sep, rest = part.strip().partition(":")
        if name not in _COMPONENTS or not sep:
            raise ValueError(
                f"bad fault component {part!r} in spec {spec!r}; expected "
                f"'name:prob[,key=value]' with name in {_COMPONENTS}")
        prob_str, *args = rest.split(",")
        try:
            prob = float(prob_str)
        except ValueError:
            raise ValueError(
                f"bad probability {prob_str!r} in fault component {part!r}"
            ) from None
        field = {"links": "link_drop", "outage": "outage",
                 "straggler": "straggler", "dropout": "client_dropout"}[name]
        if field in kw:
            raise ValueError(f"duplicate fault component {name!r} in {spec!r}")
        kw[field] = prob
        for arg in args:
            k, sep, v = arg.partition("=")
            if name == "straggler" and k == "stale" and sep:
                kw["staleness"] = int(v)
            elif name == "outage" and k == "kill" and sep:
                # kill realization: the fleet SIGKILLs the drawn servers'
                # worker processes (repro.core.fleet.chaos.plan_kills)
                # instead of masking their rows of A
                kw["outage_kill"] = bool(int(v))
            else:
                raise ValueError(
                    f"unknown argument {arg!r} for fault component {name!r}")
    return FaultModel(**kw)
