"""Core GFL protocol: topology, privacy, the 3-step algorithm, simulator."""
from repro.core import gfl, topology
from repro.core.gfl import GFLState, gfl_round, make_gfl_step, centroid

__all__ = ["gfl", "topology", "GFLState", "gfl_round", "make_gfl_step", "centroid"]
