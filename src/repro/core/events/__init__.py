"""Event-driven async engine: buffered, staleness-weighted aggregation
over the server graph.  See docs/async.md."""
from repro.core.events.buffer import (
    BufferedServerState,
    fold_tick,
    flush,
    init_buffers,
    staleness_weights,
    weighted_fold,
)
from repro.core.events.engine import (
    AsyncCohortDriver,
    AsyncRunResult,
    AsyncState,
    run_gfl_async,
)
from repro.core.events.queue import EventQueue, trace_intensity_fn
from repro.core.events.spec import (
    AsyncSpec,
    LatencySpec,
    parse_async_spec,
    parse_latency_spec,
)

__all__ = [
    "AsyncCohortDriver", "AsyncRunResult", "AsyncSpec", "AsyncState",
    "BufferedServerState", "EventQueue", "LatencySpec", "fold_tick",
    "flush", "init_buffers", "parse_async_spec", "parse_latency_spec",
    "run_gfl_async", "staleness_weights", "trace_intensity_fn",
    "weighted_fold",
]
