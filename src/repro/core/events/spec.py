"""Async-executor specification: the ``GFLConfig.async_spec`` grammar.

The event-driven engine (FedBuff-style semi-async; see docs/async.md) is
configured by a compact spec string so configs stay flat and hashable,
exactly like ``fault`` / ``cohort`` / ``population``::

    none
    async
    async:buffer=8
    async:buffer=8,latency=lognorm:0.5,max_stale=4
    async:buffer=8,latency=exp:1.5,max_stale=4,alpha=0.5,rate=16

Fields
  ``buffer``     per-server aggregation buffer: a server flushes (runs the
                 protocol's aggregation + combination for its row) once it
                 has folded this many client arrivals;
  ``latency``    per-event client latency distribution, in ticks (see
                 :class:`LatencySpec`); the floor of the draw is the AGE of
                 the arriving update — which past model snapshot the client
                 computed against;
  ``max_stale``  bounded staleness: arrivals older than this are refused
                 (the same bounded-staleness contract as
                 ``FaultModel.staleness`` — a contribution may not lag the
                 server by more than the bound);
  ``alpha``      staleness-weight exponent: contributions fold with weight
                 ``1/(1 + age)^alpha`` (FedBuff-style down-weighting);
  ``rate``       candidate arrival events per server per tick (the event
                 batch width); 0 means ``buffer`` — which, with zero
                 latency and an always-on trace, is the synchronous
                 lockstep limit.

The **sync limit** ``buffer == rate``, ``latency == zero``,
``max_stale == 0`` is the synchronous protocol: every server's buffer
fills every tick with age-0 updates, so every tick is a lockstep round.
The executor routes that case through the population engine's exact pure
path — bit-identity is by construction, not by parallel code
(tests/test_events.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

_LATENCY_KINDS = ("zero", "fixed", "exp", "lognorm")


@dataclass(frozen=True)
class LatencySpec:
    """Per-event client latency distribution, in ticks.

    ``zero``          every update arrives within its dispatch tick (age 0);
    ``fixed:<k>``     constant latency of k ticks;
    ``exp:<mean>``    exponential with the given mean;
    ``lognorm:<s>``   lognormal with log-std s and median 1 tick.
    """
    kind: str = "zero"
    param: float = 0.0

    def __post_init__(self):
        if self.kind not in _LATENCY_KINDS:
            raise ValueError(f"unknown latency kind {self.kind!r}; "
                             f"expected one of {_LATENCY_KINDS}")
        if self.kind != "zero" and self.param < 0:
            raise ValueError(f"latency parameter must be >= 0, "
                             f"got {self.param}")

    @property
    def is_zero(self) -> bool:
        return self.kind == "zero" or (self.kind == "fixed"
                                       and self.param == 0)

    def sample_ages(self, rng: np.random.Generator, size) -> np.ndarray:
        """Integer ages (floor of the latency draw), >= 0."""
        if self.kind == "zero":
            return np.zeros(size, np.int32)
        if self.kind == "fixed":
            return np.full(size, int(self.param), np.int32)
        if self.kind == "exp":
            draws = rng.exponential(self.param, size)
        else:  # lognorm: median 1 tick, log-std = param
            draws = rng.lognormal(0.0, self.param, size)
        return np.floor(draws).astype(np.int32)

    def to_spec(self) -> str:
        """Inverse of :func:`parse_latency_spec` (canonical form)."""
        if self.kind == "zero":
            return "zero"
        return f"{self.kind}:{self.param:g}"


def parse_latency_spec(spec: str) -> LatencySpec:
    """``zero`` | ``fixed:<k>`` | ``exp:<mean>`` | ``lognorm:<sigma>``."""
    spec = (spec or "zero").strip()
    name, sep, arg = spec.partition(":")
    if name not in _LATENCY_KINDS:
        raise ValueError(f"unknown latency kind {name!r} in {spec!r}; "
                         f"expected one of {_LATENCY_KINDS}")
    if name == "zero":
        if sep:
            raise ValueError(f"latency kind 'zero' takes no argument "
                             f"(got {spec!r})")
        return LatencySpec()
    if not sep or not arg:
        raise ValueError(f"latency kind {name!r} needs an argument, e.g. "
                         f"'{name}:0.5' (got {spec!r})")
    try:
        param = float(arg)
    except ValueError:
        raise ValueError(
            f"bad latency parameter {arg!r} in {spec!r}") from None
    return LatencySpec(kind=name, param=param)


@dataclass(frozen=True)
class AsyncSpec:
    """Parsed ``GFLConfig.async_spec`` (see module docstring)."""
    buffer: int = 8
    latency: LatencySpec = LatencySpec()
    max_stale: int = 0
    alpha: float = 0.5
    rate: int = 0          # candidate events per server per tick; 0 = buffer

    def __post_init__(self):
        if self.buffer < 1:
            raise ValueError(f"buffer must be >= 1, got {self.buffer}")
        if self.max_stale < 0:
            raise ValueError(f"max_stale must be >= 0, got {self.max_stale}")
        if self.alpha < 0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")
        if self.rate < 0:
            raise ValueError(f"rate must be >= 0, got {self.rate}")

    @property
    def events_per_tick(self) -> int:
        """The event batch width E (``rate``, defaulting to ``buffer``)."""
        return self.rate or self.buffer

    @property
    def is_sync_limit(self) -> bool:
        """True when every tick is a lockstep synchronous round: the buffer
        fills exactly every tick (rate == buffer) with zero-latency, age-0
        arrivals and no staleness slack."""
        return (self.events_per_tick == self.buffer
                and self.latency.is_zero and self.max_stale == 0)

    def to_spec(self) -> str:
        """Inverse of :func:`parse_async_spec` (canonical form)."""
        parts = [f"buffer={self.buffer}"]
        if not self.latency.is_zero:
            parts.append(f"latency={self.latency.to_spec()}")
        if self.max_stale:
            parts.append(f"max_stale={self.max_stale}")
        if self.alpha != 0.5:
            parts.append(f"alpha={self.alpha:g}")
        if self.rate:
            parts.append(f"rate={self.rate}")
        return "async:" + ",".join(parts)


def parse_async_spec(spec: str) -> "AsyncSpec | None":
    """Parse ``GFLConfig.async_spec``; ``"none"`` returns None.

    Grammar: ``async[:key=value,...]`` with keys ``buffer`` (int),
    ``latency`` (a :func:`parse_latency_spec` string — its own ``:`` is
    part of the value), ``max_stale`` (int), ``alpha`` (float), ``rate``
    (int).
    """
    spec = (spec or "none").strip()
    if spec == "none":
        return None
    name, _, rest = spec.partition(":")
    if name != "async":
        raise ValueError(f"bad async spec {spec!r}; expected 'none' or "
                         "'async[:buffer=..,latency=..,max_stale=..,"
                         "alpha=..,rate=..]'")
    kw: dict = {}
    conv = {"buffer": int, "max_stale": int, "rate": int, "alpha": float,
            "latency": parse_latency_spec}
    for part in filter(None, rest.split(",")):
        k, sep, v = part.partition("=")
        if not sep or k not in conv:
            raise ValueError(
                f"unknown argument {part!r} in async spec {spec!r}; "
                f"expected key=value with key in {sorted(conv)}")
        if k in kw:
            raise ValueError(f"duplicate argument {k!r} in async spec "
                             f"{spec!r}")
        try:
            kw[k] = conv[k](v)
        except ValueError as e:
            raise ValueError(
                f"bad value {v!r} for {k!r} in async spec {spec!r}: {e}"
            ) from None
    return AsyncSpec(**kw)
