"""Event-driven async GFL executor: buffered, staleness-weighted rounds.

The synchronous executors (``run_gfl`` / ``run_gfl_population``) assume a
round barrier — every sampled client reports before any server
aggregates.  This module drops the barrier (FedBuff-style semi-async):
clients arrive on their own clocks (:mod:`repro.core.events.queue`, the
availability traces reused as arrival intensities), each arrival carries
an AGE (which past model snapshot it was computed against, drawn from the
``AsyncSpec.latency`` distribution and bounded by ``max_stale``), and each
server aggregates when **its own buffer fills**, not when a global round
ends (:mod:`repro.core.events.buffer`).

Per tick the executor

  1. draws the tick's candidate event batch with THE shared cohort-draw
     program (:func:`~repro.core.population.engine.
     uniform_cohort_indices`, or with-replacement importance draws that
     compose PR 3's ``1/(K pi)`` reweighting);
  2. realizes arrivals (trace intensity thinning) and refuses over-stale
     ones, computes each surviving event's client update against its stale
     snapshot, and folds the tick through the privacy mechanism's protect
     hook as a staleness-weighted protected mean (weights
     ``1/(1 + age)^alpha``, normalization exact);
  3. folds the tick into each server's buffer; servers at >= ``buffer``
     arrivals flush — announce their weighted fold — while the rest
     re-announce their cached psi (the resilience runtime's straggler
     re-announcement semantics), and the graph combine (eq. 8) runs
     whenever at least one server flushed.

**Sync-limit contract** (the regression anchor): with ``buffer == rate``,
zero latency, ``max_stale = 0`` and a pure cohort (uniform sampler,
always-on trace), every tick is a lockstep synchronous round, and the
executor routes through the population engine's EXACT pure-path programs
(`uniform_cohort_batch` + ``gfl.make_gfl_step``) — trajectories are
bit-identical to ``run_gfl_population`` by construction, not by parallel
code (tests/test_events.py).

``run_gfl_async(..., scan=True)`` compiles the whole run as one
``lax.scan`` over event batches — arrival realizations enter as stacked
scan inputs, cohorts are gathered lazily inside the body, so throughput
is independent of the population size K (benchmarks/async_throughput.py).

Privacy: each *flush* is one ledger release of that server; feed the
result's ``(flushed, q)`` schedule to
:class:`~repro.core.privacy.accountant.AsyncAccountant` — per-server
curves at each server's own realized cadence and realized q, with the
synchronous lockstep schedule pinned to the scalar accountant.  See
docs/async.md.
"""
from __future__ import annotations

from dataclasses import replace as dc_replace
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from contextlib import nullcontext

from repro.configs.base import GFLConfig
from repro.core import gfl
from repro.core import sampling as IS
from repro.core.events.buffer import (
    BufferedServerState,
    fold_tick,
    flush,
    init_buffers,
    staleness_weights,
)
from repro.core.events.queue import EventQueue, trace_intensity_fn
from repro.core.events.spec import AsyncSpec, parse_async_spec
from repro.core.population.cohort import AvailabilityTrace, parse_cohort_spec
from repro.sanitize import (ReleaseLedger, SanitizerError,
                            sanitize_enabled, sanitizer_scope)
from repro.telemetry import (MetricsStream, RunLog, session_from_config,
                             telemetry_active, trace_span)
from repro.core.population.engine import (
    as_population,
    estimate_w_ref,
    uniform_cohort_batch,
    uniform_cohort_indices,
)
from repro.core.privacy.mechanism import RoundContext, mechanism_for
from repro.core.resilience.faults import parse_fault_spec
from repro.core.resilience.process import TopologyProcess
from repro.core.resilience.runtime import ensure_dropout_safe
from repro.core.simulate import base_combination_matrix, make_grad_fn


class AsyncState(NamedTuple):
    """Carry of the event loop."""
    params: jax.Array            # [P, D] per-server models
    step: jax.Array              # scalar int32 tick index
    key: jax.Array               # protocol PRNG key
    buffers: BufferedServerState
    hist: jax.Array              # [S+1, P, D] snapshots (hist[0] == params);
                                 # empty [0, P, D] when max_stale == 0


class AsyncRunResult(NamedTuple):
    """Trajectory and release schedule of one async run."""
    msd: np.ndarray            # [T] centroid MSD vs w_ref per tick
    params: jax.Array          # final [P, D]
    flushed: np.ndarray        # [T, P] bool: which servers released when
    q: np.ndarray              # [T, P] realized per-flush sampling rate
    staleness: np.ndarray      # [T, P] mean folded age per tick
    events: np.ndarray         # [T, P] valid arrivals folded per tick
    dropped_stale: np.ndarray  # [T, P] arrivals refused at the bound
    gaps: Optional[np.ndarray]  # [T] realized spectral gaps (fault runs)
    spec: AsyncSpec
    accountant: Optional[object] = None  # AsyncAccountant, charged off the
                                         # realized flush/q schedule

    @property
    def releases(self) -> np.ndarray:
        """[P] total releases (flushes) per server."""
        return self.flushed.sum(axis=0)


def _importance_probs(cfg: GFLConfig, P: int, K: int, floor: float,
                      scheduler=None) -> jax.Array:
    """[P, K] with-replacement event-identity probabilities for the
    importance sampler: the scheduler's current norm-estimate state when
    one is passed (frozen for the run — the scan executor cannot thread
    host-side norm feedback), else the fresh-state uniform mix."""
    state = scheduler.is_state if scheduler is not None else \
        IS.init_is_state(P, K)
    return IS.sampling_probs(state, floor=floor)


def _make_event_tick(pop, cfg: GFLConfig, spec: AsyncSpec, trace, grad_fn,
                     mech, batch_size: int, probs, w_ref_j):
    """jit-ready general event tick: (state, kb, valid_u, ages, A_t) ->
    (state, (msd, flushed, q, mean_age, n_valid, dropped)).

    Static flags select exactly the machinery the spec needs — the same
    only-trace-it-in discipline as the resilience runtime, so disabled
    features cost nothing and change no programs."""
    P, K, N = pop.P, pop.num_clients, pop.samples_per_client
    E, S, alpha = spec.events_per_tick, spec.max_stale, spec.alpha
    use_trace = not trace.always_on
    use_latency = not spec.latency.is_zero
    use_is = probs is not None
    use_mask = use_trace or use_latency
    intensity = trace_intensity_fn(trace, K) if use_trace else None
    max_pi = float(jnp.max(probs)) if use_is else None

    def tick(state: AsyncState, kb, valid_u, ages, A_t):
        # -- cohort draw: the shared program (uniform), or with-replacement
        #    importance draws mirroring the weighted population path
        if use_is:
            kc, kb2 = jax.random.split(kb)
            idx = jax.vmap(
                lambda k, p: jax.random.choice(k, K, (E,), replace=True,
                                               p=p)
            )(jax.random.split(kc, P), probs)
            bidx = jax.vmap(
                lambda k: jax.random.choice(k, N, (batch_size,),
                                            replace=False)
            )(jax.random.split(kb2, P * E)).reshape(P, E, batch_size)
        else:
            idx, bidx = uniform_cohort_indices(kb, P, K, N, E, batch_size)
        h, g = pop.gather(idx, bidx)

        key, sub = jax.random.split(state.key)
        ctx = RoundContext(step=state.step)
        key_round, key_combine = jax.random.split(sub)
        server_keys = jax.random.split(key_round, P)

        # -- arrivals: intensity thinning + bounded staleness
        valid = jnp.ones((P, E), bool)
        if use_trace:
            valid &= valid_u < intensity(state.step, idx)
        if use_latency:
            ok_age = ages <= S
            dropped = (valid & ~ok_age).sum(axis=1)
            valid &= ok_age
            a = jnp.minimum(ages, S)
        else:
            dropped = jnp.zeros((P,), jnp.int32)
            a = jnp.zeros((P, E), jnp.int32)
        s = staleness_weights(a, alpha) * valid           # [P, E]
        n_valid = valid.sum(axis=1)                       # [P]
        wsum = s.sum(axis=1)                              # [P]

        # -- stale model snapshots the arrivals were computed against
        if S > 0:
            w_base = state.hist[a, jnp.arange(P)[:, None]]   # [P, E, D]
        else:
            w_base = jnp.broadcast_to(
                state.params[:, None], (P, E, state.params.shape[1]))

        # -- per-event client updates + staleness-weighted protected fold.
        #    Pre-scaling each update by s_e * n_valid / sum(s) makes the
        #    mechanism's (masked) survivor MEAN equal the weight-normalized
        #    fold sum(s x)/sum(s) — the protect hook stays the single
        #    place noise/masks are injected.
        if use_latency:
            scale = s * (n_valid.astype(jnp.float32)
                         / jnp.maximum(wsum, 1e-12))[:, None]
        else:
            scale = None   # all folded weights are 1: the mean IS the fold

        rho = (IS.importance_weights(probs, idx) if use_is
               else jnp.ones((P, E)))

        if cfg.use_kernels and mech.fold_spec(ctx) is not None:
            # fused round-fold kernel over the tick's event batch: stale
            # per-event bases, importance weights pre-clip, staleness
            # weights as fold weights (weight-normalized), noise/masks at
            # the survivor mean — the buffered ``weighted_fold`` computed
            # in one two-pass stream over [P, E, D]
            grads = jax.vmap(lambda wb_p, h_p, g_p: jax.vmap(
                lambda w_b, hb, gb: grad_fn(w_b, (hb, gb)))(wb_p, h_p, g_p)
            )(w_base, h, g)
            if use_mask:
                fold_w = s
                noise_w = (valid.astype(jnp.float32)
                           / jnp.maximum(n_valid, 1)[:, None])
            else:
                fold_w = noise_w = None
            # at S == 0 every event's base is the live model: hand the
            # kernel the [P, D] params and let it broadcast in-VMEM
            contrib, _ = gfl._fused_client_fold(
                state.params if S == 0 else w_base, grads, server_keys,
                cfg, mech, ctx, pre_w=rho if use_is else None,
                fold_w=fold_w, noise_w=noise_w)
            return _post_fold(state, contrib, key, key_combine, wsum,
                              n_valid, a, valid, dropped, A_t, ctx)

        def one_server(wb_p, h_p, g_p, rho_p, key_p, valid_p, scale_p):
            def one_event(w_b, hb, gb, rho_e):
                grad = grad_fn(w_b, (hb, gb))
                if use_is:
                    # importance weight BEFORE the sensitivity clip — the
                    # weighted population path's calibration-preserving
                    # composition
                    step_g = gfl.clip_to_bound(rho_e * grad, cfg.grad_bound)
                else:
                    step_g = gfl.clip_to_bound(grad, cfg.grad_bound)
                return w_b - cfg.mu * step_g

            w_upd = jax.vmap(one_event)(wb_p, h_p, g_p, rho_p)   # [E, D]
            if scale_p is not None:
                w_upd = w_upd * scale_p[:, None]
            if use_mask:
                return mech.client_protect_masked(w_upd, key_p, valid_p,
                                                  ctx)
            return mech.client_protect(w_upd, key_p, ctx)

        contrib = jax.vmap(
            one_server, in_axes=(0, 0, 0, 0, 0, 0,
                                 None if scale is None else 0)
        )(w_base, h, g, rho, server_keys, valid, scale)        # [P, D]
        return _post_fold(state, contrib, key, key_combine, wsum, n_valid,
                          a, valid, dropped, A_t, ctx)

    def _post_fold(state, contrib, key, key_combine, wsum, n_valid, a,
                   valid, dropped, A_t, ctx):
        """Buffer fold, per-server flush, gated graph combine, snapshots."""
        buf = fold_tick(state.buffers, contrib, wsum, n_valid)
        n_at_flush = buf.buf_n
        if cfg.use_kernels:
            # fused cached-psi re-announce: the combine kernel selects
            # fold-vs-cache per server in VMEM (no separate [P, D] pass)
            cache = state.buffers.psi_cache
            do_flush, psi_fold, buf = flush(buf, spec.buffer, select=False)
            combine_op = (psi_fold, key_combine, cache,
                          do_flush.astype(jnp.float32))
            combine = lambda op: mech.server_combine(
                op[0], op[1], A_t, ctx, cache=op[2], gate=op[3])
        else:
            do_flush, psi, buf = flush(buf, spec.buffer)
            combine_op = (psi, key_combine)
            combine = lambda op: mech.server_combine(op[0], op[1], A_t, ctx)
        if use_is:
            q_flush = jnp.minimum(1.0, n_at_flush * max_pi)
        else:
            q_flush = jnp.minimum(1.0, n_at_flush / K)
        q_flush = jnp.where(do_flush, q_flush, 0.0)

        # -- graph combine whenever anyone flushed; non-flushing servers
        #    re-announce their cached psi (straggler semantics)
        new_params = jax.lax.cond(
            do_flush.any(), combine, lambda op: state.params, combine_op)

        if S > 0:
            hist = jnp.concatenate([new_params[None], state.hist[:-1]], 0)
        else:
            hist = state.hist

        mean_age = ((a * valid).sum(axis=1)
                    / jnp.maximum(n_valid, 1)).astype(jnp.float32)
        msd = jnp.sum((gfl.centroid(new_params) - w_ref_j) ** 2)
        new_state = AsyncState(new_params, state.step + 1, key, buf, hist)
        return new_state, (msd, do_flush, q_flush, mean_age, n_valid,
                           dropped)

    return tick


def _init_async_state(key: jax.Array, P: int, dim: int, S: int
                      ) -> AsyncState:
    """Same initial draws as ``gfl.init_state`` (bit-compatible), plus
    empty buffers and the snapshot history seeded with the init params."""
    base = gfl.init_state(key, P, dim)
    hist = (jnp.tile(base.params[None], (S + 1, 1, 1)) if S > 0
            else jnp.zeros((0, P, dim)))
    return AsyncState(base.params, base.step, base.key,
                      init_buffers(base.params), hist)


def run_gfl_async(source, cfg: GFLConfig, *, ticks: int,
                  batch_size: int = 10, seed: int = 0,
                  A: Optional[np.ndarray] = None,
                  process: Optional[TopologyProcess] = None,
                  spec: Optional[AsyncSpec] = None,
                  scheduler=None, w_ref=None, scan: bool = False
                  ) -> AsyncRunResult:
    """Run the event-driven executor with accounting/sanitizing attached.

    The returned result carries an :class:`AsyncAccountant` charged off
    the realized flush/q schedule (``record_schedule``), so per-server
    release ledgers always accompany the trajectory.  Under sanitize mode
    (``cfg.sanitize`` / ``REPRO_SANITIZE=1``) the run executes inside
    :func:`repro.sanitize.sanitizer_scope` and the total releases
    performed are cross-checked against the accountant's ledgers.
    """
    sanitize = sanitize_enabled(cfg)
    with session_from_config(cfg):
        with sanitizer_scope() if sanitize else nullcontext():
            with trace_span("async_run", ticks=ticks, scan=scan):
                res = _run_async_impl(
                    source, cfg, ticks=ticks, batch_size=batch_size,
                    seed=seed, A=A, process=process, spec=spec,
                    scheduler=scheduler, w_ref=w_ref, scan=scan)
        P = res.flushed.shape[1]
        acc = mechanism_for(cfg).async_accountant(P)
        with trace_span("privacy_accounting", ticks=ticks):
            acc.record_schedule(np.asarray(res.flushed), np.asarray(res.q))
    if sanitize:
        ledger = ReleaseLedger()
        ledger.record_release(int(np.asarray(res.flushed).sum()))
        ledger.charge_from(acc)
        ledger.cross_check()
        if not np.all(np.isfinite(np.asarray(res.msd))):
            raise SanitizerError("non-finite MSD trajectory under "
                                 "sanitize mode")
    return res._replace(accountant=acc)


def _run_async_impl(source, cfg: GFLConfig, *, ticks: int,
                    batch_size: int = 10, seed: int = 0,
                    A: Optional[np.ndarray] = None,
                    process: Optional[TopologyProcess] = None,
                    spec: Optional[AsyncSpec] = None,
                    scheduler=None, w_ref=None, scan: bool = False
                    ) -> AsyncRunResult:
    """Run the event-driven GFL executor for ``ticks`` event batches.

    ``source``/``cfg`` follow :func:`~repro.core.population.engine.
    run_gfl_population`; the async behavior comes from ``cfg.async_spec``
    (or an explicit ``spec``), arrival intensities from the trace part of
    ``cfg.cohort``, and link/outage faults from ``cfg.fault`` (per-tick
    effective A_i).  Straggler and dropout fault components are rejected:
    buffered aggregation with bounded staleness IS the async model of
    those regimes.  In the sync limit this function routes through the
    population engine's exact pure-path programs (module docstring).
    """
    if spec is None:
        spec = parse_async_spec(cfg.async_spec)
    if spec is None:
        raise ValueError(
            "run_gfl_async needs an async spec: set GFLConfig.async_spec "
            "(e.g. 'async:buffer=8,latency=lognorm:0.5,max_stale=4') or "
            "pass spec=")
    if cfg.combine_every != 1:
        raise ValueError("the event executor combines on flush ticks; "
                         "combine_every amortization is a synchronous "
                         "knob — use combine_every=1")
    fault = parse_fault_spec(cfg.fault)
    if fault.straggler > 0 or fault.client_dropout > 0:
        raise ValueError(
            "async executor models stragglers/dropout through buffered "
            "aggregation with bounded staleness (latency=/max_stale=); "
            "drop the straggler:/dropout: fault components (links:/outage: "
            "compose fine)")
    sampler, floor, trace = parse_cohort_spec(cfg.cohort)

    pop = as_population(source, cfg)
    P, K = pop.P, pop.num_clients
    E = spec.events_per_tick
    if not 1 <= E <= K:
        raise ValueError(f"events per tick E={E} not in [1, K={K}] "
                         "(the per-tick candidate draw is without "
                         "replacement)")
    grad_fn = make_grad_fn(pop.rho)
    if w_ref is None:
        w_ref = pop.w_ref
    if w_ref is None:
        w_ref = estimate_w_ref(pop)
    w_ref_j = jnp.asarray(w_ref)

    if process is None and cfg.fault != "none":
        base = A if A is not None else base_combination_matrix(cfg, P)
        process = TopologyProcess(base, cfg.fault, seed=cfg.topology_seed)
    if A is None:
        A = base_combination_matrix(cfg, P)
    Aj = jnp.asarray(A, jnp.float32)

    mech = mechanism_for(cfg)
    use_trace = not trace.always_on
    use_is = sampler == "importance"
    if use_trace or not spec.latency.is_zero:
        ensure_dropout_safe(mech.noise_profile(),
                            where="async event arrivals")

    lockstep = spec.is_sync_limit and not use_trace and not use_is
    if lockstep and not scan:
        return _run_lockstep_loop(pop, cfg, Aj, process, grad_fn, spec,
                                  batch_size, ticks, seed, w_ref_j)

    probs = (_importance_probs(cfg, P, K, floor, scheduler) if use_is
             else None)
    tick = _make_event_tick(pop, cfg, spec, trace, grad_fn, mech,
                            batch_size, probs, w_ref_j)
    queue = EventQueue(P, spec, seed=cfg.topology_seed)
    gaps = None
    if process is not None:
        gaps = np.asarray([process.realize(t).gap for t in range(ticks)])

    def tick_A(t: int) -> jax.Array:
        if process is None or process.static:
            return Aj
        return jnp.asarray(process.realize(t).A, jnp.float32)

    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    state = _init_async_state(k_init, P, pop.dim, spec.max_stale)

    if scan:
        us, ages = queue.realize_horizon(ticks)
        xs = (jnp.asarray(us), jnp.asarray(ages))
        if process is not None and not process.static:
            xs = xs + (jnp.stack([tick_A(t) for t in range(ticks)]),)

        # in-graph metrics: a MetricsStream pytree rides the scan carry
        # ONLY when a telemetry session is active — the off-path carry is
        # exactly the uninstrumented (key, state) structure; at
        # flush_every > 1 (REPRO_TELEMETRY_FLUSH_EVERY) rows buffer N
        # ticks per ordered io_callback flush
        ms = (MetricsStream("step", cumulative={"events_total": "events"},
                            fields=("step", "msd", "flushed", "events",
                                    "events_total", "dropped", "staleness"))
              if telemetry_active() else None)

        def body(carry, x):
            loop_key, st = carry[0], carry[1]
            loop_key, kb = jax.random.split(loop_key)
            A_t = x[2] if len(x) > 2 else Aj
            st, out = tick(st, kb, x[0], x[1], A_t)
            if ms is None:
                return (loop_key, st), out
            msd_t, do_flush, q_flush, mean_age, n_valid, dropped_t = out
            acc = ms.tap(carry[2], {
                "step": st.step, "msd": msd_t,
                "flushed": do_flush.sum().astype(jnp.int32),
                "events": n_valid.sum().astype(jnp.int32),
                "dropped": dropped_t.sum().astype(jnp.int32),
                "staleness": jnp.mean(mean_age)})
            return (loop_key, st, acc), out

        carry0 = ((key, state) if ms is None
                  else (key, state, ms.init()))
        with trace_span("async_scan", ticks=ticks):
            final, outs = jax.lax.scan(body, carry0, xs)
        state = final[1]
        if ms is not None:
            jax.effects_barrier()   # in-scan flushes land before the tail
            ms.drain(final[2] if len(final) > 2 else None)
        msd, flushed, q, stale, events, dropped = (np.asarray(o)
                                                   for o in outs)
    else:
        tick_j = jax.jit(tick)
        rows = []
        for t in range(ticks):
            key, kb = jax.random.split(key)
            u, ag = queue.realize(t)
            state, out = tick_j(state, kb, jnp.asarray(u), jnp.asarray(ag),
                                tick_A(t))
            rows.append(tuple(np.asarray(o) for o in out))
        msd, flushed, q, stale, events, dropped = (np.stack(col)
                                                   for col in zip(*rows))

    log = RunLog("async")
    cols = {"msd": msd, "flushed": flushed.astype(np.int32), "q_server": q,
            "staleness": stale, "events": events.astype(np.int32),
            "dropped_stale": dropped.astype(np.int32)}
    if gaps is not None:
        cols["gap"] = gaps
    log.extend_arrays(cols)
    return AsyncRunResult(np.asarray(msd), state.params,
                          np.asarray(log.stack("flushed")).astype(bool),
                          log.stack("q_server"), log.stack("staleness"),
                          log.stack("events"), log.stack("dropped_stale"),
                          log.stack("gap"), spec)


def _run_lockstep_loop(pop, cfg, Aj, process, grad_fn, spec, batch_size,
                       ticks, seed, w_ref_j) -> AsyncRunResult:
    """The sync limit: every tick is a lockstep round — run the population
    engine's EXACT pure-path programs (same sampler jit, same step jit,
    same key discipline), so trajectories are bit-identical to
    ``run_gfl_population`` by construction."""
    P, K = pop.P, pop.num_clients
    E = spec.buffer
    step = gfl.make_gfl_step(
        process if process is not None else Aj, grad_fn, cfg)
    sample = jax.jit(lambda k: uniform_cohort_batch(k, pop, E, batch_size))
    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    state = gfl.init_state(k_init, P, pop.dim)
    log = RunLog("async")
    q_tick = min(1.0, E / K)
    flushed_row = np.ones(P, np.int32)
    for t in range(ticks):
        key, kb = jax.random.split(key)
        state = step(state, sample(kb))
        gap = process.realize(t).gap if process is not None else None
        wc = gfl.centroid(state.params)
        log.row(t, msd=float(jnp.sum((wc - w_ref_j) ** 2)), gap=gap,
                flushed=flushed_row, q_server=np.full(P, q_tick),
                events=np.full(P, E, np.int32), cohort=E)
    T = ticks
    return AsyncRunResult(
        msd=np.asarray(log.stack("msd")), params=state.params,
        flushed=np.asarray(log.stack("flushed")).astype(bool),
        q=np.asarray(log.stack("q_server")),
        staleness=np.zeros((T, P), np.float32),
        events=np.asarray(log.stack("events")),
        dropped_stale=np.zeros((T, P), np.int32),
        gaps=log.stack("gap"), spec=spec)


# ---------------------------------------------------------------------------
# mesh wiring: the event layer as a cohort-weight driver
# ---------------------------------------------------------------------------


class AsyncCohortDriver:
    """Host-side event layer for the mesh trainer (launch/train.py
    ``--async``): one training step = one tick, the step's sampled [P, L]
    cohort are the tick's candidate arrivals.

    Produces the per-step ``cohort_weights`` for
    ``steps.make_train_step`` — validity-thinned, staleness-weighted and
    normalized so the mesh's server mean equals the weighted fold — plus
    the per-server (flushed, q) release schedule the
    :class:`~repro.core.privacy.accountant.AsyncAccountant` consumes.

    A server's weight row is ZERO until its buffer fills: its clients'
    data only enters the published model on its flush steps, which is
    exactly when its ledger is charged — the accounting and the release
    pattern agree (between flushes the mesh combine only re-mixes
    already-charged neighbor releases plus noise).  The mesh step can
    only feed the flush from the current step's batch, so non-flush-step
    arrivals advance the buffer clock without contributing data — the
    fully buffered cross-tick fold lives in the simulator executor
    (docs/async.md).  The availability trace must be applied exactly
    once: pass a trace here ONLY when no ``CohortScheduler`` already
    thinned the cohort at sampling time.
    """

    def __init__(self, spec: AsyncSpec, P: int, L: int, K: int, *,
                 trace: "AvailabilityTrace | str" = "always", seed: int = 0):
        from repro.core.population.cohort import parse_trace_spec
        self.spec = spec
        self.P, self.L, self.K = P, L, K
        self.trace = (parse_trace_spec(trace) if isinstance(trace, str)
                      else trace)
        # the mesh cohort is the event batch: L slots per server per tick
        self.queue = EventQueue(P, dc_replace(spec, rate=L), seed=seed)
        self.buf_n = np.zeros(P, np.int64)

    def step(self, t: int, client_ids: Optional[np.ndarray] = None):
        """(cohort_weights [P, L] jnp, flushed [P] bool, q [P]) of tick t."""
        spec = self.spec
        u, ages = self.queue.realize(t)
        valid = np.ones((self.P, self.L), bool)
        if not self.trace.always_on:
            ids = (np.asarray(client_ids) if client_ids is not None
                   else np.broadcast_to(np.arange(self.L) % self.K,
                                        (self.P, self.L)))
            valid &= u < self.trace.probs(t, self.K)[ids]
        valid &= ages <= spec.max_stale
        a = np.minimum(ages, spec.max_stale)
        s = valid * np.asarray(staleness_weights(a, spec.alpha))
        self.buf_n += valid.sum(axis=1)
        # a flush needs a full buffer AND data to release this step (the
        # mesh step feeds the flush from the current batch only)
        flushed = (self.buf_n >= spec.buffer) & valid.any(axis=1)
        self.buf_n[flushed] = 0
        # release gating: zero weights until the flush — data enters the
        # model exactly on the steps the ledger is charged for
        s = s * flushed[:, None]
        wsum = s.sum(axis=1)
        weights = s * (self.L / np.maximum(wsum, 1e-12))[:, None]
        q = np.where(flushed,
                     np.minimum(1.0, valid.sum(axis=1) / self.K), 0.0)
        return jnp.asarray(weights, jnp.float32), flushed, q
