"""Event arrival process: who arrives when, and how stale they are.

An :class:`EventQueue` realizes the per-tick **event batches** the async
executor consumes.  Time advances in ticks; at tick ``t`` every server has
``E = AsyncSpec.events_per_tick`` candidate event slots, and slot ``j`` of
server ``p`` is the global event index ``t * P * E + p * E + j`` — every
realization below is a pure function of ``(seed, event_idx)``, exactly the
determinism contract of the resilience runtime's fault draws (one shared
helper: :func:`repro.core.resilience.faults.fault_stream_rng`).

Per candidate event the queue realizes

  * an **arrival uniform** ``u`` — the event fires iff ``u`` falls below
    the arriving client's availability intensity.  Intensities are the
    population engine's :class:`~repro.core.population.cohort.
    AvailabilityTrace` probabilities reused as per-client arrival rates
    (diurnal phases, device classes): the same trace that throttled
    synchronous cohort sampling now throttles the client's own clock.
  * an **age** — the floor of a :class:`~repro.core.events.spec.
    LatencySpec` draw: the arriving update was computed against the
    server's model ``age`` ticks ago.  Ages beyond the staleness bound are
    refused by the executor (``dropped_stale`` in the run result).

Because client *identity* is drawn inside the compiled step (the cohort
sampler), the identity-dependent part of the arrival test runs in-graph:
:func:`trace_intensity_fn` compiles each trace kind to pure jnp arithmetic
(diurnal is a closed-form wave, devclass a static [K] table), while the
uniforms and ages realized here enter the step as traced arguments — the
same host-realization / traced-computation split as ``TopologyProcess``.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.events.spec import AsyncSpec
from repro.core.population.cohort import AvailabilityTrace
from repro.core.resilience.faults import (
    STREAM_ARRIVAL,
    STREAM_LATENCY,
    fault_stream_rng,
)


def trace_intensity_fn(trace: AvailabilityTrace, K: int
                       ) -> Optional[Callable]:
    """Compile a trace's availability probabilities to jnp arithmetic.

    Returns ``fn(t, idx) -> probs`` (t a traced scalar tick, idx a traced
    int array of client ids, probs the per-id arrival intensities), or
    None for the ``always`` trace (intensity 1 — the executor statically
    skips the arrival test).  Matches ``AvailabilityTrace.probs`` by
    construction: same formulas, evaluated per sampled id instead of per
    population row.
    """
    if trace.always_on:
        return None
    if trace.kind == "devclass":
        table = jnp.asarray(trace.probs(0, K), jnp.float32)  # t-independent

        def devclass(t, idx):
            return table[idx]

        return devclass

    period, lo = trace.period, trace.min_avail

    def diurnal(t, idx):
        phase = (idx % period) / period
        wave = 0.5 * (1.0 + jnp.sin(
            2.0 * jnp.pi * (t / period + phase)))
        return lo + (1.0 - lo) * wave

    return diurnal


class EventQueue:
    """Deterministic per-tick event-batch realizations.

    ``realize(t)`` returns the tick's ``(arrival uniforms [P, E],
    ages [P, E])``; ``realize_horizon(T)`` stacks ``T`` ticks into the
    ``[T, P, E]`` arrays the scan executor consumes as ``xs``.  Both are
    memo-free pure functions of ``(seed, t)`` — re-running a tick
    re-realizes identical events, which is what makes async runs
    reproducible and resumable.
    """

    def __init__(self, P: int, spec: AsyncSpec, *, seed: int = 0):
        self.P = P
        self.spec = spec
        self.E = spec.events_per_tick
        self.seed = seed

    def realize(self, t: int) -> Tuple[np.ndarray, np.ndarray]:
        """(arrival uniforms [P, E] float32, ages [P, E] int32) of tick t."""
        shape = (self.P, self.E)
        u = fault_stream_rng(self.seed, STREAM_ARRIVAL, t).random(
            shape).astype(np.float32)
        ages = self.spec.latency.sample_ages(
            fault_stream_rng(self.seed, STREAM_LATENCY, t), shape)
        return u, ages

    def realize_horizon(self, T: int) -> Tuple[np.ndarray, np.ndarray]:
        """Stacked ([T, P, E] uniforms, [T, P, E] ages) for a whole run."""
        us, ages = zip(*(self.realize(t) for t in range(T)))
        return np.stack(us), np.stack(ages)
