"""Buffered, staleness-weighted per-server aggregation state.

FedBuff-style semantics: each server folds arriving client contributions
into a running weighted sum and only runs the protocol's aggregation +
combination once the buffer holds ``AsyncSpec.buffer`` arrivals.  A
contribution of age ``a`` (computed against the model ``a`` ticks ago)
folds with weight

    s(a) = 1 / (1 + a)^alpha                       (nonnegative, s(0) = 1)

and the flushed aggregate is the weight-normalized fold

    psi_p = sum_e s_e x_e / sum_e s_e

— an affine combination of the buffered contributions, so when the ages
are drawn independently of the updates the fold is unbiased in
expectation: E[psi] equals the unweighted mean of E[x] (property-tested in
tests/test_events.py).  At ``alpha = 0`` (or all ages 0) every weight is
1 and the fold IS the synchronous mean.

The executor composes this with PR 3's importance reweighting: an
importance-sampled event's ``1/(K pi)`` weight scales its *gradient*
before the sensitivity clip (exactly the weighted population path), while
the staleness weight governs the *fold* — the two compose without
touching the privacy calibration's clipping bound.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


def staleness_weights(ages: jax.Array, alpha: float) -> jax.Array:
    """``1/(1 + age)^alpha`` — nonnegative, 1 at age 0, nonincreasing."""
    return 1.0 / (1.0 + jnp.asarray(ages, jnp.float32)) ** alpha


def weighted_fold(x: jax.Array, weights: jax.Array, axis: int = 0
                  ) -> jax.Array:
    """Weight-normalized fold ``sum w x / sum w`` (the unbiased
    contribution reweighting); zero total weight folds to zero."""
    w = jnp.asarray(weights, x.dtype)
    shape = [1] * x.ndim
    shape[axis] = -1
    w = w.reshape(shape)
    wsum = w.sum(axis=axis, keepdims=True)
    return (w * x).sum(axis=axis) / jnp.maximum(wsum.squeeze(axis), 1e-12)


class BufferedServerState(NamedTuple):
    """Per-server aggregation buffers, traced through the event loop."""
    buf_sum: jax.Array    # [P, D] staleness-weighted contribution sum
    buf_wsum: jax.Array   # [P] folded weight mass
    buf_n: jax.Array      # [P] int32 arrivals since the last flush
    version: jax.Array    # [P] int32 flush count (the server's own clock)
    psi_cache: jax.Array  # [P, D] last announced psi (re-announced by
                          # non-flushing servers during a combine, the
                          # resilience runtime's straggler semantics)


def init_buffers(params: jax.Array) -> BufferedServerState:
    """Empty buffers; psi_cache seeded with the initial params (the same
    seeding as ``init_resilient_state``)."""
    P = params.shape[0]
    return BufferedServerState(
        buf_sum=jnp.zeros_like(params),
        buf_wsum=jnp.zeros((P,), jnp.float32),
        buf_n=jnp.zeros((P,), jnp.int32),
        version=jnp.zeros((P,), jnp.int32),
        psi_cache=params)


def fold_tick(buf: BufferedServerState, contrib: jax.Array,
              wsum: jax.Array, n: jax.Array) -> BufferedServerState:
    """Fold one tick's per-server protected contribution into the buffers.

    ``contrib`` [P, D] is the tick's staleness-weighted protected mean,
    ``wsum`` [P] its folded weight mass and ``n`` [P] its valid-arrival
    count; ticks recombine exactly because the fold is associative in
    (weighted sum, weight mass) space."""
    return buf._replace(
        buf_sum=buf.buf_sum + wsum[:, None] * contrib,
        buf_wsum=buf.buf_wsum + wsum,
        buf_n=buf.buf_n + n)


def flush(buf: BufferedServerState, buffer_size: int, *,
          select: bool = True
          ) -> Tuple[jax.Array, jax.Array, BufferedServerState]:
    """(flush mask [P], announced psi [P, D], post-flush buffers).

    A server flushes when its buffer holds >= ``buffer_size`` arrivals:
    its announced psi is the weight-normalized fold and its buffers drain;
    a non-flushing server re-announces ``psi_cache``.  The whole buffer
    drains on flush (arrivals beyond ``buffer_size`` in the same tick are
    consumed, not carried).

    ``select=False`` returns the RAW fold instead of the re-announce
    select (``psi_cache`` in the returned state is still the selected
    value): the fused graph-combine kernel performs the select in-VMEM
    from ``(fold, old cache, flush mask)`` — see
    :func:`repro.kernels.ops.graph_combine`."""
    do_flush = buf.buf_n >= buffer_size
    psi_fold = buf.buf_sum / jnp.maximum(buf.buf_wsum, 1e-12)[:, None]
    psi = jnp.where(do_flush[:, None], psi_fold, buf.psi_cache)
    new_buf = BufferedServerState(
        buf_sum=jnp.where(do_flush[:, None], 0.0, buf.buf_sum),
        buf_wsum=jnp.where(do_flush, 0.0, buf.buf_wsum),
        buf_n=jnp.where(do_flush, 0, buf.buf_n),
        version=buf.version + do_flush.astype(jnp.int32),
        psi_cache=psi)
    return do_flush, (psi if select else psi_fold), new_buf
