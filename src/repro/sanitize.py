"""Runtime sanitizer mode — the dynamic counterpart to gflint.

Enabled per-run via ``GFLConfig.sanitize`` or process-wide via
``REPRO_SANITIZE=1``.  Inside :func:`sanitizer_scope` the engines run
with ``jax_debug_key_reuse`` (typed-key reuse detection) and
``jax_debug_nans`` turned on, and every engine cross-checks a
:class:`ReleaseLedger` — releases performed vs releases charged to the
accountant — so an accounting drift that static analysis cannot see
(e.g. an engine recording the wrong number of rounds) fails loudly
instead of silently under-reporting epsilon.

Checks are deliberately O(1) per run: sanitize mode is meant to be
cheap enough for a nightly tier-1 shard (see ``.github/workflows``).
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from dataclasses import dataclass

ENV_FLAG = "REPRO_SANITIZE"
_FALSY = ("", "0", "false", "False", "no")

_DEBUG_FLAGS = ("jax_debug_key_reuse", "jax_debug_nans")


class SanitizerError(AssertionError):
    """An invariant the sanitizer enforces was violated at runtime."""


def sanitize_enabled(cfg=None) -> bool:
    """True when sanitize mode is on for this run (config field wins,
    else the ``REPRO_SANITIZE`` environment flag)."""
    if cfg is not None and getattr(cfg, "sanitize", False):
        return True
    return os.environ.get(ENV_FLAG, "0") not in _FALSY


@contextmanager
def sanitizer_scope():
    """Enable jax's key-reuse and NaN debugging for the dynamic extent
    of a run, restoring prior values on exit.  Flags missing from the
    installed jax are skipped (defense in depth, not a hard dep)."""
    import jax

    previous: dict = {}
    for flag in _DEBUG_FLAGS:
        try:
            previous[flag] = getattr(jax.config, flag)
            jax.config.update(flag, True)
        except (AttributeError, KeyError, ValueError):
            continue
    try:
        yield
    finally:
        for flag, value in previous.items():
            jax.config.update(flag, value)


@dataclass
class ReleaseLedger:
    """Counts noise releases performed vs releases charged.

    Engines record a release per protocol round actually executed and a
    charge per accountant advance; :meth:`cross_check` raises when the
    two diverge — the "release the accountant never heard about" bug
    class (gflint GFL002) caught at runtime instead of in the AST.
    """
    released: int = 0
    charged: int = 0

    def record_release(self, n: int = 1) -> None:
        self.released += int(n)

    def record_charge(self, n: int = 1) -> None:
        self.charged += int(n)

    def charge_from(self, accountant) -> None:
        """Record charges straight off an accountant: a
        ``PrivacyAccountant`` exposes ``step`` (total releases charged),
        an ``AsyncAccountant`` a per-server ``releases`` list (the
        ledger compares against the busiest server — every flushed
        release must be on some ledger)."""
        if hasattr(accountant, "releases"):
            rel = accountant.releases
            self.record_charge(sum(rel))
        else:
            self.record_charge(accountant.step)

    def cross_check(self) -> None:
        if self.released != self.charged:
            raise SanitizerError(
                f"accountant ledger mismatch: {self.released} noise "
                f"release(s) performed but {self.charged} charged — "
                f"every release must be charged exactly once "
                f"(PrivacyAccountant.advance / AsyncAccountant.record_*)")
