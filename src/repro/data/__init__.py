from repro.data.synthetic import (
    TokenStream,
    federated_token_batches,
    logistic_client_data,
    make_batch,
)
from repro.data.partition import dirichlet_partition, uniform_partition

__all__ = [
    "TokenStream", "federated_token_batches", "logistic_client_data",
    "make_batch", "dirichlet_partition", "uniform_partition",
]
