"""Deterministic synthetic data pipelines.

Two generators:
  * the paper's logistic-regression data (Section V), per (server, client);
  * a token-stream LM pipeline (zipf-ish unigram + induction-head bigram
    structure so models actually have signal to fit) for the LM trainers,
    batched per (server, client) for the GFL protocol.

Everything is counter-based (jax.random.fold_in chains) so any batch is
reproducible from (seed, server, client, step) without global state.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def logistic_client_data(key, P: int, K: int, N: int, M: int,
                         sigma_h_range=(0.5, 1.5)):
    """Section-V generator: labels +-1, h | gamma ~ N(gamma*1, sigma^2 I)."""
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jnp.where(jax.random.bernoulli(k1, 0.5, (P, K, N)), 1.0, -1.0)
    sigma = jax.random.uniform(k2, (P, K, 1, 1), minval=sigma_h_range[0],
                               maxval=sigma_h_range[1])
    feats = labels[..., None] + sigma * jax.random.normal(k3, (P, K, N, M))
    return feats, labels


@dataclass(frozen=True)
class TokenStream:
    """Synthetic LM distribution: zipf unigram mixed with a deterministic
    bigram successor table (induction structure)."""
    vocab: int
    seed: int = 0
    bigram_frac: float = 0.5

    def _succ_table(self):
        rng = np.random.default_rng(self.seed)
        return jnp.asarray(rng.permutation(self.vocab), jnp.int32)

    def sample(self, key, batch: int, seq_len: int) -> jax.Array:
        succ = self._succ_table()
        k1, k2, k3 = jax.random.split(key, 3)
        # zipf via exponential rank trick
        ranks = jnp.arange(1, self.vocab + 1, dtype=jnp.float32)
        logits = -jnp.log(ranks)
        draws = jax.random.categorical(k1, logits, shape=(batch, seq_len))
        use_bigram = jax.random.bernoulli(k2, self.bigram_frac,
                                          (batch, seq_len))

        def step(prev, inp):
            d, ub = inp
            tok = jnp.where(ub, succ[prev], d)
            return tok, tok

        first = draws[:, 0]
        _, toks = jax.lax.scan(step, first,
                               (draws[:, 1:].T, use_bigram[:, 1:].T))
        return jnp.concatenate([first[:, None], toks.T], axis=1)


def make_batch(stream: TokenStream, key, batch: int, seq_len: int) -> dict:
    toks = stream.sample(key, batch, seq_len + 1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def federated_token_batches(stream: TokenStream, seed: int, step: int,
                            P: int, L: int, per_client: int, seq_len: int,
                            client_ids=None) -> dict:
    """Batch pytree with leading [P, L] dims for :func:`repro.core.gfl.gfl_round`.

    Each (server, client) pair gets its own fold_in chain, so client data is
    disjoint and reproducible.  ``client_ids`` ([P, L] ints, optional)
    names the *population* client behind each cohort slot — a virtual
    client keeps the same data chain whichever round (and slot) a
    :class:`~repro.core.population.CohortScheduler` samples it into;
    the default is the positional identity ``client_ids[p, l] = l``."""
    base = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    if client_ids is not None:
        client_ids = np.asarray(client_ids)

    def client_batch(p, l):
        cid = l if client_ids is None else int(client_ids[p, l])
        k = jax.random.fold_in(jax.random.fold_in(base, p), cid)
        return make_batch(stream, k, per_client, seq_len)

    batches = [[client_batch(p, l) for l in range(L)] for p in range(P)]
    return jax.tree.map(lambda *xs: jnp.stack(xs).reshape(
        P, L, *xs[0].shape), *[b for row in batches for b in row])
