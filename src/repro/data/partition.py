"""Federated data partitioners: split a dataset across (server, client)."""
from __future__ import annotations

import numpy as np


def uniform_partition(n: int, P: int, K: int, seed: int = 0):
    """Random equal split of n indices into P*K client shards -> [P,K,n//(P*K)]."""
    rng = np.random.default_rng(seed)
    per = n // (P * K)
    idx = rng.permutation(n)[: per * P * K]
    return idx.reshape(P, K, per)


def dirichlet_partition(labels: np.ndarray, P: int, K: int,
                        alpha: float = 0.5, seed: int = 0):
    """Non-IID label-skew split (Dirichlet over classes per client).

    Returns a list-of-lists of index arrays [P][K]."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    n_clients = P * K
    client_idx = [[] for _ in range(n_clients)]
    for c in classes:
        c_idx = np.nonzero(labels == c)[0]
        rng.shuffle(c_idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(c_idx)).astype(int)[:-1]
        for cl, part in enumerate(np.split(c_idx, cuts)):
            client_idx[cl].extend(part.tolist())
    out = [[np.asarray(client_idx[p * K + k]) for k in range(K)]
           for p in range(P)]
    return out
