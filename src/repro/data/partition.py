"""Federated data partitioners: split a dataset across (server, client)."""
from __future__ import annotations

import numpy as np


def uniform_partition(n: int, P: int, K: int, seed: int = 0):
    """Random equal split of n indices into P*K client shards -> [P,K,n//(P*K)].

    The output is rectangular, so the ``n mod (P*K)`` remainder indices are
    intentionally left out (documented, unlike silent float-cut drops);
    use :func:`dirichlet_partition` with ``alpha -> inf`` behavior when every
    index must be assigned."""
    rng = np.random.default_rng(seed)
    per = n // (P * K)
    idx = rng.permutation(n)[: per * P * K]
    return idx.reshape(P, K, per)


def _largest_remainder_counts(props: np.ndarray, total: int) -> np.ndarray:
    """Integer allocation of `total` items proportional to `props`, exact:
    floor the raw shares, then hand the leftover items to the largest
    fractional remainders.  sum(counts) == total always."""
    raw = props * total
    counts = np.floor(raw).astype(int)
    short = total - counts.sum()
    if short > 0:
        order = np.argsort(-(raw - counts))
        counts[order[:short]] += 1
    return counts


def dirichlet_partition(labels: np.ndarray, P: int, K: int,
                        alpha: float = 0.5, seed: int = 0,
                        min_per_client: int = 0):
    """Non-IID label-skew split (Dirichlet over classes per client).

    For every class c, client proportions are drawn from Dirichlet(alpha)
    and the class's indices are allocated by largest-remainder rounding —
    every index is assigned to exactly one client (the old float-cut
    implementation truncated cumulative proportions, which both biased mass
    toward the last clients and could drop/duplicate boundary indices).

    ``min_per_client > 0`` additionally redistributes so every client ends
    with at least that many samples (taken from the richest clients) — a
    population generator cannot sample a minibatch from an empty shard.

    Returns a list-of-lists of index arrays [P][K].
    """
    labels = np.asarray(labels)
    rng = np.random.default_rng(seed)
    n_clients = P * K
    if min_per_client * n_clients > len(labels):
        raise ValueError(
            f"min_per_client={min_per_client} needs at least "
            f"{min_per_client * n_clients} samples, got {len(labels)}")
    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for c in np.unique(labels):
        c_idx = np.nonzero(labels == c)[0]
        rng.shuffle(c_idx)
        props = rng.dirichlet([alpha] * n_clients)
        counts = _largest_remainder_counts(props, len(c_idx))
        stops = np.cumsum(counts)
        for cl, (lo, hi) in enumerate(zip(np.r_[0, stops[:-1]], stops)):
            client_idx[cl].extend(c_idx[lo:hi].tolist())
    assert sum(len(ci) for ci in client_idx) == len(labels)

    if min_per_client > 0:
        # move samples from the richest shards into the starved ones; pop
        # from the tail so donors keep their own class skew at the front
        order = sorted(range(n_clients), key=lambda i: len(client_idx[i]))
        rich = n_clients - 1
        for cl in order:
            while len(client_idx[cl]) < min_per_client:
                while len(client_idx[order[rich]]) <= min_per_client:
                    rich -= 1
                client_idx[cl].append(client_idx[order[rich]].pop())

    return [[np.asarray(client_idx[p * K + k], dtype=np.int64)
             for k in range(K)] for p in range(P)]
