"""Simple sharded-pytree checkpointing (npz + json manifest, no orbax).

Arrays are host-gathered (fine at example scale; per-shard saving would slot
in here for the production path) and stored flat keyed by pytree path.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _sanitize(key: str) -> str:
    return key.replace("/", "·")  # npz entries cannot contain path seps


def save_checkpoint(path: str, tree, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # numpy's npz cannot serialize ml_dtypes (bfloat16 etc.): store the raw
    # bits as uint16/uint8 and record the true dtype in the manifest.
    storable = {}
    for k, v in arrays.items():
        if v.dtype.kind not in "biufc":  # ml_dtypes (bf16/f8) custom kinds
            width = {1: np.uint8, 2: np.uint16, 4: np.uint32}[v.dtype.itemsize]
            storable[_sanitize(k)] = v.view(width)
        else:
            storable[_sanitize(k)] = v
    np.savez(os.path.join(path, "arrays.npz"), **storable)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, like):
    """Restore into the structure of `like` (shape/dtype validated)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten_with_paths(like)
    missing = set(flat_like) - set(manifest["keys"])
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    import ml_dtypes  # jax dependency; provides bfloat16 etc.

    restored = {}
    for k, leaf in flat_like.items():
        arr = data[_sanitize(k)]
        true_dtype = np.dtype(getattr(
            ml_dtypes, manifest["dtypes"][k], None) or manifest["dtypes"][k]) \
            if manifest["dtypes"][k] not in (str(arr.dtype),) else arr.dtype
        if str(arr.dtype) != str(true_dtype):
            arr = arr.view(true_dtype)   # reinterpret stored raw bits
        if tuple(arr.shape) != tuple(jnp.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {k}: ckpt {arr.shape} vs {jnp.shape(leaf)}")
        restored[k] = jnp.asarray(arr, dtype=leaf.dtype if hasattr(leaf, "dtype")
                                  else arr.dtype)
    # rebuild tree in `like`'s structure
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    new_leaves = [restored[p] for p in paths]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["step"]
