"""Simple sharded-pytree checkpointing (npz + json manifest, no orbax).

Arrays are host-gathered (fine at example scale; per-shard saving would slot
in here for the production path) and stored flat keyed by pytree path.

Crash atomicity: a checkpoint directory is never observable half-written.
``save_checkpoint`` stages ``arrays.npz`` + ``manifest.json`` in a temp
sibling directory and publishes it with ``os.replace`` — a reader (or a
restarting fleet worker, repro.core.fleet.worker) sees either the previous
complete checkpoint or the new complete one, never a torn mix.  A process
killed mid-save leaves at most an orphaned ``.tmp-*`` sibling, which the
next save of the same path removes.

Load-side validation is exact-key: a manifest whose key set has extras OR
is missing entries relative to the restore target is rejected with a clear
error — silently dropping stored state is as wrong as silently zero-filling
absent state.  ml_dtypes leaves (bfloat16, float8_*) round-trip bit-exactly:
npz cannot serialize them, so saves store the raw bits as uint8/16/32 views
with the true dtype recorded in the manifest, and loads view the stored
bits back to the manifest-recorded ml_dtype before any cast.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _sanitize(key: str) -> str:
    return key.replace("/", "·")  # npz entries cannot contain path seps


def _true_dtype(name: str) -> np.dtype:
    """Manifest dtype string -> dtype, resolving ml_dtypes names
    (bfloat16, float8_e4m3fn, ...) that plain numpy cannot parse."""
    import ml_dtypes  # jax dependency; provides bfloat16 etc.
    return np.dtype(getattr(ml_dtypes, name, name))


def save_checkpoint(path: str, tree, step: int = 0) -> None:
    """Atomically write ``tree`` as a checkpoint directory at ``path``."""
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # numpy's npz cannot serialize ml_dtypes (bfloat16 etc.): store the raw
    # bits as uint16/uint8 and record the true dtype in the manifest.
    storable = {}
    for k, v in arrays.items():
        if v.dtype.kind not in "biufc":  # ml_dtypes (bf16/f8) custom kinds
            width = {1: np.uint8, 2: np.uint16, 4: np.uint32}[v.dtype.itemsize]
            storable[_sanitize(k)] = v.view(width)
        else:
            storable[_sanitize(k)] = v
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
    }

    # stage in a temp sibling, fsync, then publish with os.replace: a kill
    # mid-save can orphan the .tmp dir but never tear the published path
    path = path.rstrip(os.sep)
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = f"{path}.tmp-{os.getpid()}"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **storable)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if not os.path.exists(path):
            os.replace(tmp, path)        # fully atomic: rename into place
        else:
            # POSIX rename cannot replace a non-empty directory: retire the
            # old checkpoint first (path -> .old, tmp -> path).  A kill in
            # the sub-microsecond window between the two renames leaves NO
            # live path but a COMPLETE .old sibling to recover from —
            # never a torn checkpoint.
            old = f"{path}.old-{os.getpid()}"
            if os.path.isdir(old):
                shutil.rmtree(old)
            os.replace(path, old)
            os.replace(tmp, path)
            shutil.rmtree(old, ignore_errors=True)
    finally:
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


def load_checkpoint(path: str, like):
    """Restore into the structure of `like` (keys/shape/dtype validated)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten_with_paths(like)
    stored = set(manifest["keys"])
    missing = set(flat_like) - stored
    if missing:
        raise ValueError(
            f"checkpoint at {path} is missing keys required by the restore "
            f"target: {sorted(missing)[:5]} "
            f"({len(missing)} missing of {len(flat_like)})")
    extra = stored - set(flat_like)
    if extra:
        raise ValueError(
            f"checkpoint at {path} has keys the restore target does not: "
            f"{sorted(extra)[:5]} ({len(extra)} extra of {len(stored)}); "
            f"refusing to silently drop stored state — restore into a "
            f"matching structure")

    restored = {}
    for k, leaf in flat_like.items():
        arr = data[_sanitize(k)]
        true_dtype = _true_dtype(manifest["dtypes"][k])
        if arr.dtype != true_dtype:
            arr = arr.view(true_dtype)   # uint-stored ml_dtype bits back
        if tuple(arr.shape) != tuple(jnp.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {k}: ckpt {arr.shape} vs {jnp.shape(leaf)}")
        if isinstance(leaf, (np.ndarray, np.generic)):
            # numpy restore target: stay on host at full precision (jax's
            # default x64-off asarray would truncate float64 state — the
            # fleet workers' crash-exactness depends on the bits)
            restored[k] = np.asarray(arr, dtype=leaf.dtype)
        else:
            restored[k] = jnp.asarray(arr, dtype=leaf.dtype
                                      if hasattr(leaf, "dtype") else arr.dtype)
    # rebuild tree in `like`'s structure
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    new_leaves = [restored[p] for p in paths]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["step"]
