"""Pallas TPU kernel: fused graph-homomorphic server combination (eq. 8 + 24).

Computes, for every server p and model-dim block:

    out[p, :] = sum_m A[m, p] * (psi_eff[m, :] + g_hom[m, p, :])
              = (A^T (psi_eff + g))[p, :] - g[p, :]

using the eq.-(24) identity so the [P, P, D] noise tensor is never
materialized: only the per-server Laplace draws ``g`` [P, D] stream through
VMEM alongside ``psi``, and the P x P mixing runs on the MXU per block.

``A`` (transposed) is a runtime operand: per-round effective matrices from
the resilience ``TopologyProcess`` reuse the one compiled program, so
combines inside ``lax.scan`` bodies stay fused.  Optional extensions:

  ``g=None``       noise-free combine (A^T psi) — the ``none`` mechanism;
  ``gate/cache``   the event engine's cached-psi re-announce: per server,
                   ``psi_eff = gate * psi + (1 - gate) * cache`` is computed
                   in-VMEM, so non-flushing servers re-announce their cached
                   psi without a separate [P, D] select pass over HBM.

HBM traffic: 2*P*D reads + P*D writes (vs 3x that for the unfused
psi-gather -> noise-add -> matmul chain), which matters because this pass
streams the ENTIRE parameter space every GFL iteration.

Grid: one program per model-dim tile of size ``block_d``.  P is padded to
the 8-sublane boundary outside the kernel (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(*refs, has_g: bool, has_gate: bool):
    """a_t: [P, P] (=A^T), psi/g/cache/out blocks: [P, block_d],
    gate: [P, 1]."""
    it = iter(refs)
    a_t_ref = next(it)
    psi_ref = next(it)
    g_ref = next(it) if has_g else None
    cache_ref = next(it) if has_gate else None
    gate_ref = next(it) if has_gate else None
    out_ref = next(it)
    a_t = a_t_ref[...]
    psi = psi_ref[...].astype(jnp.float32)
    if has_gate:
        gate = gate_ref[...].astype(jnp.float32)          # [P, 1]
        psi = gate * psi + (1.0 - gate) * cache_ref[...].astype(jnp.float32)
    if has_g:
        g = g_ref[...].astype(jnp.float32)
        mixed = jnp.dot(a_t, psi + g,
                        preferred_element_type=jnp.float32)
        out_ref[...] = (mixed - g).astype(out_ref.dtype)
    else:
        mixed = jnp.dot(a_t, psi, preferred_element_type=jnp.float32)
        out_ref[...] = mixed.astype(out_ref.dtype)


def graph_combine(a_t: jax.Array, psi: jax.Array,
                  g: jax.Array | None = None, *,
                  cache: jax.Array | None = None,
                  gate: jax.Array | None = None,
                  block_d: int = 512, interpret: bool = False
                  ) -> jax.Array:
    """psi, g, cache: [P, D]; a_t: [P, P] (transposed combination matrix);
    gate: [P, 1] float (1 = announce psi, 0 = re-announce cache)."""
    P, D = psi.shape
    assert D % block_d == 0, (D, block_d)
    has_g = g is not None
    has_gate = gate is not None
    if has_gate:
        assert cache is not None, "gate needs a psi cache"
    grid = (D // block_d,)
    in_specs = [
        pl.BlockSpec((P, P), lambda j: (0, 0)),           # A^T resident
        pl.BlockSpec((P, block_d), lambda j: (0, j)),
    ]
    args = [a_t, psi]
    if has_g:
        in_specs.append(pl.BlockSpec((P, block_d), lambda j: (0, j)))
        args.append(g)
    if has_gate:
        in_specs.append(pl.BlockSpec((P, block_d), lambda j: (0, j)))
        in_specs.append(pl.BlockSpec((P, 1), lambda j: (0, 0)))
        args.extend([cache, gate])
    kern = functools.partial(_combine_kernel, has_g=has_g, has_gate=has_gate)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((P, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((P, D), psi.dtype),
        interpret=interpret,
    )(*args)
