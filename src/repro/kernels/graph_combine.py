"""Pallas TPU kernel: fused graph-homomorphic server combination (eq. 8 + 24).

Computes, for every server p and model-dim block:

    out[p, :] = sum_m A[m, p] * (psi[m, :] + g_hom[m, p, :])
              = (A^T (psi + g))[p, :] - g[p, :]

using the eq.-(24) identity so the [P, P, D] noise tensor is never
materialized: only the per-server Laplace draws ``g`` [P, D] stream through
VMEM alongside ``psi``, and the P x P mixing runs on the MXU per block.

HBM traffic: 2*P*D reads + P*D writes (vs 3x that for the unfused
psi-gather -> noise-add -> matmul chain), which matters because this pass
streams the ENTIRE parameter space every GFL iteration.

Grid: one program per model-dim tile of size ``block_d``.  P is padded to
the 8-sublane boundary outside the kernel (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(a_t_ref, psi_ref, g_ref, out_ref):
    """a_t: [P, P] (=A^T), psi/g/out blocks: [P, block_d]."""
    a_t = a_t_ref[...]
    psi = psi_ref[...]
    g = g_ref[...]
    mixed = jnp.dot(a_t, (psi + g).astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    out_ref[...] = (mixed - g.astype(jnp.float32)).astype(out_ref.dtype)


def graph_combine(a_t: jax.Array, psi: jax.Array, g: jax.Array,
                  *, block_d: int = 512, interpret: bool = False
                  ) -> jax.Array:
    """psi, g: [P, D]; a_t: [P, P] (transposed combination matrix)."""
    P, D = psi.shape
    assert D % block_d == 0, (D, block_d)
    grid = (D // block_d,)
    return pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((P, P), lambda j: (0, 0)),       # A^T resident
            pl.BlockSpec((P, block_d), lambda j: (0, j)),
            pl.BlockSpec((P, block_d), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((P, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((P, D), psi.dtype),
        interpret=interpret,
    )(a_t, psi, g)
