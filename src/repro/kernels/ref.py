"""Pure-jnp oracles for every Pallas kernel (bit-compatible semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.secure_agg import pair_stream


def graph_combine_ref(a_t: jax.Array, psi: jax.Array, g: jax.Array
                      ) -> jax.Array:
    """out = A^T (psi + g) - g  (eq. 8 with eq. 24 noise structure)."""
    mixed = (a_t.astype(jnp.float32)
             @ (psi + g).astype(jnp.float32))
    return (mixed - g.astype(jnp.float32)).astype(psi.dtype)


def secure_agg_mean_ref(updates: jax.Array, seed: jax.Array,
                        scale: float = 1.0) -> jax.Array:
    """Masked client mean with the same integer-hash pairwise streams."""
    L, D = updates.shape
    acc = jnp.sum(updates.astype(jnp.float32), axis=0)
    idx = jnp.arange(D, dtype=jnp.uint32)
    pid = 0
    for a in range(L):
        for b in range(a + 1, L):
            s = pair_stream(jnp.uint32(pid), idx, seed[0], scale)
            acc = acc + s - s
            pid += 1
    return (acc / L).astype(updates.dtype)


def laplace_transform_ref(u: jax.Array, sigma: float) -> jax.Array:
    b = sigma / (2.0 ** 0.5)
    uf = u.astype(jnp.float32)
    return (-b * jnp.sign(uf) * jnp.log1p(-2.0 * jnp.abs(uf))).astype(u.dtype)


def clip_accum_ref(grads: jax.Array, bound: float) -> jax.Array:
    g = grads.astype(jnp.float32)
    nrm = jnp.linalg.norm(g, axis=1, keepdims=True)
    coef = jnp.minimum(1.0, bound / jnp.maximum(nrm, 1e-12))
    return jnp.mean(g * coef, axis=0).astype(grads.dtype)


def swa_decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                             nvalid: jax.Array) -> jax.Array:
    """Naive masked decode attention. q: [B,H,Dh]; k,v: [B,C,H,Dh]."""
    Dh = q.shape[-1]
    C = k.shape[1]
    s = jnp.einsum("bhd,bchd->bhc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (Dh ** 0.5)
    s = jnp.where(jnp.arange(C)[None, None, :] < nvalid[0], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhc,bchd->bhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
