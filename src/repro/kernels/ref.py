"""Pure-jnp oracles for every Pallas kernel (bit-compatible semantics).

These are also the ``backend="ref"`` implementations of the dispatch layer
in :mod:`repro.kernels.ops`: the same one-pass *algorithms* expressed as a
single fused jnp computation, so the CPU path gets the fusion win from XLA
while the Pallas path realizes it explicitly on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.secure_agg import pair_stream


def graph_combine_ref(a_t: jax.Array, psi: jax.Array, g: jax.Array
                      ) -> jax.Array:
    """out = A^T (psi + g) - g  (eq. 8 with eq. 24 noise structure)."""
    mixed = (a_t.astype(jnp.float32)
             @ (psi + g).astype(jnp.float32))
    return (mixed - g.astype(jnp.float32)).astype(psi.dtype)


def secure_agg_mean_ref(updates: jax.Array, seed: jax.Array,
                        scale: float = 1.0) -> jax.Array:
    """Masked client mean with the same integer-hash pairwise streams."""
    L, D = updates.shape
    acc = jnp.sum(updates.astype(jnp.float32), axis=0)
    idx = jnp.arange(D, dtype=jnp.uint32)
    pid = 0
    for a in range(L):
        for b in range(a + 1, L):
            s = pair_stream(jnp.uint32(pid), idx, seed[0], scale)
            acc = acc + s - s
            pid += 1
    return (acc / L).astype(updates.dtype)


def laplace_transform_ref(u: jax.Array, sigma: float) -> jax.Array:
    b = sigma / (2.0 ** 0.5)
    uf = u.astype(jnp.float32)
    return (-b * jnp.sign(uf) * jnp.log1p(-2.0 * jnp.abs(uf))).astype(u.dtype)


def clip_accum_ref(grads: jax.Array, bound: float) -> jax.Array:
    g = grads.astype(jnp.float32)
    nrm = jnp.linalg.norm(g, axis=1, keepdims=True)
    coef = jnp.minimum(1.0, bound / jnp.maximum(nrm, 1e-12))
    return jnp.mean(g * coef, axis=0).astype(grads.dtype)


def hash_net_mask_fold(seed: jax.Array, noise_w: jax.Array, D: int,
                       scale: float) -> jax.Array:
    """One server's folded net pairwise hash-stream masks
    ``sum_k noise_w[k] * mask_k`` -> [D].

    Same counter-hash streams, pair enumeration and O(L) per-owner
    accumulation as the in-kernel path
    (:func:`~repro.kernels.secure_agg.net_mask_stream` inside a
    ``fori_loop``): peak memory is one [L, D] stream block, never the
    [L, L, D] pair tensor.  Because each alive pair's stream enters two
    owners' nets with opposite signs and the same (survivor-uniform)
    weight, the fold term cancels exactly in real arithmetic (eq. 23).
    """
    from repro.kernels.secure_agg import net_mask_stream
    L = noise_w.shape[0]
    idx = jnp.arange(D, dtype=jnp.uint32)[None, :]            # [1, D]
    alive = noise_w > 0

    def fold_owner(k, acc):
        m = net_mask_stream(k, idx, seed, scale, L, alive)    # [1, D]
        return acc + noise_w[k] * m[0]

    return jax.lax.fori_loop(0, L, fold_owner,
                             jnp.zeros((D,), jnp.float32))


def round_fold_ref(w: jax.Array, grads: jax.Array, *, mu: float,
                   bound: float, pre_w: jax.Array, fold_w: jax.Array,
                   noise_w: jax.Array, mode: str = "none",
                   sigma: float = 0.0, seeds: jax.Array | None = None,
                   noise: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Fused round-fold oracle: clip -> update -> privatize -> fold.

    w: [P, D] or [P, L, D]; grads: [P, L, D]; pre_w / fold_w / noise_w:
    [P, L].  Returns (psi [P, D], sq [P, L] raw squared grad norms) — the
    same contract as :func:`repro.kernels.ops.round_fold`.
    """
    P, L, D = grads.shape
    g32 = grads.astype(jnp.float32)
    sq = jnp.sum(g32 * g32, axis=-1)                          # [P, L]
    pre = pre_w.astype(jnp.float32)
    nrm = pre * jnp.sqrt(sq)
    if bound > 0:
        coef = jnp.minimum(1.0, bound / jnp.maximum(nrm, 1e-12))
    else:
        coef = jnp.ones_like(nrm)
    ss = mu * coef * pre                                      # [P, L]
    wb = w.astype(jnp.float32)
    if w.ndim == 2:
        wb = wb[:, None, :]
    upd = wb - ss[..., None] * g32                            # [P, L, D]
    fw = fold_w.astype(jnp.float32)
    fwn = fw / jnp.maximum(fw.sum(axis=1, keepdims=True), 1e-12)
    psi = jnp.sum(fwn[..., None] * upd, axis=1)               # [P, D]
    nw = noise_w.astype(jnp.float32)
    if mode == "laplace":
        psi = psi + jnp.sum(nw[..., None] * noise.astype(jnp.float32),
                            axis=1)
    elif mode == "mask":
        psi = psi + jax.vmap(
            lambda sd, nw_p: hash_net_mask_fold(sd, nw_p, D, sigma)
        )(seeds, nw)                                          # [P, D]
    else:
        assert mode == "none", mode
    return psi.astype(w.dtype), sq


def swa_decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                             nvalid: jax.Array) -> jax.Array:
    """Naive masked decode attention. q: [B,H,Dh]; k,v: [B,C,H,Dh]."""
    Dh = q.shape[-1]
    C = k.shape[1]
    s = jnp.einsum("bhd,bchd->bhc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (Dh ** 0.5)
    s = jnp.where(jnp.arange(C)[None, None, :] < nvalid[0], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhc,bchd->bhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
