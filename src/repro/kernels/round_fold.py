"""Pallas TPU kernel: fused GFL round fold — clip -> update -> privatize -> fold.

The per-iteration client work of the protocol (eqs. 6-7, with eq. 23 masks or
iid noise) is a pure streaming pass over the whole ``[P, L, D]`` gradient
tensor, yet the reference chain runs it as 4-6 separate XLA ops that re-read
the tensor from HBM each time (norms, scale, update, noise add, fold).  This
kernel computes, per server p and model-dim tile,

    coef_k = min(1, B / max(pre_w_k * ||grad_k||, eps))          (clip, eq. 14)
    upd_k  = w_[p|p,k] - mu * coef_k * pre_w_k * grad_k          (update, eq. 6)
    psi_p  = sum_k fold_wn_k * upd_k  +  noise term              (fold, eq. 7)

in TWO HBM passes over the gradients: a norms pass and a scale/noise/fold
pass (the tiny ``[P, L]`` clip/weight math in between runs on host-shaped
arrays).  The composed weight vector — PR 3's ``1/(K pi)`` importance
weights (``pre_w``, applied BEFORE the sensitivity clip), PR 4's
``1/(1+age)^alpha`` staleness weights and alive masks (``fold_wn``,
normalized fold weights) — makes the same kernel serve the dense
``_client_updates``, ``run_gfl_population``'s weighted executor and the
event engine's buffered ``weighted_fold``.

Noise modes (the mechanism's client level):
  ``none``     plain weighted fold;
  ``mask``     in-kernel counter-hash pairwise secure-agg streams
               (:func:`~repro.kernels.secure_agg.net_mask_stream`),
               restricted to alive pairs, entering with the survivor-mean
               weight ``noise_w`` — exact cancellation in the fold;
  ``laplace``  a pre-drawn ``[P, L, D]`` noise tensor streamed once and
               folded with ``noise_w`` (the iid_dp path keeps the reference
               sampler's draws bit-for-bit, so backend parity is tight).

Per-client base models (``w`` of shape [P, L, D], the event engine's stale
snapshots) are supported by a static variant flag.

Use :func:`repro.kernels.ops.round_fold` — it handles tile padding, block
autotuning and the ref-jnp backend; this module is the raw kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.secure_agg import net_mask_stream


def _norms_kernel(g_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[0].astype(jnp.float32)                      # [L, bd]
    out_ref[...] += jnp.sum(g * g, axis=1)[None, :]


def fold_norms(grads: jax.Array, *, block_d: int = 512,
               interpret: bool = False) -> jax.Array:
    """Phase 1: per-(server, client) squared gradient norms.

    grads: [P, L, D] -> [P, L] float32 (one HBM read of the gradients;
    the grid revisits each server's [1, L] output across model-dim tiles).
    """
    P, L, D = grads.shape
    assert D % block_d == 0, (D, block_d)
    return pl.pallas_call(
        _norms_kernel,
        grid=(P, D // block_d),
        in_specs=[pl.BlockSpec((1, L, block_d), lambda p, j: (p, 0, j))],
        out_specs=pl.BlockSpec((1, L), lambda p, j: (p, 0)),
        out_shape=jax.ShapeDtypeStruct((P, L), jnp.float32),
        interpret=interpret,
    )(grads)


def _fold_kernel(*refs, mode: str, sigma: float, L: int, block_d: int,
                 per_client_base: bool):
    w_ref, g_ref, ss_ref, fw_ref, nw_ref = refs[:5]
    out_ref = refs[-1]
    g = g_ref[0].astype(jnp.float32)                      # [L, bd]
    ss = ss_ref[...].astype(jnp.float32)[0]               # [L]
    fw = fw_ref[...].astype(jnp.float32)[0]               # [L]
    nw = nw_ref[...].astype(jnp.float32)[0]               # [L]
    if per_client_base:
        wb = w_ref[0].astype(jnp.float32)                 # [L, bd]
    else:
        wb = w_ref[...].astype(jnp.float32)               # [1, bd] broadcasts
    upd = wb - ss[:, None] * g                            # [L, bd]
    acc = jnp.sum(fw[:, None] * upd, axis=0, keepdims=True)   # [1, bd]
    if mode == "laplace":
        nz = refs[5][0].astype(jnp.float32)               # [L, bd]
        acc = acc + jnp.sum(nw[:, None] * nz, axis=0, keepdims=True)
    elif mode == "mask":
        # per-server seed arrives as this program's own (1, 1) SMEM block
        # (statically indexed — a dynamically-indexed ANY ref would not
        # lower on TPU)
        seed_ref = refs[5]
        j = pl.program_id(1)
        seed = seed_ref[0, 0]
        idx = (j * block_d
               + jax.lax.broadcasted_iota(jnp.uint32, (1, block_d), 1))
        alive = nw > 0
        # each alive pair's stream enters the fold twice with opposite signs
        # and the same survivor-mean weight -> exact cancellation (eq. 23);
        # O(L) fori_loop, body vectorized over peers (compile-flat in L)
        def fold_client(k, a):
            m = net_mask_stream(k, idx, seed, sigma, L, alive)
            return a + nw[k] * m

        acc = jax.lax.fori_loop(0, L, fold_client, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


def fold_apply(w: jax.Array, grads: jax.Array, stepscale: jax.Array,
               fold_wn: jax.Array, noise_w: jax.Array, *,
               mode: str = "none", sigma: float = 0.0,
               seeds: jax.Array | None = None,
               noise: jax.Array | None = None,
               block_d: int = 512, interpret: bool = False) -> jax.Array:
    """Phase 2: fused scale/update/privatize/fold.

    w: [P, D] (shared base) or [P, L, D] (per-client stale bases);
    grads: [P, L, D]; stepscale = mu * clip_coef * pre_w, fold_wn =
    normalized fold weights, noise_w = per-client noise/mask fold weight
    (all [P, L]).  mode "mask" needs ``seeds`` [P] uint32; mode "laplace"
    needs ``noise`` [P, L, D].  Returns psi [P, D] in w.dtype.
    """
    P, L, D = grads.shape
    assert D % block_d == 0, (D, block_d)
    per_client_base = w.ndim == 3
    if per_client_base:
        w_spec = pl.BlockSpec((1, L, block_d), lambda p, j: (p, 0, j))
    else:
        w_spec = pl.BlockSpec((1, block_d), lambda p, j: (p, j))
    in_specs = [
        w_spec,
        pl.BlockSpec((1, L, block_d), lambda p, j: (p, 0, j)),
        pl.BlockSpec((1, L), lambda p, j: (p, 0)),
        pl.BlockSpec((1, L), lambda p, j: (p, 0)),
        pl.BlockSpec((1, L), lambda p, j: (p, 0)),
    ]
    args = [w, grads, stepscale, fold_wn, noise_w]
    if mode == "mask":
        assert seeds is not None, "mask mode needs per-server seeds [P]"
        in_specs.append(pl.BlockSpec((1, 1), lambda p, j: (p, 0),
                                     memory_space=pltpu.SMEM))
        args.append(seeds.astype(jnp.uint32).reshape(P, 1))
    elif mode == "laplace":
        assert noise is not None, "laplace mode needs pre-drawn noise [P,L,D]"
        in_specs.append(pl.BlockSpec((1, L, block_d), lambda p, j: (p, 0, j)))
        args.append(noise)
    else:
        assert mode == "none", mode
    kern = functools.partial(_fold_kernel, mode=mode, sigma=float(sigma),
                             L=L, block_d=block_d,
                             per_client_base=per_client_base)
    return pl.pallas_call(
        kern,
        grid=(P, D // block_d),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_d), lambda p, j: (p, j)),
        out_shape=jax.ShapeDtypeStruct((P, D), w.dtype),
        interpret=interpret,
    )(*args)
