# The fused round-pipeline kernel layer (docs/kernels.md):
#   <name>.py        raw Pallas kernels (round_fold, graph_combine,
#                    secure_agg, clip_accum, laplace, swa_decode)
#   ref.py           pure-jnp oracles / the "ref" backend
#   ops.py           padding + block autotuning + backend dispatch — the
#                    ONLY entry point engines use (GFLConfig.use_kernels)
