"""Pallas TPU kernel: single-token decode attention over a (ring) KV cache.

The decode hot path at 32k-500k context: one query per sequence against C
cached slots, with slot-validity masking (ring buffers expose min(pos+1, C)
valid slots).  Flash-style online softmax: the cache is streamed through
VMEM in `block_c` tiles; running (max, denom, weighted-V) state lives in the
output refs, which every grid step revisits — the [C] score vector never
exists in HBM.

Layout: q [B, H, Dh]; k/v [B, C, H, Dh] (GQA grouping resolved by the
wrapper via repeat of KV heads, keeping the kernel MXU-shaped).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _swa_decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref,
                       *, block_c: int, scale: float):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)            # [B, H, Dh]
    k = k_ref[...].astype(jnp.float32)            # [B, bc, H, Dh]
    v = v_ref[...].astype(jnp.float32)
    nvalid = valid_ref[0]                         # scalar int32

    s = jnp.einsum("bhd,bchd->bhc", q, k) * scale  # [B, H, bc]
    slot = j * block_c + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, dimension=2)
    s = jnp.where(slot < nvalid, s, NEG_INF)

    m_prev = m_ref[...]                           # [B, H]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=2))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])             # [B, H, bc]
    l_new = l_prev * alpha + p.sum(axis=2)
    o_prev = o_ref[...].astype(jnp.float32)
    o_new = o_prev * alpha[..., None] + jnp.einsum("bhc,bchd->bhd", p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new
    o_ref[...] = o_new.astype(o_ref.dtype)


def swa_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         nvalid: jax.Array, *, block_c: int = 256,
                         interpret: bool = False) -> jax.Array:
    """q: [B,H,Dh]; k,v: [B,C,H,Dh]; nvalid: [1] int32 -> out [B,H,Dh]."""
    B, H, Dh = q.shape
    C = k.shape[1]
    block_c = min(block_c, C)
    while C % block_c:
        block_c //= 2
    scale = 1.0 / (Dh ** 0.5)
    kern = functools.partial(_swa_decode_kernel, block_c=block_c, scale=scale)
    o, m, l = pl.pallas_call(
        kern,
        grid=(C // block_c,),
        in_specs=[
            pl.BlockSpec((B, H, Dh), lambda j: (0, 0, 0)),
            pl.BlockSpec((B, block_c, H, Dh), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((B, block_c, H, Dh), lambda j: (0, j, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((B, H, Dh), lambda j: (0, 0, 0)),
            pl.BlockSpec((B, H), lambda j: (0, 0)),
            pl.BlockSpec((B, H), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, nvalid)
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
