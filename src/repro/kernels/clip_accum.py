"""Pallas TPU kernels: per-client gradient clipping to bound B + accumulate.

Enforces Assumption 3 (||grad|| <= B, eq. 14) the way DP-SGD does: project
each client's gradient onto the B-ball, then average.  Two-phase grid:

  phase 1  per-client squared norms, accumulated across model-dim tiles
           (grid revisits the [L] output block; first visit zero-inits);
  phase 2  scale-and-mean, streaming the gradients a second time with the
           norms resident in VMEM.

2*L*D reads + D writes total; the naive chain (norms, scale, mean as three
XLA ops) re-reads the gradient tensor three times.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sumsq_kernel(g_ref, out_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.sum(g * g, axis=1, keepdims=True)


def _scale_mean_kernel(g_ref, norms_ref, out_ref, *, bound: float, L: int):
    g = g_ref[...].astype(jnp.float32)                     # [L, bd]
    nrm = jnp.sqrt(norms_ref[...])                         # [L, 1]
    coef = jnp.minimum(1.0, bound / jnp.maximum(nrm, 1e-12))
    out_ref[...] = (jnp.sum(g * coef, axis=0, keepdims=True) / L
                    ).astype(out_ref.dtype)


def clip_accum(grads: jax.Array, bound: float, *, block_d: int = 512,
               interpret: bool = False) -> jax.Array:
    """grads: [L, D] per-client gradients -> clipped mean [D]."""
    L, D = grads.shape
    assert D % block_d == 0, (D, block_d)
    grid = (D // block_d,)
    norms = pl.pallas_call(
        _sumsq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((L, block_d), lambda j: (0, j))],
        out_specs=pl.BlockSpec((L, 1), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, 1), jnp.float32),
        interpret=interpret,
    )(grads)
    kern = functools.partial(_scale_mean_kernel, bound=float(bound), L=L)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L, block_d), lambda j: (0, j)),
            pl.BlockSpec((L, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, D), grads.dtype),
        interpret=interpret,
    )(grads, norms)
    return out[0]
