"""Pallas TPU kernel: fused secure-aggregation masked client mean (eq. 7 + 23).

Server aggregation with Bonawitz-style pairwise masks, with the masks
generated IN-KERNEL from a counter-based integer hash (xorshift-mix of
(pair_id, feature_index, round_seed)) instead of being materialized in HBM.
For L clients the [L, D] mask tensor never exists: each grid step
regenerates its block of every pairwise stream in VMEM and accumulates

    out[:] = (1/L) sum_k (upd[k, :] + mask_k[:]),
    mask_k = sum_{j<k} -PRG(j,k) + sum_{j>k} +PRG(k,j)

Because each pair's stream enters twice with opposite signs, the kernel's
output equals the plain client mean bit-for-bit in exact arithmetic, and to
float-add reordering in practice — asserted against ref.py in tests.

HBM traffic: L*D reads + D writes (the mask tensor would add 2*L*D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hash_u32(x: jax.Array) -> jax.Array:
    """xorshift-multiply mix (Murmur3 finalizer) on uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def pair_stream(pair_id: jax.Array, idx: jax.Array, seed: jax.Array,
                scale: float) -> jax.Array:
    """Uniform(-scale, scale) stream for one client pair at feature idx."""
    h = _hash_u32(idx.astype(jnp.uint32)
                  ^ _hash_u32(jnp.uint32(pair_id) * jnp.uint32(0x9E3779B9)
                              + jnp.uint32(seed)))
    # top 24 bits -> uniform in [0,1) with exact float32 representation
    u = (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    return (2.0 * u - 1.0) * scale


def _secure_agg_kernel(upd_ref, seed_ref, out_ref, *, L: int, scale: float,
                       block_d: int):
    j = pl.program_id(0)
    seed = seed_ref[0]
    idx = j * block_d + jax.lax.broadcasted_iota(jnp.uint32, (1, block_d), 1)
    acc = jnp.sum(upd_ref[...].astype(jnp.float32), axis=0, keepdims=True)
    # pairwise masks: pair (a, b) adds +stream to a, -stream to b; the net
    # effect on the SUM is zero, so we inject them in +/- pairs to mirror
    # exactly what the distributed protocol computes (and its float error).
    pid = 0
    for a in range(L):
        for b in range(a + 1, L):
            s = pair_stream(jnp.uint32(pid), idx, seed, scale)
            acc = acc + s            # client a's mask contribution
            acc = acc - s            # client b's
            pid += 1
    out_ref[...] = (acc / L).astype(out_ref.dtype)


def secure_agg_mean(updates: jax.Array, seed: jax.Array, *, scale: float = 1.0,
                    block_d: int = 512, interpret: bool = False) -> jax.Array:
    """updates: [L, D] -> masked mean [D]. seed: uint32 scalar array [1]."""
    L, D = updates.shape
    assert D % block_d == 0, (D, block_d)
    import functools
    kern = functools.partial(_secure_agg_kernel, L=L, scale=scale,
                             block_d=block_d)
    out = pl.pallas_call(
        kern,
        grid=(D // block_d,),
        in_specs=[
            pl.BlockSpec((L, block_d), lambda j: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, D), updates.dtype),
        interpret=interpret,
    )(updates, seed)
    return out[0]
