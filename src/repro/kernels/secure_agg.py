"""Pallas TPU kernel: fused secure-aggregation masked client mean (eq. 7 + 23).

Server aggregation with Bonawitz-style pairwise masks, with the masks
generated IN-KERNEL from a counter-based integer hash (xorshift-mix of
(pair_id, feature_index, round_seed)) instead of being materialized in HBM.
For L clients the [L, D] mask tensor never exists: each grid step
regenerates its block of every pairwise stream in VMEM and accumulates

    out[:] = (1/L) sum_k (upd[k, :] + mask_k[:]),
    mask_k = sum_{j<k} -PRG(j,k) + sum_{j>k} +PRG(k,j)

Because each pair's stream enters the sum twice with opposite signs (once in
each endpoint's net mask), the kernel's output equals the plain client mean
bit-for-bit in exact arithmetic, and to float-add reordering in practice —
asserted against ref.py in tests.

The per-client net masks are accumulated by an O(L) ``fori_loop`` whose body
evaluates all of client k's pair streams at once (:func:`net_mask_stream`),
so trace/compile time and program size are FLAT in the cohort size L — the
previous unrolled double python loop emitted all L(L-1)/2 pair streams as
separate graph nodes (2016 streams at L=64), which made compile time
quadratic in L.  Runtime stream work is unchanged.

HBM traffic: L*D reads + D writes (the mask tensor would add 2*L*D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hash_u32(x: jax.Array) -> jax.Array:
    """xorshift-multiply mix (Murmur3 finalizer) on uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def pair_stream(pair_id: jax.Array, idx: jax.Array, seed: jax.Array,
                scale: float) -> jax.Array:
    """Uniform(-scale, scale) stream for one client pair at feature idx.

    ``pair_id`` may be a scalar or an integer array (it broadcasts against
    ``idx``), which is what lets :func:`net_mask_stream` evaluate all of one
    client's pair streams in a single vectorized expression."""
    pid = jnp.asarray(pair_id).astype(jnp.uint32)
    h = _hash_u32(idx.astype(jnp.uint32)
                  ^ _hash_u32(pid * jnp.uint32(0x9E3779B9)
                              + jnp.uint32(seed)))
    # top 24 bits -> uniform in [0,1) with exact float32 representation
    u = (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    return (2.0 * u - 1.0) * scale


def net_mask_stream(k: jax.Array, idx: jax.Array, seed: jax.Array,
                    scale: float, L: int,
                    alive: jax.Array | None = None) -> jax.Array:
    """Client k's NET pairwise mask at feature block ``idx`` ([1, bd]).

    mask_k = sum_{j>k} +PRG(pair(k,j)) + sum_{j<k} -PRG(pair(j,k)),
    optionally restricted to pairs whose peer j is alive (``alive`` [L]
    bool) — dead peers' streams never arrive, matching the Bonawitz
    orphan-repair semantics of the reference path.

    Vectorized over all L peers, so a ``fori_loop`` over k costs O(1) trace
    size; the pair enumeration matches the row-major (a < b) ordering of
    the reference double loop (pair (a, b) has id
    ``a*(2L-a-1)/2 + (b-a-1)``).
    """
    j = jax.lax.broadcasted_iota(jnp.int32, (L, 1), 0)
    k = jnp.asarray(k, jnp.int32)
    a = jnp.minimum(j, k)
    b = jnp.maximum(j, k)
    pid = (a * (2 * L - a - 1)) // 2 + (b - a - 1)            # [L, 1]
    s = pair_stream(pid, idx, seed, scale)                    # [L, bd]
    sgn = jnp.where(j > k, jnp.float32(1.0), jnp.float32(-1.0))
    m = jnp.where(j == k, 0.0, sgn) * s
    if alive is not None:
        m = jnp.where(alive[:, None], m, 0.0)
    return jnp.sum(m, axis=0, keepdims=True)                  # [1, bd]


def _secure_agg_kernel(upd_ref, seed_ref, out_ref, *, L: int, scale: float,
                       block_d: int):
    j = pl.program_id(0)
    seed = seed_ref[0]
    idx = j * block_d + jax.lax.broadcasted_iota(jnp.uint32, (1, block_d), 1)
    acc = jnp.sum(upd_ref[...].astype(jnp.float32), axis=0, keepdims=True)
    # pairwise masks: each pair's stream enters the sum twice with opposite
    # signs (through both endpoints' net masks), so the net effect on the
    # SUM is zero — mirroring exactly what the distributed protocol
    # computes.  O(L) fori_loop over clients, each body vectorized over the
    # client's L-1 peer streams: trace/compile cost flat in L.
    def fold_client(k, a):
        return a + net_mask_stream(k, idx, seed, scale, L)

    acc = jax.lax.fori_loop(0, L, fold_client, acc)
    out_ref[...] = (acc / L).astype(out_ref.dtype)


def secure_agg_mean(updates: jax.Array, seed: jax.Array, *, scale: float = 1.0,
                    block_d: int = 512, interpret: bool = False) -> jax.Array:
    """updates: [L, D] -> masked mean [D]. seed: uint32 scalar array [1]."""
    L, D = updates.shape
    assert D % block_d == 0, (D, block_d)
    import functools
    kern = functools.partial(_secure_agg_kernel, L=L, scale=scale,
                             block_d=block_d)
    out = pl.pallas_call(
        kern,
        grid=(D // block_d,),
        in_specs=[
            pl.BlockSpec((L, block_d), lambda j: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, D), updates.dtype),
        interpret=interpret,
    )(updates, seed)
    return out[0]
