"""jit'd public wrappers around the Pallas kernels.

Handle padding to TPU tile boundaries ((8, 128) for f32) and fall back to
interpret mode automatically on CPU so the same call sites work in tests,
the simulator, and on real TPUs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import clip_accum as _clip
from repro.kernels import graph_combine as _combine
from repro.kernels import laplace as _laplace
from repro.kernels import secure_agg as _sagg


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_last(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    d = x.shape[-1]
    pad = (-d) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, d


def _pad_first(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, n


def _block_for(d: int, want: int = 512) -> int:
    b = min(want, d)
    while d % b:
        b //= 2
    return max(b, 1)


@partial(jax.jit, static_argnames=("interpret",))
def graph_combine(A: jax.Array, psi: jax.Array, g: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """Fused server combination: [P,D], [P,D] -> [P,D]."""
    interpret = _on_cpu() if interpret is None else interpret
    a_t = jnp.asarray(A).T
    psi_p, D = _pad_last(psi, 128)
    g_p, _ = _pad_last(g, 128)
    psi_p, P = _pad_first(psi_p, 8)
    g_p, _ = _pad_first(g_p, 8)
    a_pad = jnp.zeros((psi_p.shape[0], psi_p.shape[0]), a_t.dtype)
    a_pad = a_pad.at[:P, :P].set(a_t)
    # padded servers get g=0 rows already; diag term subtracts their own g=0
    out = _combine.graph_combine(a_pad, psi_p, g_p,
                                 block_d=_block_for(psi_p.shape[1]),
                                 interpret=interpret)
    return out[:P, :D]


@partial(jax.jit, static_argnames=("scale", "interpret"))
def secure_agg_mean(updates: jax.Array, seed: jax.Array, scale: float = 1.0,
                    interpret: bool | None = None) -> jax.Array:
    """Masked client mean: [L,D] -> [D]."""
    interpret = _on_cpu() if interpret is None else interpret
    upd, D = _pad_last(updates, 128)
    out = _sagg.secure_agg_mean(upd, jnp.atleast_1d(seed).astype(jnp.uint32),
                                scale=scale,
                                block_d=_block_for(upd.shape[1]),
                                interpret=interpret)
    return out[:D]


@partial(jax.jit, static_argnames=("sigma", "interpret"))
def laplace_transform(u: jax.Array, sigma: float,
                      interpret: bool | None = None) -> jax.Array:
    """Uniform (-1/2,1/2) -> Lap(0, sigma/sqrt 2): [P,D] -> [P,D]."""
    interpret = _on_cpu() if interpret is None else interpret
    up, D = _pad_last(u, 128)
    up, P = _pad_first(up, 8)
    out = _laplace.laplace_transform(up, sigma,
                                     block_d=_block_for(up.shape[1]),
                                     interpret=interpret)
    return out[:P, :D]


@partial(jax.jit, static_argnames=("bound", "interpret"))
def clip_accum(grads: jax.Array, bound: float,
               interpret: bool | None = None) -> jax.Array:
    """Per-client clip to B + mean: [L,D] -> [D]."""
    interpret = _on_cpu() if interpret is None else interpret
    g, D = _pad_last(grads, 128)
    out = _clip.clip_accum(g, bound, block_d=_block_for(g.shape[1]),
                           interpret=interpret)
    return out[:D]


@partial(jax.jit, static_argnames=("interpret",))
def swa_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         nvalid: jax.Array,
                         interpret: bool | None = None) -> jax.Array:
    """Flash-style decode attention vs a (ring) KV cache.

    q: [B,H,Dh]; k,v: [B,C,KVH,Dh] (KV heads repeated to H by the caller or
    here when KVH divides H); nvalid: [1] int32 valid-slot count."""
    from repro.kernels import swa_decode as _swa
    interpret = _on_cpu() if interpret is None else interpret
    B, H, Dh = q.shape
    kvh = k.shape[2]
    if kvh != H:
        rep = H // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return _swa.swa_decode_attention(q, k, v,
                                     jnp.atleast_1d(nvalid).astype(jnp.int32),
                                     interpret=interpret)
