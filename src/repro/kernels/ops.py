"""Backend-dispatch layer over the Pallas kernels (ref-jnp vs Pallas).

This is the ONE place the engines touch the kernel layer: ``core/gfl.py``,
``core/population/engine.py``, ``core/events/engine.py`` and
``launch/steps.py`` all call these wrappers, so ``GFLConfig.use_kernels``
is a whole-run switch (the engines route through here when it is set)
instead of a mechanism-internal detail.  Every op takes

  ``backend``   "pallas" (default) or "ref" — the pure-jnp oracle from
                :mod:`repro.kernels.ref`, same contract, same one-pass
                algorithm, used for parity tests and CPU-side fusion;
  ``interpret`` None (auto: interpret mode on CPU so the same call sites
                work in tests, the simulator and on real TPUs) or explicit.

Padding: inputs are padded UP to the model-dim tile boundary and sliced
back — the old ``_block_for`` heuristic shrank the block until it divided D,
which collapsed to pathological 1-wide grids for odd/prime D; now the block
is always a 128-multiple and D pads to it (regression-tested on D=509).

Block autotuning: ``block_d`` candidates {128, 256, 512, 1024} that tile
the padded model dim are timed once per (op, shape, dtype) and the winner
is cached for the process (``choose_block``); set ``REPRO_KERNEL_AUTOTUNE=0``
to skip timing and take the largest candidate.  Timing runs eagerly on
dummy zeros at trace time, so jitted callers autotune exactly once per
shape.
"""
from __future__ import annotations

import os
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import clip_accum as _clip
from repro.kernels import graph_combine as _combine
from repro.kernels import laplace as _laplace
from repro.kernels import ref as _ref
from repro.kernels import round_fold as _rf
from repro.kernels import secure_agg as _sagg

BACKENDS = ("pallas", "ref")
_BLOCK_CANDIDATES = (128, 256, 512, 1024)
_AUTOTUNE_CACHE: dict = {}


def _emit_kernel(**values) -> None:
    """Host-side dispatch record onto the ``kernel`` telemetry stream.

    Runs at TRACE time (dispatch decisions are host logic), so nothing is
    ever inserted into the kernels' process-lifetime jit caches — a
    record fires once per newly-traced (op, shape), only while a
    telemetry session is active."""
    from repro.telemetry import current_session, emit
    sess = current_session()
    if sess is None:
        return
    emit("kernel", {"seq": sess.next_seq(), **values})


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _resolve(backend: str | None, interpret: bool | None):
    # default backend: "pallas" (interpret mode on CPU keeps the kernels
    # exercised by tier-1); REPRO_KERNEL_BACKEND=ref flips whole-run CPU
    # jobs onto the fused jnp oracles — same one-pass pipeline, XLA-fused,
    # much faster than interpreting Pallas on the host
    backend = backend or os.environ.get("REPRO_KERNEL_BACKEND", "pallas")
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    if interpret is None:
        interpret = _on_cpu()
    return backend, interpret


def _pad_last(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    d = x.shape[-1]
    pad = (-d) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, d


def _pad_first(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, n


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def block_candidates(d: int) -> tuple[list[int], int]:
    """(candidate block_d list, padded model dim) for a last dim of d.

    The padded dim is the 128-tile round-up; candidates are the standard
    tile multiples that divide it, so the grid is never pathological
    (the old ``_block_for`` returned block_d=1 for odd D > 512)."""
    d_pad = max(d, 1) + (-max(d, 1)) % 128
    cands = [c for c in _BLOCK_CANDIDATES if c <= d_pad and d_pad % c == 0]
    return (cands or [d_pad]), d_pad


def clear_autotune_cache() -> None:
    _AUTOTUNE_CACHE.clear()


def apply_gate(psi: jax.Array, gate: jax.Array | None,
               cache: jax.Array | None) -> jax.Array:
    """The cached-psi re-announce select (gated-off servers contribute
    ``cache``) — the jnp realization of what the gated combine kernel does
    in VMEM.  ``gate=None`` is the ungated identity."""
    if gate is None:
        return psi
    return jnp.where(jnp.asarray(gate).astype(bool)[:, None], psi, cache)


def choose_block(op: str, d: int, *, shape_key: tuple = (),
                 make_timed=None, interpret: bool = False
                 ) -> tuple[int, int]:
    """Pick (block_d, padded D) for op on a last dim of d.

    When more than one candidate tiles the padded dim and ``make_timed``
    is given (``make_timed(block_d, d_pad) -> zero-arg callable`` running
    the kernel on dummy data), each candidate is timed once — warmup call
    then one measured call — and the winner is cached per
    ``(op, d_pad, interpret, *shape_key)`` for the process lifetime."""
    from repro.telemetry import trace_span
    cands, d_pad = block_candidates(d)
    key = (op, d_pad, interpret) + tuple(shape_key)
    if key in _AUTOTUNE_CACHE:
        return _AUTOTUNE_CACHE[key], d_pad
    autotuned = False
    if (len(cands) == 1 or make_timed is None
            or os.environ.get("REPRO_KERNEL_AUTOTUNE", "1") == "0"):
        block = cands[-1]
    else:
        autotuned = True
        best = (float("inf"), cands[-1])
        with trace_span(f"autotune:{op}", d_pad=d_pad,
                        candidates=len(cands)):
            for c in cands:
                try:
                    fn = make_timed(c, d_pad)
                    jax.block_until_ready(fn())      # compile + warmup
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn())
                    best = min(best, (time.perf_counter() - t0, c))
                except Exception:                    # candidate infeasible
                    continue
        block = best[1]
    _AUTOTUNE_CACHE[key] = block
    _emit_kernel(op=op, backend="pallas", block_d=block, d_pad=d_pad,
                 interpret=int(interpret), autotuned=int(autotuned))
    return block, d_pad


# ---------------------------------------------------------------------------
# fused round fold (clip -> update -> privatize -> fold), eqs. 6-7 + 23
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("mu", "bound", "mode", "sigma",
                                   "backend", "interpret"))
def round_fold(w: jax.Array, grads: jax.Array, *, mu: float, bound: float,
               pre_w: jax.Array | None = None,
               fold_w: jax.Array | None = None,
               noise_w: jax.Array | None = None,
               mode: str = "none", sigma: float = 0.0,
               seeds: jax.Array | None = None,
               noise: jax.Array | None = None,
               backend: str | None = None,
               interpret: bool | None = None
               ) -> tuple[jax.Array, jax.Array]:
    """Fused client-side round: [P, L, D] grads -> (psi [P, D], sq [P, L]).

    ``w`` is the per-server base model [P, D], or per-client stale bases
    [P, L, D] (the event engine).  ``pre_w`` scales gradients BEFORE the
    sensitivity clip (importance weights); ``fold_w`` are unnormalized fold
    weights (staleness x alive; the fold is weight-normalized with a 1e-12
    guard, zero total weight folds to zero); ``noise_w`` weights the
    noise/mask term per client (defaults to the uniform 1/L mean).  ``sq``
    is the raw squared gradient norm per (server, client) — callers derive
    clipped-norm feedback as ``min(bound, sqrt(sq))``.
    """
    backend, interpret = _resolve(backend, interpret)
    P, L, D = grads.shape
    from repro.telemetry import telemetry_active
    if telemetry_active():
        # shapes are concrete at trace time: record the round's analytic
        # HBM traffic (launch/roofline.py) once per newly-traced shape
        from repro.launch.roofline import round_pipeline_traffic
        itemsize = jnp.dtype(grads.dtype).itemsize
        fused_t = round_pipeline_traffic(P, L, D, itemsize=itemsize,
                                         mode=mode, fused=True)
        ref_t = round_pipeline_traffic(P, L, D, itemsize=itemsize,
                                       mode=mode, fused=False)
        _emit_kernel(op="round_fold.traffic", backend=backend, mode=mode,
                     hbm_bytes=float(fused_t["total"]),
                     hbm_bytes_ref=float(ref_t["total"]),
                     pld_passes=int(fused_t["pld_passes"]))
    ones = jnp.ones((P, L), jnp.float32)
    pre_w = ones if pre_w is None else pre_w.astype(jnp.float32)
    fold_w = ones if fold_w is None else fold_w.astype(jnp.float32)
    noise_w = ones / L if noise_w is None else noise_w.astype(jnp.float32)

    if backend == "ref":
        return _ref.round_fold_ref(w, grads, mu=mu, bound=bound,
                                   pre_w=pre_w, fold_w=fold_w,
                                   noise_w=noise_w, mode=mode, sigma=sigma,
                                   seeds=seeds, noise=noise)

    l_mult = 16 if grads.dtype == jnp.bfloat16 else 8

    def timed(block, d_pad):
        # mode-faithful proxy: mask mode's per-block cost is dominated by
        # the in-kernel stream generation, so candidates must be timed on
        # the mode they will serve
        L_p = L + (-L) % l_mult
        g0 = jnp.zeros((P, L_p, d_pad), grads.dtype)
        w0 = jnp.zeros((P, d_pad), w.dtype)
        s0 = jnp.zeros((P, L_p), jnp.float32)
        sd0 = jnp.zeros((P,), jnp.uint32) if mode == "mask" else None
        n0 = (jnp.zeros((P, L_p, d_pad), grads.dtype)
              if mode == "laplace" else None)
        return lambda: _rf.fold_apply(w0, g0, s0, s0, s0, mode=mode,
                                      sigma=sigma, seeds=sd0, noise=n0,
                                      block_d=block, interpret=interpret)

    block, d_pad = choose_block(
        "round_fold", D, shape_key=(P, L, str(grads.dtype), mode),
        make_timed=timed, interpret=interpret)

    g_p = _pad_axis(_pad_last(grads, d_pad)[0], 1, l_mult)
    w_p = _pad_last(w, d_pad)[0]
    if w.ndim == 3:
        w_p = _pad_axis(w_p, 1, l_mult)
    pre_p = _pad_last(pre_w, l_mult)[0]
    fold_p = _pad_last(fold_w, l_mult)[0]
    nw_p = _pad_last(noise_w, l_mult)[0]

    sq = _rf.fold_norms(g_p, block_d=block, interpret=interpret)  # [P, L_p]
    # tiny [P, L] clip/weight math between the two streaming passes
    nrm = pre_p * jnp.sqrt(sq)
    if bound > 0:
        coef = jnp.minimum(1.0, bound / jnp.maximum(nrm, 1e-12))
    else:
        coef = jnp.ones_like(nrm)
    stepscale = mu * coef * pre_p
    fsum = fold_p.sum(axis=1, keepdims=True)
    fold_n = fold_p / jnp.maximum(fsum, 1e-12)
    noise_p = (None if noise is None
               else _pad_axis(_pad_last(noise, d_pad)[0], 1, l_mult))
    psi = _rf.fold_apply(w_p, g_p, stepscale, fold_n, nw_p, mode=mode,
                         sigma=sigma, seeds=seeds, noise=noise_p,
                         block_d=block, interpret=interpret)
    return psi[:, :D], sq[:, :L]


# ---------------------------------------------------------------------------
# fused server combination (eq. 8 + 24)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("backend", "interpret"))
def graph_combine(A: jax.Array, psi: jax.Array, g: jax.Array | None = None,
                  *, cache: jax.Array | None = None,
                  gate: jax.Array | None = None,
                  backend: str | None = None,
                  interpret: bool | None = None) -> jax.Array:
    """Fused server combination: [P,D], [P,D] -> [P,D].

    ``A`` is a runtime argument, so per-round effective matrices from the
    resilience ``TopologyProcess`` slot straight in (one compilation serves
    every round, including inside ``lax.scan`` bodies).  ``g=None`` is the
    noise-free combine (A^T psi).  ``gate``/``cache`` ([P] mask, [P, D])
    implement the event engine's cached-psi re-announce IN the kernel:
    servers with gate off contribute their cached psi to the mix instead of
    the (unflushed) fold — no separate select pass over the parameters.
    """
    backend, interpret = _resolve(backend, interpret)
    if backend == "ref":
        psi = apply_gate(psi, gate, cache)
        if g is None:
            mixed = (jnp.asarray(A).T.astype(jnp.float32)
                     @ psi.astype(jnp.float32))
            return mixed.astype(psi.dtype)
        return _ref.graph_combine_ref(jnp.asarray(A).T, psi, g)

    a_t = jnp.asarray(A).T

    def timed(block, d_pad):
        # variant-faithful: the gated kernel reads two extra operands per
        # block, so time exactly the (g, gate) combination being served
        P8 = psi.shape[0] + (-psi.shape[0]) % 8
        z = jnp.zeros((P8, d_pad), psi.dtype)
        a0 = jnp.zeros((P8, P8), a_t.dtype)
        g0 = None if g is None else z
        c0 = None if gate is None else z
        gt0 = None if gate is None else jnp.zeros((P8, 1), jnp.float32)
        return lambda: _combine.graph_combine(a0, z, g0, cache=c0,
                                              gate=gt0, block_d=block,
                                              interpret=interpret)

    block, d_pad = choose_block(
        "graph_combine", psi.shape[-1],
        shape_key=(psi.shape[0], str(psi.dtype), g is None, gate is None),
        make_timed=timed, interpret=interpret)

    psi_p, D = _pad_last(psi, d_pad)
    psi_p, P = _pad_first(psi_p, 8)
    g_p = None
    if g is not None:
        g_p = _pad_first(_pad_last(g, d_pad)[0], 8)[0]
    cache_p = gate_p = None
    if gate is not None:
        cache_p = _pad_first(_pad_last(cache, d_pad)[0], 8)[0]
        gate_p = _pad_first(jnp.asarray(gate).astype(jnp.float32)[:, None],
                            8)[0]
    a_pad = jnp.zeros((psi_p.shape[0], psi_p.shape[0]), a_t.dtype)
    a_pad = a_pad.at[:P, :P].set(a_t)
    # padded servers get psi=g=0 rows already; diag term subtracts their own 0
    out = _combine.graph_combine(a_pad, psi_p, g_p, cache=cache_p,
                                 gate=gate_p, block_d=block,
                                 interpret=interpret)
    return out[:P, :D]


# ---------------------------------------------------------------------------
# single-server kernels
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("scale", "backend", "interpret"))
def secure_agg_mean(updates: jax.Array, seed: jax.Array, scale: float = 1.0,
                    backend: str | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """Masked client mean: [L,D] -> [D]."""
    backend, interpret = _resolve(backend, interpret)
    if backend == "ref":
        return _ref.secure_agg_mean_ref(updates, jnp.atleast_1d(seed),
                                        scale)

    def timed(block, d_pad):
        z = jnp.zeros((updates.shape[0], d_pad), updates.dtype)
        s0 = jnp.zeros((1,), jnp.uint32)
        return lambda: _sagg.secure_agg_mean(z, s0, scale=scale,
                                             block_d=block,
                                             interpret=interpret)

    block, d_pad = choose_block(
        "secure_agg", updates.shape[-1],
        shape_key=(updates.shape[0], str(updates.dtype)),
        make_timed=timed, interpret=interpret)
    upd, D = _pad_last(updates, d_pad)
    out = _sagg.secure_agg_mean(upd, jnp.atleast_1d(seed).astype(jnp.uint32),
                                scale=scale, block_d=block,
                                interpret=interpret)
    return out[:D]


@partial(jax.jit, static_argnames=("sigma", "backend", "interpret"))
def laplace_transform(u: jax.Array, sigma: float,
                      backend: str | None = None,
                      interpret: bool | None = None) -> jax.Array:
    """Uniform (-1/2,1/2) -> Lap(0, sigma/sqrt 2): [P,D] -> [P,D]."""
    backend, interpret = _resolve(backend, interpret)
    if backend == "ref":
        return _ref.laplace_transform_ref(u, sigma)

    def timed(block, d_pad):
        P8 = u.shape[0] + (-u.shape[0]) % 8
        z = jnp.zeros((P8, d_pad), u.dtype)
        return lambda: _laplace.laplace_transform(z, sigma, block_d=block,
                                                  interpret=interpret)

    block, d_pad = choose_block(
        "laplace", u.shape[-1], shape_key=(u.shape[0], str(u.dtype)),
        make_timed=timed, interpret=interpret)
    up, D = _pad_last(u, d_pad)
    up, P = _pad_first(up, 8)
    out = _laplace.laplace_transform(up, sigma, block_d=block,
                                     interpret=interpret)
    return out[:P, :D]


@partial(jax.jit, static_argnames=("bound", "backend", "interpret"))
def clip_accum(grads: jax.Array, bound: float,
               backend: str | None = None,
               interpret: bool | None = None) -> jax.Array:
    """Per-client clip to B + mean: [L,D] -> [D]."""
    backend, interpret = _resolve(backend, interpret)
    if backend == "ref":
        return _ref.clip_accum_ref(grads, bound)

    def timed(block, d_pad):
        z = jnp.zeros((grads.shape[0], d_pad), grads.dtype)
        return lambda: _clip.clip_accum(z, bound, block_d=block,
                                        interpret=interpret)

    block, d_pad = choose_block(
        "clip_accum", grads.shape[-1],
        shape_key=(grads.shape[0], str(grads.dtype)),
        make_timed=timed, interpret=interpret)
    g, D = _pad_last(grads, d_pad)
    out = _clip.clip_accum(g, bound, block_d=block, interpret=interpret)
    return out[:D]


@partial(jax.jit, static_argnames=("interpret",))
def swa_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         nvalid: jax.Array,
                         interpret: bool | None = None) -> jax.Array:
    """Flash-style decode attention vs a (ring) KV cache.

    q: [B,H,Dh]; k,v: [B,C,KVH,Dh] (KV heads repeated to H by the caller or
    here when KVH divides H); nvalid: [1] int32 valid-slot count."""
    from repro.kernels import swa_decode as _swa
    interpret = _on_cpu() if interpret is None else interpret
    B, H, Dh = q.shape
    kvh = k.shape[2]
    if kvh != H:
        rep = H // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return _swa.swa_decode_attention(q, k, v,
                                     jnp.atleast_1d(nvalid).astype(jnp.int32),
                                     interpret=interpret)
