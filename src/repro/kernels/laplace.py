"""Pallas TPU kernel: uniform bits -> Laplace noise (inverse CDF), fused scale.

Transforms uniform u in (-1/2, 1/2) to Lap(0, b):  g = -b sign(u) log1p(-2|u|).
Fused with the per-server scale so the noise tensor is written to HBM exactly
once, ready for :mod:`graph_combine`.  Elementwise; VPU-bound by design — the
point is avoiding a second HBM pass, not MXU math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _laplace_kernel(u_ref, out_ref, *, scale: float):
    u = u_ref[...].astype(jnp.float32)
    g = -scale * jnp.sign(u) * jnp.log1p(-2.0 * jnp.abs(u))
    out_ref[...] = g.astype(out_ref.dtype)


def laplace_transform(u: jax.Array, sigma: float, *, block_d: int = 512,
                      interpret: bool = False) -> jax.Array:
    """u: [P, D] uniform in (-1/2, 1/2) -> Lap(0, sigma/sqrt(2)) samples."""
    P, D = u.shape
    assert D % block_d == 0, (D, block_d)
    b = float(sigma) / (2.0 ** 0.5)
    kern = functools.partial(_laplace_kernel, scale=b)
    return pl.pallas_call(
        kern,
        grid=(D // block_d,),
        in_specs=[pl.BlockSpec((P, block_d), lambda j: (0, j))],
        out_specs=pl.BlockSpec((P, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((P, D), u.dtype),
        interpret=interpret,
    )(u)
