"""Baseline (grandfathered findings) support.

The baseline is a checked-in JSON inventory of known findings; CI fails
on any finding not in it ("new") and on any baseline entry that no
longer reproduces ("stale" — the debt was paid, so the entry must be
dropped to keep the inventory honest).  Entries match on
(rule, path, context, message) — line numbers are recorded for humans
but ignored for matching, so pure code motion never churns the file.
Each entry carries a free-form ``justification`` explaining why it is
grandfathered rather than fixed.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.framework import Finding

BASELINE_VERSION = 1
Key = Tuple[str, str, str, str]


def load_baseline(path) -> Dict[Key, dict]:
    data = json.loads(Path(path).read_text(encoding="utf-8"))
    entries: Dict[Key, dict] = {}
    for e in data.get("findings", []):
        key = (e["rule"], e["path"], e.get("context", ""), e["message"])
        entries[key] = e
    return entries


def save_baseline(path, findings: Sequence[Finding],
                  old: Optional[Dict[Key, dict]] = None) -> None:
    """Write findings as the new baseline, carrying over justification
    strings from matching old entries."""
    old = old or {}
    out: List[dict] = []
    for f in sorted(findings):
        entry = f.to_dict()
        prev = old.get(f.key())
        entry["justification"] = (prev or {}).get(
            "justification", "TODO: justify or fix")
        out.append(entry)
    blob = json.dumps({"version": BASELINE_VERSION, "findings": out},
                      indent=2, sort_keys=True)
    Path(path).write_text(blob + "\n", encoding="utf-8")


def diff_against_baseline(findings: Sequence[Finding],
                          baseline: Dict[Key, dict]
                          ) -> Tuple[List[Finding], List[dict],
                                     List[Finding]]:
    """(new findings, stale baseline entries, matched findings)."""
    found_keys = {f.key() for f in findings}
    new = [f for f in findings if f.key() not in baseline]
    matched = [f for f in findings if f.key() in baseline]
    stale = [e for k, e in sorted(baseline.items())
             if k not in found_keys]
    return new, stale, matched
