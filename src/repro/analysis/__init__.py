"""gflint: AST-based privacy/repro invariant analysis for the GFL stack.

Static rules (GFL001-GFL005) live in :mod:`repro.analysis.rules`; the
runtime counterpart (key-reuse / NaN / ledger checks) is
:mod:`repro.sanitize`.  CLI: ``python -m repro.analysis``.
"""
from repro.analysis.baseline import (diff_against_baseline, load_baseline,
                                     save_baseline)
from repro.analysis.framework import (AnalysisContext, Finding, ModuleInfo,
                                      Rule, run_analysis)
from repro.analysis.rules import ALL_RULES, default_rules, rule_by_id

__all__ = [
    "ALL_RULES", "AnalysisContext", "Finding", "ModuleInfo", "Rule",
    "default_rules", "diff_against_baseline", "load_baseline",
    "rule_by_id", "run_analysis", "save_baseline",
]
