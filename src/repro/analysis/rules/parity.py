"""GFL004 — backend-parity coverage.

The kernel layer (``kernels/ops.py``) dispatches every op between the
Pallas implementation and a pure-jnp reference (``backend="pallas"|
"ref"``); the whole-run ``use_kernels`` switch is only trustworthy while
each dispatched op (a) actually wires a ``*_ref`` counterpart and (b)
has a parity test referencing it by name.  The rule treats any public
function with a ``backend`` parameter as a dispatched op, so fixture
modules and future dispatch layers are covered without configuration.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.framework import (AnalysisContext, Finding, Rule,
                                      dotted_name)


def _has_backend_param(fn) -> bool:
    args = fn.args
    every = (args.posonlyargs + args.args + args.kwonlyargs)
    return any(a.arg == "backend" for a in every)


def _references_ref_impl(fn) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr.endswith("_ref"):
            return True
        if isinstance(node, ast.Name) and node.id.endswith("_ref"):
            return True
        name = dotted_name(node) if isinstance(node, ast.Attribute) else None
        if name and "_ref." in name:
            return True
    return False


class BackendParityRule(Rule):
    id = "GFL004"
    title = "dispatched kernel ops have a ref counterpart + parity test"

    def check(self, ctx: AnalysisContext) -> Iterable[Finding]:
        findings: List[Finding] = []
        for mod in ctx.source_modules():
            for fn in ast.walk(mod.tree):
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                if fn.name.startswith("_") or not _has_backend_param(fn):
                    continue
                if not _references_ref_impl(fn):
                    findings.append(Finding(
                        self.id, mod.path, fn.lineno, fn.col_offset,
                        mod.context_of(fn),
                        f"dispatched op '{fn.name}' has no ref "
                        f"counterpart (no *_ref reference in its body)"))
                if not ctx.test_references(fn.name):
                    findings.append(Finding(
                        self.id, mod.path, fn.lineno, fn.col_offset,
                        mod.context_of(fn),
                        f"dispatched op '{fn.name}' has no parity test "
                        f"referencing it"))
        return findings
