"""GFL005 — spec grammar round-trips.

Config surfaces in this repo are spec strings (``links:0.1+dropout:0.2``,
``uniform+trace:diurnal,...``, ``async:buffer=8,...``) with a
``parse_*_spec`` / ``*_to_spec`` pair each.  A parser whose inverse is
untested drifts silently — checkpoint metadata and sweep manifests stop
round-tripping.  The rule requires that

* every top-level ``parse_*_spec`` function is registered in the spec
  grammar registry (:mod:`repro.core.specs`), so the inventory is
  enumerable instead of pattern-matched, and
* every registered grammar has round-trip test evidence: a test that
  drives the registry (``all_grammars`` / ``get_grammar``) covers all of
  them; otherwise a test must reference both the parse function and a
  ``to_spec``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Tuple

from repro.analysis.framework import (AnalysisContext, Finding, Rule,
                                      call_tail, dotted_name)

REGISTRY_DRIVER_NAMES = ("all_grammars", "get_grammar", "spec_grammars")


class SpecRoundTripRule(Rule):
    id = "GFL005"
    title = "every parse/to_spec grammar registered and inverse-tested"

    def check(self, ctx: AnalysisContext) -> Iterable[Finding]:
        findings: List[Finding] = []

        parsers: List[Tuple[str, object, object]] = []  # (name, mod, node)
        for mod in ctx.source_modules():
            for node in mod.tree.body:
                if isinstance(node, ast.FunctionDef) \
                        and node.name.startswith("parse_") \
                        and node.name.endswith("_spec"):
                    parsers.append((node.name, mod, node))

        # registered grammars: register_grammar("name", parse=..., ...)
        registered: Dict[str, Tuple[object, object]] = {}
        registered_parse_names: set = set()
        for mod in ctx.source_modules():
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call) \
                        or call_tail(node) != "register_grammar":
                    continue
                gname = None
                if node.args and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str):
                    gname = node.args[0].value
                for arg in list(node.args) + [kw.value
                                              for kw in node.keywords]:
                    ref = dotted_name(arg)
                    if ref:
                        registered_parse_names.add(ref.split(".")[-1])
                if gname is not None:
                    registered[gname] = (mod, node)

        for pname, mod, node in parsers:
            if pname not in registered_parse_names:
                findings.append(Finding(
                    self.id, mod.path, node.lineno, node.col_offset,
                    mod.context_of(node),
                    f"spec parser '{pname}' is not registered in the "
                    f"spec-grammar registry (repro.core.specs) — its "
                    f"round-trip cannot be enumerated"))

        registry_driven = any(ctx.test_references(n)
                              for n in REGISTRY_DRIVER_NAMES)
        for gname, (mod, node) in sorted(registered.items()):
            if registry_driven:
                break
            parse_ref = None
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                ref = dotted_name(arg)
                if ref and ref.split(".")[-1].startswith("parse_"):
                    parse_ref = ref.split(".")[-1]
            evidenced = (parse_ref is not None
                         and ctx.test_references(parse_ref)
                         and ctx.test_references("to_spec"))
            if not evidenced:
                findings.append(Finding(
                    self.id, mod.path, node.lineno, node.col_offset,
                    mod.context_of(node),
                    f"registered spec grammar '{gname}' has no "
                    f"round-trip test (drive all_grammars()/get_grammar "
                    f"or test its parse/to_spec pair directly)"))
        return findings
