"""GFL006 — host-callback routing.

A raw ``jax.experimental.io_callback`` / ``jax.pure_callback`` /
``jax.debug.callback`` inside a traced body (``jit`` / ``scan`` /
Pallas kernels) is an unmanaged side channel: it bypasses the telemetry
session gate, so it fires even on "telemetry off" runs, is not schema
validated, and its host work cannot be accounted for by the overhead
contract of docs/observability.md.  PR 7's rule: in-graph host
callbacks must route through :mod:`repro.telemetry` (``emit`` /
``MetricsStream``), which owns the single sanctioned ``io_callback``
call site — or carry an explicit ``# gflint: disable=GFL006`` pragma
with the justification reviewed like any other baseline entry.

The telemetry package itself is exempt (it IS the routing point), as is
any module whose path contains a ``telemetry`` component.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.framework import (AnalysisContext, Finding, Rule,
                                      dotted_name)
from repro.analysis.rules.tracing import (_decorator_trace_info,
                                          _names_passed_to_tracers)

# callee tails that perform a host callback from a traced body
CALLBACK_TAILS = frozenset({"io_callback", "pure_callback",
                            "debug_callback"})
# ``jax.debug.callback`` has the generic tail "callback" — match it only
# with its qualifying prefix so ordinary ``obj.callback(...)`` calls on
# user objects stay out of scope
_DEBUG_CALLBACK_SUFFIX = "debug.callback"


def _is_callback_call(call: ast.Call) -> bool:
    name = dotted_name(call.func)
    if name is None:
        return False
    tail = name.split(".")[-1]
    if tail in CALLBACK_TAILS:
        return True
    return name == "callback" or name.endswith("." + _DEBUG_CALLBACK_SUFFIX) \
        or name == _DEBUG_CALLBACK_SUFFIX


def _is_exempt_module(path: str) -> bool:
    parts = path.split("/")
    return "telemetry" in parts


class CallbackRoutingRule(Rule):
    id = "GFL006"
    title = "in-graph host callbacks must route through repro.telemetry"

    def check(self, ctx: AnalysisContext) -> Iterable[Finding]:
        findings: List[Finding] = []
        for mod in ctx.source_modules():
            if _is_exempt_module(mod.path):
                continue
            passed = _names_passed_to_tracers(mod.tree)
            for fn in ast.walk(mod.tree):
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                traced, _, _ = _decorator_trace_info(fn)
                if not traced and fn.name not in passed:
                    continue
                findings.extend(self._check_fn(fn, mod))
        return findings

    def _check_fn(self, fn, mod) -> Iterable[Finding]:
        ctxname = mod.context_of(fn)
        qual = ctxname + "." + fn.name if ctxname else fn.name

        def own_nodes(owner):
            stack = list(ast.iter_child_nodes(owner))
            while stack:
                node = stack.pop()
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                    continue
                yield node
                stack.extend(ast.iter_child_nodes(node))

        for node in own_nodes(fn):
            if isinstance(node, ast.Call) and _is_callback_call(node):
                name = dotted_name(node.func) or "callback"
                yield Finding(
                    self.id, mod.path, node.lineno, node.col_offset,
                    mod.context_of(node),
                    f"raw host callback {name}() inside traced body {qual} "
                    f"— bypasses the telemetry session gate and schema; "
                    f"route through repro.telemetry.emit / MetricsStream "
                    f"(docs/observability.md)")
