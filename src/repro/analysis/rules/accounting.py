"""GFL002 — accountant coverage.

Every *release site* — a call into a mechanism protection hook
(``client_protect`` / ``client_protect_masked``), a mechanism noise
combiner, a secure-agg mask draw, or the fused kernel fold
(``round_fold`` with its noise ``fold_spec`` modes) — must be reachable
from some caller chain that also charges the accountant
(``PrivacyAccountant.advance`` or ``AsyncAccountant.record_round`` /
``record_schedule``).  A release no accountant ever hears about is
exactly the failure mode Theorem 2's budget bookkeeping forbids.

The pass builds a name-matched reference graph over the scanned modules:
each function definition is a node; any bare-name or attribute-tail
reference to a known definition name is an edge (this deliberately
over-connects — e.g. all ``client_protect`` methods merge — which only
ever *suppresses* findings, never invents them).  A function containing
a release call is flagged when no transitive referrer (including itself
and module-level code) contains a charge call.
"""
from __future__ import annotations

import ast
from collections import defaultdict
from typing import Dict, Iterable, List, Set, Tuple

from repro.analysis.framework import (AnalysisContext, Finding, Rule,
                                      call_tail)

RELEASE_NAMES = frozenset({
    "client_protect", "client_protect_masked",
    "homomorphic_combine_noise", "iid_noise_combine",
    "pairwise_masks_vec", "masked_client_mean_dropout_vec",
    "client_noise_tree", "combine_noise_tree",
    "round_fold",
})
CHARGE_NAMES = frozenset({"advance", "record_round", "record_schedule"})


class _FuncNode:
    __slots__ = ("name", "module", "context", "refs", "releases",
                 "has_charge", "line", "col")

    def __init__(self, name, module, context, line, col):
        self.name = name
        self.module = module
        self.context = context
        self.refs: Set[str] = set()
        self.releases: List[Tuple[int, int, str]] = []
        self.has_charge = False
        self.line = line
        self.col = col


def _collect_own_nodes(body_owner) -> Iterable[ast.AST]:
    """Walk a function/module body but stop at nested function/class
    definitions (they become their own graph nodes); lambdas stay with
    their enclosing function."""
    stack = list(ast.iter_child_nodes(body_owner))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


class AccountantCoverageRule(Rule):
    id = "GFL002"
    title = "every release site reachable from an accountant charge"

    def check(self, ctx: AnalysisContext) -> Iterable[Finding]:
        nodes: List[_FuncNode] = []
        for mod in ctx.source_modules():
            defs = [mod.tree] + [
                n for n in ast.walk(mod.tree)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
            for d in defs:
                if isinstance(d, ast.Module):
                    node = _FuncNode("<module>", mod, "", 0, 0)
                else:
                    node = _FuncNode(d.name, mod, mod.context_of(d),
                                     d.lineno, d.col_offset)
                for child in _collect_own_nodes(d):
                    if isinstance(child, ast.Call):
                        tail = call_tail(child)
                        if tail in CHARGE_NAMES:
                            node.has_charge = True
                        if tail in RELEASE_NAMES:
                            node.releases.append(
                                (child.lineno, child.col_offset, tail))
                    if isinstance(child, ast.Name):
                        node.refs.add(child.id)
                    elif isinstance(child, ast.Attribute):
                        node.refs.add(child.attr)
                nodes.append(node)

        # reverse edges by definition name: who references name N?
        referrers: Dict[str, List[_FuncNode]] = defaultdict(list)
        def_names = {n.name for n in nodes if n.name != "<module>"}
        for n in nodes:
            for ref in n.refs & def_names:
                referrers[ref].append(n)

        findings: List[Finding] = []
        for n in nodes:
            if not n.releases:
                continue
            if self._reaches_charge(n, referrers):
                continue
            reported: set = set()
            for line, col, rel in n.releases:
                if rel in reported:
                    continue
                reported.add(rel)
                if n.name == "<module>":
                    where = ""
                else:
                    where = (n.context + "." + n.name if n.context
                             else n.name)
                findings.append(Finding(
                    self.id, n.module.path, line, col, where,
                    f"release site '{rel}' in {where or '<module>'} is "
                    f"not reachable "
                    f"from any accountant charge "
                    f"(advance/record_round/record_schedule)"))
        return findings

    @staticmethod
    def _reaches_charge(start: _FuncNode, referrers) -> bool:
        seen = {id(start)}
        frontier = [start]
        while frontier:
            node = frontier.pop()
            if node.has_charge:
                return True
            if node.name == "<module>":
                continue  # module-level code has no callers
            for parent in referrers.get(node.name, ()):
                if id(parent) not in seen:
                    seen.add(id(parent))
                    frontier.append(parent)
        return False
