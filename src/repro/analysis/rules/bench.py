"""GFL007 — benchmark payload routing.

Repo-root ``BENCH_*.json`` payloads are the perf trajectory: they carry
the provenance ``meta`` block, declare headline metrics, and append the
compact record to ``BENCH_history.jsonl`` that ``benchmarks/compare.py``
gates CI on.  All of that happens inside :func:`benchmarks.meta.
write_bench` — a benchmark that writes its payload with a raw
``json.dump`` / ``Path.write_text`` produces an unattributable,
history-less file that silently falls out of the regression gate and
the ``inspect bench`` trends.

The rule flags any write-shaped call — ``write_text`` / ``write_bytes``
/ ``json.dump`` tails, or ``open(..., "w"/"a"/"x")`` — whose argument
subtree mentions a ``BENCH_*.json[l]`` literal or a name assigned from
one.  ``benchmarks/meta.py`` is exempt (it IS the routing point);
one-off exceptions carry ``# gflint: disable=GFL007`` with the
justification reviewed like any baseline entry.
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, List, Set

from repro.analysis.framework import (AnalysisContext, Finding, Rule,
                                      call_tail)

BENCH_FILE_RE = re.compile(r"\bBENCH_\w+\.jsonl?\b")
# callee tails that persist a payload to disk
WRITE_TAILS = frozenset({"write_text", "write_bytes", "dump"})
_WRITE_MODES = ("w", "a", "x")


def _is_exempt_module(path: str) -> bool:
    # the sanctioned call site and the stdlib-only gate that reads what it
    # wrote
    return path.endswith("benchmarks/meta.py") \
        or path.endswith("benchmarks/compare.py") \
        or path == "benchmarks/meta.py" or path == "benchmarks/compare.py"


def _mentions_bench_literal(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Constant) and isinstance(sub.value, str)
                and BENCH_FILE_RE.search(sub.value)):
            return True
    return False


def _bench_names(tree: ast.Module) -> Set[str]:
    """Names assigned (anywhere in the module) from an expression that
    mentions a BENCH_*.json literal or an already-known bench name —
    e.g. ``OUT = REPO_ROOT / "BENCH_kernels.json"``; ``p = OUT``."""
    names: Set[str] = set()
    for _ in range(2):  # one extra pass resolves simple aliases
        for node in ast.walk(tree):
            if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                continue
            value = node.value
            if value is None:
                continue
            hit = _mentions_bench_literal(value) or any(
                isinstance(sub, ast.Name) and sub.id in names
                for sub in ast.walk(value))
            if not hit:
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
    return names


def _open_write_mode(call: ast.Call) -> bool:
    """True for ``open(..., "w"|"a"|"x")`` (positional or mode= kw)."""
    mode = None
    if len(call.args) >= 2:
        mode = call.args[1]
    for kw in call.keywords:
        if kw.arg == "mode":
            mode = kw.value
    if mode is None or not (isinstance(mode, ast.Constant)
                            and isinstance(mode.value, str)):
        return False
    return any(m in mode.value for m in _WRITE_MODES)


def _targets_bench(call: ast.Call, bench_names: Set[str]) -> bool:
    for sub in ast.walk(call):
        if (isinstance(sub, ast.Constant) and isinstance(sub.value, str)
                and BENCH_FILE_RE.search(sub.value)):
            return True
        if isinstance(sub, ast.Name) and sub.id in bench_names:
            return True
    return False


class BenchWriteRoutingRule(Rule):
    id = "GFL007"
    title = "BENCH_*.json writes must route through benchmarks.meta" \
            ".write_bench"

    def check(self, ctx: AnalysisContext) -> Iterable[Finding]:
        findings: List[Finding] = []
        for mod in ctx.source_modules():
            if _is_exempt_module(mod.path):
                continue
            bench_names = _bench_names(mod.tree)
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                tail = call_tail(node)
                if tail in WRITE_TAILS:
                    pass
                elif tail == "open" and _open_write_mode(node):
                    pass
                else:
                    continue
                if not _targets_bench(node, bench_names):
                    continue
                findings.append(Finding(
                    self.id, mod.path, node.lineno, node.col_offset,
                    mod.context_of(node),
                    f"raw {tail}() write of a BENCH_*.json payload — "
                    f"bypasses provenance, headline declaration and the "
                    f"BENCH_history.jsonl regression gate; route through "
                    f"benchmarks.meta.write_bench"))
        return findings
