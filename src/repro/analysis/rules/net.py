"""GFL008 — process/network side channels route through core/fleet.

The fleet layer (:mod:`repro.core.fleet`) owns every OS-level delivery
path in this repo: sockets live behind the :class:`Transport` ABC (with
its timeout / retry / idempotent-dedup contract) and worker processes
behind :class:`Fleet` (heartbeat tracking, elastic restart, write-ahead
checkpoints).  A raw ``socket`` or ``subprocess`` use anywhere else is
an unmanaged side channel: no retry budget, no dedup, invisible to the
``fleet`` telemetry stream, and unreachable by the chaos harness — the
exact failure modes PR 10 exists to close.

The rule flags ``import socket`` / ``import subprocess`` (and their
``from ... import`` forms) in any source module outside ``core/fleet/``.
Flagging the import rather than individual calls keeps findings stable
under refactors and catches aliased use (``import subprocess as sp``).
Tooling that legitimately shells out (e.g. ``benchmarks/meta.py``
capturing ``git rev-parse`` provenance) carries a line pragma
``# gflint: disable=GFL008`` with the justification reviewed like any
baseline entry.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.framework import AnalysisContext, Finding, Rule

RAW_NET_MODULES = frozenset({"socket", "subprocess"})


def _is_exempt_module(path: str) -> bool:
    # core/fleet IS the sanctioned home of sockets and process control
    parts = path.split("/")
    return "fleet" in parts


def _imported_raw(node: ast.AST):
    """Yield (module_name, node) for raw socket/subprocess imports."""
    if isinstance(node, ast.Import):
        for alias in node.names:
            root = alias.name.split(".")[0]
            if root in RAW_NET_MODULES:
                yield root
    elif isinstance(node, ast.ImportFrom):
        root = (node.module or "").split(".")[0]
        if root in RAW_NET_MODULES:
            yield root


class NetRoutingRule(Rule):
    id = "GFL008"
    title = "raw socket/subprocess use must live in core/fleet"

    def check(self, ctx: AnalysisContext) -> Iterable[Finding]:
        findings: List[Finding] = []
        for mod in ctx.source_modules():
            if _is_exempt_module(mod.path):
                continue
            for node in ast.walk(mod.tree):
                for name in _imported_raw(node):
                    findings.append(Finding(
                        self.id, mod.path, node.lineno, node.col_offset,
                        mod.context_of(node),
                        f"raw '{name}' import outside core/fleet — "
                        f"delivery and process control must route through "
                        f"the fleet Transport/Fleet layer (timeout, retry, "
                        f"dedup, telemetry; docs/fleet.md)"))
        return findings
