"""GFL003 — trace safety.

Inside a traced body (``jit`` / ``scan`` / ``cond`` / ``shard_map`` /
Pallas kernels), Python-level branching or host materialization of a
traced value either crashes at trace time or — worse — triggers a
recompile per concrete value, leaking data through compilation timing.
PR 1's threefry fix was one instance of this class; the rule catches:

* ``if`` / ``while`` / ternary / ``assert`` whose test reads a traced
  parameter,
* ``float()`` / ``bool()`` / ``int()`` on a traced parameter,
* ``np.*`` calls fed a traced parameter.

A function counts as traced when it is decorated with ``jit`` (directly
or via ``partial(jax.jit, ...)``) or its name is passed as an argument
to a tracing entry point (``jit``, ``vmap``, ``grad``, ``scan``,
``cond``, ``while_loop``, ``fori_loop``, ``shard_map``,
``pallas_call``, ...).  Parameters named in ``static_argnames`` /
``static_argnums`` are exempt, as are structural reads that are static
under tracing: ``x is None``, ``x.shape`` / ``x.ndim`` / ``x.dtype`` /
``x.size``, and ``len(x)`` / ``isinstance(x, ...)``.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set, Tuple

from repro.analysis.framework import (AnalysisContext, Finding, ModuleInfo,
                                      Rule, dotted_name)

TRACE_ENTRY_POINTS = frozenset({
    "jit", "vmap", "pmap", "grad", "value_and_grad", "scan", "cond",
    "while_loop", "fori_loop", "shard_map", "pallas_call", "checkpoint",
    "remat", "custom_vjp", "custom_jvp", "switch", "associative_scan",
})
STRUCTURAL_CALLS = frozenset({"len", "isinstance", "type", "hasattr",
                              "getattr"})
STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "sharding",
                          "at"})
CASTS = frozenset({"float", "bool", "int"})


def _decorator_trace_info(fn) -> Tuple[bool, Set[str], Set[int]]:
    """(is_traced, static_argnames, static_argnums) from decorators."""
    static_names: Set[str] = set()
    static_nums: Set[int] = set()
    traced = False
    for dec in fn.decorator_list:
        name = dotted_name(dec if not isinstance(dec, ast.Call)
                           else dec.func)
        tail = name.split(".")[-1] if name else None
        if tail in ("jit", "pjit"):
            traced = True
            if isinstance(dec, ast.Call):
                static_names, static_nums = _static_kwargs(dec)
        elif tail == "partial" and isinstance(dec, ast.Call) and dec.args:
            inner = dotted_name(dec.args[0])
            if inner and inner.split(".")[-1] in ("jit", "pjit"):
                traced = True
                static_names, static_nums = _static_kwargs(dec)
    return traced, static_names, static_nums


def _static_kwargs(call: ast.Call) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) \
                        and isinstance(node.value, str):
                    names.add(node.value)
        elif kw.arg == "static_argnums":
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) \
                        and isinstance(node.value, int):
                    nums.add(node.value)
    return names, nums


def _names_passed_to_tracers(tree: ast.Module) -> Set[str]:
    """Function names passed (positionally or by keyword) into a tracing
    entry point anywhere in the module: ``jax.jit(tick)``,
    ``lax.scan(body, ...)``, ``pl.pallas_call(kernel, ...)``."""
    passed: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        tail = name.split(".")[-1] if name else None
        if tail not in TRACE_ENTRY_POINTS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name):
                passed.add(arg.id)
    return passed


class _ParentMap(dict):
    @classmethod
    def build(cls, root: ast.AST) -> "_ParentMap":
        pm = cls()
        for parent in ast.walk(root):
            for child in ast.iter_child_nodes(parent):
                pm[id(child)] = parent
        return pm


class TraceSafetyRule(Rule):
    id = "GFL003"
    title = "no python control flow / host casts on traced values"

    def check(self, ctx: AnalysisContext) -> Iterable[Finding]:
        findings: List[Finding] = []
        for mod in ctx.source_modules():
            passed = _names_passed_to_tracers(mod.tree)
            np_aliases = _numpy_aliases(mod.tree)
            for fn in ast.walk(mod.tree):
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                traced, st_names, st_nums = _decorator_trace_info(fn)
                if not traced and fn.name in passed:
                    traced = True
                if not traced:
                    continue
                findings.extend(self._check_fn(fn, st_names, st_nums,
                                               mod, np_aliases))
        return findings

    def _check_fn(self, fn, static_names: Set[str], static_nums: Set[int],
                  mod: ModuleInfo, np_aliases: Set[str]
                  ) -> Iterable[Finding]:
        params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        traced_params = {p for i, p in enumerate(params)
                         if p not in static_names and i not in static_nums
                         and p not in ("self", "cls")}
        if not traced_params:
            return

        def own_nodes(owner):
            stack = list(ast.iter_child_nodes(owner))
            while stack:
                node = stack.pop()
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                    continue
                yield node
                stack.extend(ast.iter_child_nodes(node))

        ctxname = mod.context_of(fn)
        qual = ctxname + "." + fn.name if ctxname else fn.name
        for node in own_nodes(fn):
            if isinstance(node, (ast.If, ast.While, ast.IfExp, ast.Assert)):
                test = node.test
                hit = _traced_value_read(test, traced_params)
                if hit:
                    kind = {ast.If: "if", ast.While: "while",
                            ast.IfExp: "ternary",
                            ast.Assert: "assert"}[type(node)]
                    yield Finding(
                        self.id, mod.path, node.lineno, node.col_offset,
                        mod.context_of(node),
                        f"python `{kind}` on traced value '{hit}' inside "
                        f"traced body {qual} — recompiles per value and "
                        f"leaks data-dependent control flow; use lax.cond/"
                        f"jnp.where")
            elif isinstance(node, ast.Call):
                name = dotted_name(node.func)
                parts = name.split(".") if name else []
                tail = parts[-1] if parts else None
                if tail in CASTS and len(parts) == 1:
                    for arg in node.args:
                        hit = _traced_value_read(arg, traced_params)
                        if hit:
                            yield Finding(
                                self.id, mod.path, node.lineno,
                                node.col_offset, mod.context_of(node),
                                f"host cast {tail}() on traced value "
                                f"'{hit}' inside traced body {qual} — "
                                f"forces a trace-time concretization")
                            break
                elif parts and parts[0] in np_aliases:
                    for arg in node.args:
                        hit = _traced_value_read(arg, traced_params)
                        if hit:
                            yield Finding(
                                self.id, mod.path, node.lineno,
                                node.col_offset, mod.context_of(node),
                                f"numpy call {name}() on traced value "
                                f"'{hit}' inside traced body {qual} — "
                                f"materializes the tracer on host; use "
                                f"jnp")
                            break


def _numpy_aliases(tree: ast.Module) -> Set[str]:
    aliases: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    aliases.add(a.asname or "numpy")
    return aliases


def _traced_value_read(expr: ast.AST, traced: Set[str]) -> Optional[str]:
    """Name of a traced parameter whose *value* (not structure) is read
    inside `expr`; None when every reference is structural/static."""
    pm = _ParentMap.build(expr)
    for node in ast.walk(expr):
        if not isinstance(node, ast.Name) or node.id not in traced:
            continue
        if _is_structural(node, pm):
            continue
        return node.id
    return None


def _is_structural(name: ast.Name, pm: _ParentMap) -> bool:
    node: ast.AST = name
    while True:
        parent = pm.get(id(node))
        if parent is None:
            return False
        if isinstance(parent, ast.Attribute) and parent.value is node \
                and parent.attr in STATIC_ATTRS:
            return True
        if isinstance(parent, ast.Call):
            fname = dotted_name(parent.func)
            tail = fname.split(".")[-1] if fname else None
            if tail in STRUCTURAL_CALLS and parent.func is not node:
                return True
        if isinstance(parent, ast.Compare):
            # `x is None` / `x is not None` are static under tracing
            ops_ok = all(isinstance(op, (ast.Is, ast.IsNot))
                         for op in parent.ops)
            operands = [parent.left] + list(parent.comparators)
            if ops_ok and node in operands:
                return True
        node = parent
