"""GFL001 — PRNG key hygiene.

Two sub-checks:

* **key reuse**: a ``jax.random.*`` sampling call consumes its key; a
  second sampling call on the same (un-rebound) name in the same scope is
  a correlated-noise bug — exactly the class of error that silently
  breaks the DP guarantee (two "independent" noise draws that are
  bit-identical).  ``split``/``fold_in`` (or any rebinding) clears the
  consumed mark.
* **literal seeds**: ``PRNGKey(<int literal>)`` outside tests and the
  approved seed factory (``repro.rng_key``) hard-codes the experiment
  seed at the call site, so sweeps silently share randomness.

The reuse analysis is a small abstract interpretation over statement
lists: ``if``/``else`` branches fork the consumed-set and merge by union;
loop bodies are scanned twice so a draw that consumes a loop-invariant
key is caught on the second pass.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.framework import (AnalysisContext, Finding, ModuleInfo,
                                      Rule, dotted_name)

# jax.random samplers that consume a key (first positional arg).
SAMPLING_FNS = frozenset({
    "normal", "uniform", "randint", "bernoulli", "laplace", "exponential",
    "gamma", "beta", "dirichlet", "categorical", "choice", "permutation",
    "gumbel", "truncated_normal", "cauchy", "logistic", "poisson",
    "rademacher", "bits", "orthogonal", "ball", "loggamma", "rayleigh",
    "multivariate_normal", "t", "gallery",
})
# repo-local samplers with the same (key, ...) convention.
LOCAL_SAMPLERS = frozenset({"sample_laplace", "sample_gaussian"})
# interposing calls that re-derive keys and never count as consumption.
KEY_DERIVE_FNS = frozenset({"split", "fold_in", "clone"})
KEY_CTORS = frozenset({"PRNGKey", "key"})

# files allowed to construct literal-seed keys (the seed factory itself).
ALLOWED_LITERAL_SUFFIXES = ("repro/__init__.py",)


class _JaxRandomResolver:
    """Map call nodes back to jax.random function names through the
    module's import aliases (``import jax``, ``import jax.random as jr``,
    ``from jax import random``, ``from jax.random import normal as n``)."""

    def __init__(self, tree: ast.Module):
        self.jax_aliases: Set[str] = set()
        self.random_aliases: Set[str] = set()
        self.direct: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "jax":
                        self.jax_aliases.add(a.asname or "jax")
                    elif a.name == "jax.random":
                        if a.asname:
                            self.random_aliases.add(a.asname)
                        else:
                            self.jax_aliases.add("jax")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "jax":
                    for a in node.names:
                        if a.name == "random":
                            self.random_aliases.add(a.asname or "random")
                elif node.module == "jax.random":
                    for a in node.names:
                        self.direct[a.asname or a.name] = a.name

    def resolve(self, call: ast.Call) -> Optional[str]:
        """jax.random function name for this call, or None."""
        func = call.func
        name = dotted_name(func)
        if name is None:
            return None
        parts = name.split(".")
        if len(parts) >= 3 and parts[0] in self.jax_aliases \
                and parts[-2] == "random":
            return parts[-1]
        if len(parts) == 2 and parts[0] in self.random_aliases:
            return parts[1]
        if len(parts) == 1 and parts[0] in self.direct:
            return self.direct[parts[0]]
        return None


class KeyHygieneRule(Rule):
    id = "GFL001"
    title = "PRNG key hygiene (reuse / literal seeds)"

    def check(self, ctx: AnalysisContext) -> Iterable[Finding]:
        findings: List[Finding] = []
        for mod in ctx.source_modules():
            resolver = _JaxRandomResolver(mod.tree)
            findings.extend(self._literal_seeds(mod, resolver))
            findings.extend(self._reuse(mod, resolver))
        return findings

    # -- literal PRNGKey(<const>) ------------------------------------
    def _literal_seeds(self, mod: ModuleInfo,
                       resolver: _JaxRandomResolver) -> Iterable[Finding]:
        if mod.path.endswith(ALLOWED_LITERAL_SUFFIXES):
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = resolver.resolve(node)
            if fn not in KEY_CTORS or not node.args:
                continue
            seed = node.args[0]
            if isinstance(seed, ast.Constant) and isinstance(seed.value,
                                                             int):
                yield Finding(
                    self.id, mod.path, node.lineno, node.col_offset,
                    mod.context_of(node),
                    f"literal PRNGKey({seed.value}): hard-coded seed "
                    f"outside an approved factory; route through "
                    f"repro.rng_key()")

    # -- key reuse ----------------------------------------------------
    def _reuse(self, mod: ModuleInfo,
               resolver: _JaxRandomResolver) -> Iterable[Finding]:
        findings: List[Finding] = []
        scopes = [mod.tree] + [n for n in ast.walk(mod.tree)
                               if isinstance(n, (ast.FunctionDef,
                                                 ast.AsyncFunctionDef))]
        for scope in scopes:
            body = scope.body
            self._scan_block(body, set(), mod, resolver, findings)
        # lambdas are their own binding scope (`lambda k: choice(k, ...)`
        # twice is two different keys): scan each body independently
        for lam in ast.walk(mod.tree):
            if isinstance(lam, ast.Lambda):
                self._scan_expr(lam.body, set(), mod, resolver, findings)
        # dedup per (line, col) — loop double-scan revisits statements
        return list({(f.line, f.col, f.message): f for f in findings}
                    .values())

    def _scan_block(self, stmts, consumed: Set[str], mod: ModuleInfo,
                    resolver: _JaxRandomResolver,
                    findings: List[Finding]) -> Set[str]:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue  # nested scopes analyzed on their own
            if isinstance(st, ast.If):
                c1 = self._scan_block(st.body, set(consumed), mod,
                                      resolver, findings)
                c2 = self._scan_block(st.orelse, set(consumed), mod,
                                      resolver, findings)
                consumed = c1 | c2
            elif isinstance(st, (ast.For, ast.While)):
                # two passes: catches draws consuming a loop-invariant key
                once = self._scan_block(st.body, set(consumed), mod,
                                        resolver, findings)
                consumed = self._scan_block(st.body, once, mod, resolver,
                                            findings)
                consumed = self._scan_block(st.orelse, consumed, mod,
                                            resolver, findings)
            elif isinstance(st, (ast.With, ast.Try)):
                for block in getattr(st, "body", []), \
                        getattr(st, "finalbody", []):
                    consumed = self._scan_block(block, consumed, mod,
                                                resolver, findings)
                for h in getattr(st, "handlers", []):
                    consumed |= self._scan_block(h.body, set(consumed),
                                                 mod, resolver, findings)
            else:
                consumed = self._scan_statement(st, consumed, mod,
                                                resolver, findings)
        return consumed

    @staticmethod
    def _walk_same_scope(root) -> Iterable[ast.AST]:
        """Walk `root` without descending into nested binding scopes
        (defs, lambdas, comprehensions bind their own names)."""
        stack = list(ast.iter_child_nodes(root))
        yield root
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Lambda)):
                continue
            yield node
            stack.extend(ast.iter_child_nodes(node))

    def _scan_expr(self, expr, consumed: Set[str], mod: ModuleInfo,
                   resolver: _JaxRandomResolver,
                   findings: List[Finding]) -> Set[str]:
        return self._scan_statement(ast.Expr(value=expr), consumed, mod,
                                    resolver, findings)

    def _scan_statement(self, st, consumed: Set[str], mod: ModuleInfo,
                        resolver: _JaxRandomResolver,
                        findings: List[Finding]) -> Set[str]:
        for node in self._walk_same_scope(st):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            fn = resolver.resolve(node)
            tail = dotted_name(node.func)
            tail = tail.split(".")[-1] if tail else None
            is_sampler = fn in SAMPLING_FNS or tail in LOCAL_SAMPLERS
            if fn in KEY_DERIVE_FNS:
                continue  # split/fold_in interpose; no consumption
            if not is_sampler:
                continue
            keyarg = node.args[0]
            if not isinstance(keyarg, ast.Name):
                continue  # split(k)[0], fold_in(k, i): fresh each time
            name = keyarg.id
            if name in consumed:
                findings.append(Finding(
                    self.id, mod.path, node.lineno, node.col_offset,
                    mod.context_of(node),
                    f"key '{name}' reused by "
                    f"{fn or tail}() without an interposed "
                    f"split/fold_in — correlated noise draws"))
            consumed.add(name)
        # any rebinding clears the consumed mark
        for node in self._walk_same_scope(st):
            targets: List[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [node.target]
            elif isinstance(node, ast.NamedExpr):
                targets = [node.target]
            for tgt in targets:
                for leaf in ast.walk(tgt):
                    if isinstance(leaf, ast.Name):
                        consumed.discard(leaf.id)
        return consumed
