"""gflint rule registry."""
from __future__ import annotations

from repro.analysis.rules.accounting import AccountantCoverageRule
from repro.analysis.rules.bench import BenchWriteRoutingRule
from repro.analysis.rules.callbacks import CallbackRoutingRule
from repro.analysis.rules.keys import KeyHygieneRule
from repro.analysis.rules.net import NetRoutingRule
from repro.analysis.rules.parity import BackendParityRule
from repro.analysis.rules.specs import SpecRoundTripRule
from repro.analysis.rules.tracing import TraceSafetyRule

ALL_RULES = (KeyHygieneRule, AccountantCoverageRule, TraceSafetyRule,
             BackendParityRule, SpecRoundTripRule, CallbackRoutingRule,
             BenchWriteRoutingRule, NetRoutingRule)


def default_rules():
    return [cls() for cls in ALL_RULES]


def rule_by_id(rule_id: str):
    for cls in ALL_RULES:
        if cls.id == rule_id:
            return cls
    raise KeyError(rule_id)
