"""gflint CLI: ``python -m repro.analysis [options] [paths...]``.

Exit codes: 0 clean (or fully baselined), 1 new findings or stale
baseline entries, 2 usage errors.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.baseline import (diff_against_baseline, load_baseline,
                                     save_baseline)
from repro.analysis.framework import run_analysis
from repro.analysis.rules import ALL_RULES


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="gflint: privacy/repro invariant analysis "
                    "(GFL001-GFL005)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to analyze (default: src/ "
                         "if present, else .)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="baseline JSON of grandfathered findings "
                         "(default: analysis/baseline.json when it "
                         "exists)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline; report every finding")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "(keeps existing justifications)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--root", default=".",
                    help="root that finding paths are relative to")
    ap.add_argument("--list-rules", action="store_true")
    return ap


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        for cls in ALL_RULES:
            print(f"{cls.id}  {cls.title}")
        return 0

    root = Path(args.root)
    paths = args.paths or None
    if not paths:
        default = root / "src"
        paths = [default] if default.is_dir() else [root]

    findings = run_analysis(paths, root=root)

    baseline_path = args.baseline
    if baseline_path is None and not args.no_baseline:
        candidate = root / "analysis" / "baseline.json"
        if candidate.is_file():
            baseline_path = candidate
    baseline: dict = {}
    if baseline_path and not args.no_baseline:
        baseline_path = Path(baseline_path)
        if baseline_path.is_file():
            baseline = load_baseline(baseline_path)
        elif not args.write_baseline:
            print(f"gflint: baseline {baseline_path} not found",
                  file=sys.stderr)
            return 2

    if args.write_baseline:
        target = Path(baseline_path or root / "analysis" / "baseline.json")
        target.parent.mkdir(parents=True, exist_ok=True)
        save_baseline(target, findings, baseline)
        print(f"gflint: wrote {len(findings)} finding(s) to {target}")
        return 0

    new, stale, matched = diff_against_baseline(findings, baseline)

    if args.format == "json":
        print(json.dumps({
            "findings": [f.to_dict() for f in findings],
            "new": [f.to_dict() for f in new],
            "stale": stale,
            "baselined": len(matched),
        }, indent=2, sort_keys=True))
    else:
        for f in new:
            print(f.render())
        for e in stale:
            print(f"stale baseline entry: {e['rule']} {e['path']} "
                  f"{e['message']!r} no longer reproduces — remove it "
                  f"(or run --write-baseline)")
        status = (f"gflint: {len(findings)} finding(s): {len(new)} new, "
                  f"{len(matched)} baselined, {len(stale)} stale")
        print(status)

    return 1 if (new or stale) else 0
