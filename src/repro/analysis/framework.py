"""gflint core: modules, findings, rule protocol and the analysis runner.

The paper's guarantee — privatized graph FL matching non-private
performance — only holds if every noise release is charged to the
accountant and every random draw follows the key-splitting discipline the
repro layer depends on.  After PRs 1-5 those invariants are enforced by
convention across ``core/privacy``, ``core/population``, ``core/events``
and ``kernels/``; gflint makes them machine-checked.

Design: one :class:`ModuleInfo` per parsed source file; rules implement
``check(ctx)`` over an :class:`AnalysisContext` so cross-module invariants
(call-graph reachability, test-evidence checks) are first-class rather
than bolted on.  Test files are parsed into the context as an *evidence
corpus* (GFL004/GFL005 look for parity / round-trip tests there) but are
never themselves linted.

Suppression: a trailing or preceding ``# gflint: disable=GFL001`` comment
silences a rule on that line; ``# gflint: disable-file=GFL003`` near the
top of a file silences it for the whole module.  Grandfathered findings
belong in the checked-in baseline (see :mod:`repro.analysis.baseline`)
with a justification string, not in pragmas.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

PRAGMA_RE = re.compile(r"#\s*gflint:\s*disable=([A-Z0-9,\s]+)")
FILE_PRAGMA_RE = re.compile(r"#\s*gflint:\s*disable-file=([A-Z0-9,\s]+)")
PARSE_ERROR_RULE = "GFL000"
# how many leading lines may carry a disable-file pragma
_FILE_PRAGMA_WINDOW = 10


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation.

    The baseline matches findings on :meth:`key` — (rule, path, context,
    message) — NOT on line numbers, so moving code around does not churn
    the baseline; only adding/removing violations does.
    """
    rule: str
    path: str          # posix path relative to the analysis root
    line: int
    col: int
    context: str       # enclosing function qualname ("" = module level)
    message: str

    def key(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.context, self.message)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "context": self.context,
                "message": self.message}

    def render(self) -> str:
        where = f" [in {self.context}]" if self.context else ""
        return (f"{self.path}:{self.line}:{self.col} {self.rule} "
                f"{self.message}{where}")


@dataclass
class ModuleInfo:
    """One parsed source file plus the derived lookup tables rules need."""
    path: str                      # posix relpath from the analysis root
    tree: ast.Module
    lines: List[str]
    is_test: bool = False
    file_disabled: frozenset = frozenset()
    # node -> qualname of the enclosing function chain, filled lazily
    _contexts: Optional[Dict[int, str]] = field(default=None, repr=False)

    def context_of(self, node: ast.AST) -> str:
        """Qualified name of the function enclosing `node` ("" = module)."""
        if self._contexts is None:
            self._contexts = _build_contexts(self.tree)
        return self._contexts.get(id(node), "")

    def line_disabled(self, line: int, rule: str) -> bool:
        """True when a pragma on the finding's line (or the line above)
        disables `rule`."""
        if rule in self.file_disabled:
            return True
        for ln in (line, line - 1):
            if 1 <= ln <= len(self.lines):
                m = PRAGMA_RE.search(self.lines[ln - 1])
                if m and rule in _split_rules(m.group(1)):
                    return True
        return False


def _split_rules(blob: str) -> frozenset:
    return frozenset(r.strip() for r in blob.split(",") if r.strip())


def _build_contexts(tree: ast.Module) -> Dict[int, str]:
    contexts: Dict[int, str] = {}

    def walk(node: ast.AST, stack: Tuple[str, ...]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                contexts[id(child)] = ".".join(stack) if stack else ""
                walk(child, stack + (child.name,))
            else:
                contexts[id(child)] = ".".join(stack)
                walk(child, stack)

    walk(tree, ())
    return contexts


def dotted_name(node: ast.AST) -> Optional[str]:
    """Flatten a Name/Attribute chain to "a.b.c" (None for anything else,
    e.g. a call result used as a callee)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_tail(call: ast.Call) -> Optional[str]:
    """Last component of the callee name: ``mech.client_protect(...)`` ->
    "client_protect"."""
    func = call.func
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


class Rule:
    """Base class: rules declare an id/title and implement ``check``."""

    id: str = "GFL???"
    title: str = ""

    def check(self, ctx: "AnalysisContext") -> Iterable[Finding]:
        raise NotImplementedError


class AnalysisContext:
    """Everything a rule may look at: lint targets + test evidence."""

    def __init__(self, modules: Sequence[ModuleInfo],
                 test_modules: Sequence[ModuleInfo], root: Path):
        self.modules = list(modules)
        self.test_modules = list(test_modules)
        self.root = root

    def source_modules(self) -> List[ModuleInfo]:
        """The lintable (non-test) modules."""
        return self.modules

    def test_references(self, name: str) -> bool:
        """True when any test module references `name` (as a bare name, an
        attribute tail, or inside a string literal — covers parametrized
        test ids)."""
        for mod in self.test_modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Name) and node.id == name:
                    return True
                if isinstance(node, ast.Attribute) and node.attr == name:
                    return True
                if isinstance(node, ast.alias) and name in (node.name,
                                                            node.asname):
                    return True
                if isinstance(node, ast.arg) and node.arg == name:
                    return True
                if (isinstance(node, ast.Constant)
                        and isinstance(node.value, str)
                        and name in node.value):
                    return True
        return False


def _is_test_path(rel: Path) -> bool:
    return ("tests" in rel.parts or "test" in rel.parts
            or rel.name.startswith("test_") or rel.name == "conftest.py")


def load_module(path: Path, root: Path) -> ModuleInfo:
    rel = path.resolve().relative_to(root.resolve()) \
        if path.resolve().is_relative_to(root.resolve()) else path
    text = path.read_text(encoding="utf-8")
    lines = text.splitlines()
    disabled: frozenset = frozenset()
    for ln in lines[:_FILE_PRAGMA_WINDOW]:
        m = FILE_PRAGMA_RE.search(ln)
        if m:
            disabled = disabled | _split_rules(m.group(1))
    tree = ast.parse(text, filename=str(path))
    return ModuleInfo(path=rel.as_posix(), tree=tree, lines=lines,
                      is_test=_is_test_path(rel), file_disabled=disabled)


def collect_modules(paths: Sequence[Path], root: Path
                    ) -> Tuple[List[ModuleInfo], List[ModuleInfo],
                               List[Finding]]:
    """Parse every .py under `paths`; returns (source modules, test
    modules, parse-error findings)."""
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    seen: set = set()
    sources: List[ModuleInfo] = []
    tests: List[ModuleInfo] = []
    errors: List[Finding] = []
    for f in files:
        key = f.resolve()
        if key in seen:
            continue
        seen.add(key)
        try:
            mod = load_module(f, root)
        except SyntaxError as e:
            rel = (key.relative_to(root.resolve())
                   if key.is_relative_to(root.resolve()) else f)
            errors.append(Finding(PARSE_ERROR_RULE, Path(rel).as_posix(),
                                  e.lineno or 0, e.offset or 0, "",
                                  f"syntax error: {e.msg}"))
            continue
        (tests if mod.is_test else sources).append(mod)
    return sources, tests, errors


def run_analysis(paths: Sequence, *, root=None,
                 rules: Optional[Sequence[Rule]] = None,
                 extra_test_paths: Sequence = ()) -> List[Finding]:
    """Run gflint over `paths` and return the surviving findings, sorted.

    ``root`` anchors the relative paths in findings (default: cwd).  Test
    files found under `paths` (or ``extra_test_paths``) join the evidence
    corpus; a ``tests/`` directory next to ``root`` is picked up
    automatically so GFL004/GFL005 see the parity/round-trip tests without
    callers having to pass it.
    """
    from repro.analysis.rules import default_rules

    root = Path(root) if root is not None else Path.cwd()
    sources, tests, findings = collect_modules([Path(p) for p in paths],
                                               root)
    auto_tests = root / "tests"
    extra = list(extra_test_paths)
    if auto_tests.is_dir() and not any(
            Path(p).resolve() == auto_tests.resolve()
            for p in list(paths) + extra):
        extra.append(auto_tests)
    if extra:
        _, more_tests, more_errors = collect_modules(
            [Path(p) for p in extra], root)
        known = {m.path for m in tests}
        tests += [m for m in more_tests if m.path not in known]
        findings += more_errors

    ctx = AnalysisContext(sources, tests, root)
    for rule in (rules if rules is not None else default_rules()):
        findings.extend(rule.check(ctx))

    by_path = {m.path: m for m in sources}
    kept: List[Finding] = []
    for f in findings:
        mod = by_path.get(f.path)
        if mod is not None and mod.line_disabled(f.line, f.rule):
            continue
        kept.append(f)
    return sorted(set(kept))
