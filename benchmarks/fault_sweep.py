"""Resilience benchmark: MSD vs failure rate per topology family.

The paper's motivation for the graph architecture is robustness to
communication failures; this sweep quantifies it.  For each topology family
and link-drop probability p we run the protocol under the resilience
runtime (per-round effective A_i with Metropolis fold-back, Assumption 1
enforced every round) and report the steady-state MSD together with the
realized spectral-gap trajectory (lambda_i = rho(A_i - 11^T/P): larger =
slower mixing; the base value is the p=0 row).

    PYTHONPATH=src python benchmarks/fault_sweep.py            # full sweep
    PYTHONPATH=src python benchmarks/fault_sweep.py --reduced  # CPU smoke

Writes results/fault_sweep.csv with rows
    topology, fault_kind, drop_p, msd_tail, gap_mean, gap_worst
and prints ``name,value`` summary metrics for the benchmark harness.
"""
from __future__ import annotations

import argparse
import csv
import os

import jax

from repro.configs.base import GFLConfig
from repro.core.simulate import fault_sweep, generate_problem

OUT = os.path.join(os.path.dirname(__file__), "results")

# >= 3 families, mixing quality increasing: ring (gap -> 1 with P),
# torus (2-D wraparound), hypercube (log-degree), full (gap 0)
TOPOLOGIES = ("ring", "torus", "hypercube", "full")
FAULT_KINDS = ("links", "outage")


def run(iters: int = 300, quick: bool = False, reduced: bool = False,
        P: int = 8, K: int = 20, sigma_g: float = 0.2):
    if quick or reduced:
        iters, K = 60, 10
    drop_ps = (0.0, 0.1, 0.3) if (quick or reduced) \
        else (0.0, 0.05, 0.1, 0.2, 0.3, 0.5)
    fault_kinds = ("links",) if (quick or reduced) else FAULT_KINDS

    prob = generate_problem(jax.random.PRNGKey(0), P=P, K=K)  # fixed bench seed: reproducible trajectory  # gflint: disable=GFL001
    rows = []
    finals = {}
    for topology in TOPOLOGIES:
        cfg = GFLConfig(num_servers=P, clients_per_server=K,
                        clients_sampled=min(5, K), topology=topology,
                        privacy="hybrid", sigma_g=sigma_g, mu=0.1,
                        grad_bound=10.0)
        for kind in fault_kinds:
            for p, tail, gap_mean, gap_worst in fault_sweep(
                    prob, cfg, iters=iters, drop_probs=drop_ps,
                    fault_kind=kind, batch_size=10, seed=1):
                rows.append((topology, kind, p, tail, gap_mean, gap_worst))
                finals[(topology, kind, p)] = (tail, gap_mean)

    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "fault_sweep.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["topology", "fault_kind", "drop_p", "msd_tail",
                    "gap_mean", "gap_worst"])
        w.writerows(rows)

    p_hi = max(drop_ps)
    out = []
    for topology in TOPOLOGIES:
        base_msd, base_gap = finals[(topology, "links", 0.0)]
        hi_msd, hi_gap = finals[(topology, "links", p_hi)]
        out.append((f"fault_sweep/{topology}_msd_ratio@p{p_hi:g}",
                    hi_msd / max(base_msd, 1e-12)))
        out.append((f"fault_sweep/{topology}_gap_delta@p{p_hi:g}",
                    hi_gap - base_gap))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke: fewer iters/probabilities")
    ap.add_argument("--iters", type=int, default=300)
    args = ap.parse_args(argv)
    for name, val in run(iters=args.iters, reduced=args.reduced):
        print(f"{name},{val:.6g}")


if __name__ == "__main__":
    main()
