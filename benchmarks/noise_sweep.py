"""Fig. 2 (right) extension: steady-state MSD vs noise level sigma_g,
swept over EVERY registered privacy mechanism.

Shows the Theorem-1 structure: the iid scheme's MSD grows with
O(mu + mu^{-1}) sigma^2 while the hybrid-family (hybrid, gaussian_dp,
scheduled) MSD grows only with the O(mu)-scaled network-disagreement term —
their noise lies in the averaging nullspace regardless of distribution.
"""
from __future__ import annotations

import csv
import os

import jax
import numpy as np

from repro.configs.base import GFLConfig
from repro.core.privacy.accountant import scheduled_sigma_at
from repro.core.privacy.mechanism import list_mechanisms
from repro.core.simulate import generate_problem, run_gfl

OUT = os.path.join(os.path.dirname(__file__), "results")


MU = 0.1
B = 10.0


def run(iters: int = 250, quick: bool = False):
    if quick:
        iters = 100
    sigmas = [0.0, 0.2, 0.5, 1.0, 2.0]
    prob = generate_problem(jax.random.PRNGKey(0), P=10, K=50)  # fixed bench seed: reproducible trajectory  # gflint: disable=GFL001
    rows = []
    finals = {}
    for scheme in list_mechanisms():
        for sigma in sigmas if scheme != "none" else [0.0]:
            # scheduled ignores sigma_g; invert scheduled_sigma_at at
            # i == iters (sigma is proportional to 1/eps) so its
            # end-of-horizon noise tracks the sweep
            eps = (scheduled_sigma_at(iters, MU, B, iters, 1.0) / sigma
                   if sigma > 0 else 0.0)
            cfg = GFLConfig(num_servers=10, clients_per_server=50,
                            clients_sampled=10, privacy=scheme,
                            sigma_g=sigma, mu=MU, topology="full",
                            grad_bound=B,
                            epsilon_target=eps, epsilon_horizon=iters)
            trace, _ = run_gfl(prob, cfg, iters=iters, batch_size=10, seed=1)
            tail = float(np.mean(trace[-max(iters // 10, 5):]))
            rows.append((scheme, sigma, tail))
            finals[(scheme, sigma)] = tail
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "noise_sweep.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["scheme", "sigma_g", "msd_tail"])
        w.writerows(rows)
    base = finals[("none", 0.0)]
    return [
        ("noise_sweep/hybrid_over_none@sigma2", finals[("hybrid", 2.0)]
         / max(base, 1e-12)),
        ("noise_sweep/iid_over_none@sigma2", finals[("iid_dp", 2.0)]
         / max(base, 1e-12)),
    ]


if __name__ == "__main__":
    for name, val in run():
        print(f"{name},{val:.6g}")
