"""Benchmark harness: one module per paper table/figure + system benches.

Prints ``name,value`` CSV rows (value is us_per_call for kernel benches and
a derived metric otherwise).  ``--quick`` trims iteration counts.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args(argv)

    from benchmarks import (async_throughput, fault_sweep, fig2_convergence,
                            kernel_bench, noise_sweep, population_scale,
                            privacy_epsilon, roofline_report,
                            telemetry_overhead)
    benches = {
        "fig2_convergence": fig2_convergence.run,     # paper Fig. 2
        "noise_sweep": noise_sweep.run,               # Fig. 2 right, extended
        "privacy_epsilon": privacy_epsilon.run,       # Theorem 2
        "fault_sweep": fault_sweep.run,               # resilience runtime
        "population_scale": population_scale.run,     # virtual-K engine
        "async_throughput": async_throughput.run,     # event-driven engine
        "kernel_bench": kernel_bench.run,             # Pallas kernels
        "kernel_round": kernel_bench.run_round,       # fused round pipeline
                                                      # (writes BENCH_kernels)
        "roofline_report": roofline_report.run,       # deliverable (g)
        "telemetry_overhead": telemetry_overhead.run,  # docs/observability.md
                                                       # (writes BENCH_telemetry)
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    print("name,value,seconds")
    failures = 0
    for name, fn in benches.items():
        t0 = time.time()
        try:
            rows = fn(quick=args.quick)
            dt = time.time() - t0
            for metric, val in rows:
                print(f"{metric},{val:.6g},{dt:.1f}")
        except Exception:
            failures += 1
            print(f"{name},FAILED,{time.time()-t0:.1f}", file=sys.stderr)
            traceback.print_exc()
    # refresh the BENCH_index.json catalog over whatever landed on disk
    from benchmarks.meta import write_index
    write_index()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
