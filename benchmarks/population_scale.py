"""Population-scale benchmark: virtual K sweep at fixed cohort size L.

The point of the population engine (repro/core/population/) is that K is a
*virtual* quantity: memory and compute scale with the sampled cohort
[P, L], not the population [P, K].  This sweep makes that measurable — for
K in {50, 1e3, 1e5} at fixed L it reports

  * client-steps/sec (throughput of the whole-run lax.scan executor), and
  * peak live device bytes (sampled per round on the streaming loop),

and ASSERTS the bounded-memory claim at every K: peak live bytes stay
below what one dense ``[P, K, N, M]`` float32 tensor alone would cost (the
dense simulator materializes exactly that tensor before the first round),
with a fixed small allowance so tiny-K rows — where the dense tensor is
smaller than baseline jit scratch — remain checkable.

    PYTHONPATH=src python benchmarks/population_scale.py            # full
    PYTHONPATH=src python benchmarks/population_scale.py --reduced  # CI smoke

Writes the repo-root ``BENCH_population.json`` (the first datapoint of the
perf trajectory) and prints ``name,value`` rows for the harness
(benchmarks/run.py).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs.base import GFLConfig
from repro.core.population import (
    SyntheticPopulation,
    estimate_w_ref,
    run_gfl_population,
)

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_population.json")

VIRTUAL_KS = (50, 1_000, 100_000)
_OVERHEAD_BYTES = 8 * 2**20   # runtime-buffer allowance for the tiny-K rows


def live_bytes() -> int:
    """Total bytes of live jax device buffers."""
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.live_arrays())


def bench_one(K: int, *, P: int, L: int, N: int, iters: int,
              batch_size: int, mem_rounds: int = 3) -> dict:
    """Throughput (scan executor) + peak-memory (streaming loop) at one K."""
    pop = SyntheticPopulation(P, K, mode="hetero", N=N, M=2, data_seed=0)
    cfg = GFLConfig(num_servers=P, clients_per_server=K, clients_sampled=L,
                    topology="ring", privacy="hybrid", sigma_g=0.2, mu=0.1,
                    grad_bound=10.0)
    dense_bytes = P * K * N * 2 * 4  # the [P, K, N, M] f32 the dense path holds

    # memory: stream a few rounds by hand, sampling live bytes while the
    # cohort batch and the state are both in flight (run_gfl_population
    # frees its intermediates before returning, which would under-report)
    import jax.numpy as jnp

    from repro.core import gfl
    from repro.core.population import uniform_cohort_batch
    from repro.core.simulate import base_combination_matrix, make_grad_fn

    step = gfl.make_gfl_step(
        jnp.asarray(base_combination_matrix(cfg, P)), make_grad_fn(pop.rho),
        cfg)
    sample = jax.jit(
        lambda k: uniform_cohort_batch(k, pop, min(L, K), batch_size))
    key = jax.random.PRNGKey(0)  # fixed bench seed: reproducible trajectory  # gflint: disable=GFL001
    key, k_init = jax.random.split(key)
    state = gfl.init_state(k_init, P, pop.dim)
    peak = live_bytes()
    for _ in range(mem_rounds):
        key, kb = jax.random.split(key)
        batch = sample(kb)
        jax.block_until_ready(batch)
        peak = max(peak, live_bytes())
        state = step(state, batch)
        jax.block_until_ready(state.params)
        peak = max(peak, live_bytes())
    del batch, state
    # asserted at EVERY K: below the dense [P, K, N, M] tensor, with a
    # fixed overhead allowance for runtime buffers so tiny-K rows (where
    # the dense tensor is smaller than baseline jit scratch) stay checkable
    budget = max(dense_bytes, _OVERHEAD_BYTES)
    assert peak < budget, (
        f"population engine peaked at {peak} live bytes for K={K} — "
        f"above the {budget}-byte budget (dense [P, K, N, M] equivalent "
        f"{dense_bytes}); it is supposed to never materialize the "
        "population")

    # throughput: reference minimizer solved OUTSIDE the timed region
    # (run_gfl_population would otherwise Monte-Carlo one on first use),
    # then one compile (warmup) + timed scan run
    w_ref = estimate_w_ref(pop, sample_clients=8, iters=200)
    run_gfl_population(pop, cfg, iters=2, batch_size=batch_size, seed=0,
                       scan=True, w_ref=w_ref)
    t0 = time.time()
    res = run_gfl_population(pop, cfg, iters=iters, batch_size=batch_size,
                             seed=0, scan=True, w_ref=w_ref)
    jax.block_until_ready(res.params)
    dt = time.time() - t0
    return {
        "virtual_K": K, "P": P, "L": L, "N": N, "iters": iters,
        "batch_size": batch_size,
        "client_steps_per_sec": P * L * iters / dt,
        "seconds": dt,
        "peak_live_bytes": int(peak),
        "dense_equiv_bytes": int(dense_bytes),
        "q": L / K,
    }


def run(quick: bool = False, reduced: bool = False, iters: int | None = None,
        P: int = 8, L: int = 10, N: int = 100, batch_size: int = 10):
    if quick or reduced:
        P, L, N = 4, 5, 50
        iters = 20 if iters is None else iters   # explicit --iters wins
    iters = 100 if iters is None else iters
    rows = [bench_one(K, P=P, L=min(L, K), N=N, iters=iters,
                      batch_size=batch_size) for K in VIRTUAL_KS]

    from benchmarks.meta import write_bench
    write_bench(OUT, {"benchmark": "population_scale",
                      "reduced": bool(quick or reduced),
                      "rows": rows},
                headline={
                    # largest-K row: the scaling claim the bench exists for
                    "client_steps_per_sec":
                        ("higher", rows[-1]["client_steps_per_sec"]),
                    "peak_live_bytes":
                        ("lower", float(rows[-1]["peak_live_bytes"]), 0.10),
                })

    out = []
    for r in rows:
        tag = f"K{r['virtual_K']:.0e}".replace("e+0", "e")
        out.append((f"population_scale/{tag}_client_steps_per_sec",
                    r["client_steps_per_sec"]))
        out.append((f"population_scale/{tag}_peak_live_mb",
                    r["peak_live_bytes"] / 2**20))
    # the headline scaling claim: going 50 -> 1e5 virtual clients must not
    # blow up memory (dense would grow 2000x)
    out.append(("population_scale/peak_mb_ratio_K1e5_vs_K50",
                rows[-1]["peak_live_bytes"] / max(rows[0]["peak_live_bytes"],
                                                  1)))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke: fewer iters, smaller P/L/N (virtual K "
                         "sweep unchanged — that is the point)")
    ap.add_argument("--iters", type=int, default=None,
                    help="rounds per K (default: 100 full / 20 reduced)")
    args = ap.parse_args(argv)
    for name, val in run(iters=args.iters, reduced=args.reduced):
        print(f"{name},{val:.6g}")


if __name__ == "__main__":
    main()
