"""Aggregate dry-run JSONs into the §Roofline table (markdown + CSV).

Also reconciles the *measured* side of the roofline: when the repo-root
``BENCH_kernels.json`` (written by ``benchmarks/kernel_bench.py``)
carries achieved-GB/s / roofline-fraction columns, ``run()`` emits one
``roofline/kernel_*`` row per pipeline mode so the analytic table and
the measured kernel trajectory land in the same report.
"""
from __future__ import annotations

import csv
import glob
import json
import os

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "dryrun_results")
OUT = os.path.join(HERE, "results")
REPO_ROOT = os.path.dirname(HERE)
KERNELS_JSON = os.path.join(REPO_ROOT, "BENCH_kernels.json")

COLS = ["arch", "shape", "mesh", "combine", "kind", "chips",
        "compute_s", "memory_s", "collective_s", "bottleneck",
        "model_flops", "hlo_flops", "useful_flop_frac", "collective_bytes"]


def load_records():
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = False):
    recs = load_records()
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "roofline.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(COLS + ["skip"])
        for r in recs:
            if "skip" in r:
                w.writerow([r.get("arch"), r.get("shape"), r.get("mesh"),
                            r.get("combine"), "", "", "", "", "", "", "", "",
                            "", "", r["skip"]])
            else:
                w.writerow([r.get(c, "") for c in COLS] + [""])
    base = {}
    for r in recs:  # dedupe: one baseline per (arch, shape, mesh)
        if r.get("variant"):
            continue
        base.setdefault((r.get("arch"), r.get("shape"), r.get("mesh")), r)
    base = list(base.values())
    ok = [r for r in base if "skip" not in r]
    skips = [r for r in base if "skip" in r]
    bottl = {}
    for r in ok:
        bottl[r["bottleneck"]] = bottl.get(r["bottleneck"], 0) + 1
    out = [("roofline/num_compiled", len(ok)),
           ("roofline/num_skipped", len(skips))]
    out += [(f"roofline/bottleneck_{k}", v) for k, v in sorted(bottl.items())]
    out += kernel_rows()
    return out


def kernel_rows(path: str = KERNELS_JSON):
    """Measured-kernel reconciliation rows from BENCH_kernels.json (empty
    when the kernel bench has not run or predates the roofline columns)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return []
    rows = []
    for r in doc.get("rows", []):
        mode = r.get("mode")
        if mode is None or "achieved_gbps_pallas" not in r:
            continue
        rows += [
            (f"roofline/kernel_{mode}_achieved_gbps",
             r["achieved_gbps_pallas"]),
            (f"roofline/kernel_{mode}_roofline_frac",
             r["roofline_frac_pallas"]),
            (f"roofline/kernel_{mode}_hbm_ratio", r["hbm_ratio"]),
        ]
    return rows


def markdown_table(mesh="16x16", combine=None) -> str:
    recs = [r for r in load_records()
            if r.get("mesh", mesh) == mesh
            and (combine is None or r.get("combine") in (combine, None))]
    lines = ["| arch | shape | compute_s | memory_s | collective_s | "
             "bottleneck | useful_flops | note |",
             "|---|---|---|---|---|---|---|---|"]
    seen = set()
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| SKIP: {r['skip']} |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
                f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
                f"**{r['bottleneck']}** | {r['useful_flop_frac']:.3f} | |")
    return "\n".join(lines)


if __name__ == "__main__":
    for name, val in run():
        print(f"{name},{val}")
    print()
    print(markdown_table())
