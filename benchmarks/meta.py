"""Shared benchmark provenance: one metadata block for every BENCH_*.json.

Every benchmark that persists a repo-root ``BENCH_<name>.json`` routes its
payload through :func:`write_bench` (gflint GFL007 enforces the routing),
which stamps a common ``meta`` block (host, backend, jax/jaxlib versions,
git sha, timestamp) so perf trajectories across commits stay attributable
to the machine and revision that produced them.

Benchmarks additionally declare their **headline metrics** — name,
value, direction (``higher``/``lower`` is better) and optionally a
per-metric relative tolerance — and every :func:`write_bench` call
appends one compact record (headline + provenance) to the append-only
``BENCH_history.jsonl``, keyed by ``(benchmark, git_sha, timestamp)``.
``benchmarks/compare.py`` diffs the current payloads against the last
same-backend history entry and gates CI on regressions;
``python -m repro.telemetry.inspect bench`` renders the trends.

:func:`write_index` scans the repo root and rebuilds ``BENCH_index.json``
— the one-stop catalog (now carrying each benchmark's headline values,
so the index doubles as a one-file perf snapshot).
"""
from __future__ import annotations

import json
import platform
# git-provenance capture only (rev-parse/diff-index); no delivery path,
# nothing for the fleet transport layer to own
import subprocess  # gflint: disable=GFL008
import sys
import time
from pathlib import Path
from typing import Mapping, Optional

REPO_ROOT = Path(__file__).resolve().parents[1]
HISTORY = REPO_ROOT / "BENCH_history.jsonl"

_DIRECTIONS = ("higher", "lower")


def _git_sha() -> str:
    try:
        proc = subprocess.run(["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
                              capture_output=True, text=True, timeout=10)
        sha = proc.stdout.strip()
        return sha if proc.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


def bench_metadata() -> dict:
    """The provenance block stamped into every benchmark payload."""
    import jax
    import jaxlib
    return {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "git_sha": _git_sha(),
        "host": platform.node(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kinds": sorted({d.device_kind for d in jax.devices()}),
    }


def normalize_headline(headline: Optional[Mapping]) -> dict:
    """Headline declarations -> the canonical stored form.

    Accepts ``{name: (direction, value[, rel_tol])}`` tuples or already-
    canonical ``{name: {"value": v, "direction": d[, "tol": t]
    [, "abs_tol": a]}}`` dicts (``abs_tol`` is an absolute slack for
    metrics that live near zero, where relative tolerances degenerate).
    """
    out = {}
    for name, decl in (headline or {}).items():
        if isinstance(decl, Mapping):
            entry = {"value": float(decl["value"]),
                     "direction": str(decl["direction"])}
            if decl.get("tol") is not None:
                entry["tol"] = float(decl["tol"])
            if decl.get("abs_tol") is not None:
                entry["abs_tol"] = float(decl["abs_tol"])
        else:
            direction, value, *tol = decl
            entry = {"value": float(value), "direction": str(direction)}
            if tol:
                entry["tol"] = float(tol[0])
        if entry["direction"] not in _DIRECTIONS:
            raise ValueError(
                f"headline metric {name!r}: direction must be one of "
                f"{_DIRECTIONS}, got {entry['direction']!r}")
        out[name] = entry
    return out


def write_bench(path, payload: dict, *, headline: Optional[Mapping] = None,
                history: Optional[Path] = None) -> Path:
    """Write one BENCH_*.json with the shared ``meta`` block attached and
    append the compact headline+provenance record to BENCH_history.jsonl.

    ``headline`` maps metric name -> ``(direction, value[, rel_tol])``
    (direction ``"higher"``/``"lower"`` = which way is better; the
    optional relative tolerance overrides compare.py's noise-derived
    default for deterministic metrics).
    """
    path = Path(path)
    payload = dict(payload)
    payload.setdefault("meta", bench_metadata())
    if headline is not None:
        payload["headline"] = normalize_headline(headline)
    path.write_text(json.dumps(payload, indent=2) + "\n")

    meta = payload["meta"]
    record = {
        "benchmark": (payload.get("benchmark") or payload.get("bench")
                      or path.stem),
        "file": path.name,
        "git_sha": meta.get("git_sha"),
        "timestamp": meta.get("timestamp"),
        "backend": meta.get("backend"),
        "host": meta.get("host"),
        "reduced": payload.get("reduced"),
        "repeats": payload.get("repeats"),
        "headline": payload.get("headline", {}),
    }
    history = Path(history) if history is not None else HISTORY
    with open(history, "a", encoding="utf-8") as fh:
        fh.write(json.dumps(record) + "\n")
    return path


def write_index(root=REPO_ROOT) -> Path:
    """Rebuild BENCH_index.json from the BENCH_*.json files under `root`."""
    root = Path(root)
    entries = []
    for f in sorted(root.glob("BENCH_*.json")):
        if f.name == "BENCH_index.json":
            continue
        try:
            doc = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError):
            entries.append({"file": f.name, "error": "unreadable"})
            continue
        meta = doc.get("meta", {})
        entries.append({
            "file": f.name,
            "benchmark": doc.get("benchmark") or doc.get("bench") or f.stem,
            "reduced": doc.get("reduced"),
            "git_sha": meta.get("git_sha"),
            "timestamp": meta.get("timestamp"),
            "backend": meta.get("backend"),
            # declared headline metric values: the index doubles as a
            # one-file perf snapshot
            "headline": {name: decl.get("value")
                         for name, decl in doc.get("headline", {}).items()},
        })
    out = root / "BENCH_index.json"
    out.write_text(json.dumps({"benchmarks": entries,
                               "meta": bench_metadata()}, indent=2) + "\n")
    return out
