"""Shared benchmark provenance: one metadata block for every BENCH_*.json.

Every benchmark that persists a repo-root ``BENCH_<name>.json`` routes its
payload through :func:`write_bench`, which stamps a common ``meta`` block
(host, backend, jax/jaxlib versions, git sha, timestamp) so perf
trajectories across commits stay attributable to the machine and revision
that produced them.  :func:`write_index` scans the repo root and rebuilds
``BENCH_index.json`` — the one-stop catalog the CI artifacts and the docs
link to.
"""
from __future__ import annotations

import json
import platform
import subprocess
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def _git_sha() -> str:
    try:
        proc = subprocess.run(["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
                              capture_output=True, text=True, timeout=10)
        sha = proc.stdout.strip()
        return sha if proc.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


def bench_metadata() -> dict:
    """The provenance block stamped into every benchmark payload."""
    import jax
    import jaxlib
    return {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "git_sha": _git_sha(),
        "host": platform.node(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kinds": sorted({d.device_kind for d in jax.devices()}),
    }


def write_bench(path, payload: dict) -> Path:
    """Write one BENCH_*.json with the shared ``meta`` block attached."""
    path = Path(path)
    payload = dict(payload)
    payload.setdefault("meta", bench_metadata())
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def write_index(root=REPO_ROOT) -> Path:
    """Rebuild BENCH_index.json from the BENCH_*.json files under `root`."""
    root = Path(root)
    entries = []
    for f in sorted(root.glob("BENCH_*.json")):
        if f.name == "BENCH_index.json":
            continue
        try:
            doc = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError):
            entries.append({"file": f.name, "error": "unreadable"})
            continue
        meta = doc.get("meta", {})
        entries.append({
            "file": f.name,
            "benchmark": doc.get("benchmark") or doc.get("bench") or f.stem,
            "reduced": doc.get("reduced"),
            "git_sha": meta.get("git_sha"),
            "timestamp": meta.get("timestamp"),
            "backend": meta.get("backend"),
        })
    out = root / "BENCH_index.json"
    out.write_text(json.dumps({"benchmarks": entries,
                               "meta": bench_metadata()}, indent=2) + "\n")
    return out
