"""Telemetry overhead: population CPU smoke, telemetry on vs off.

The observability contract (docs/observability.md) is two-sided:
``telemetry=off`` must be bit-identical to an uninstrumented run (a
regression test owns that half), and ``telemetry=on`` must stay cheap
enough to leave enabled on real runs.  This bench measures the second
half: the same population-engine scan run — the executor with the
densest in-graph tap — timed with telemetry off, with the default
per-round ordered ``io_callback`` flush (``flush_every=1``), and with
the buffered flush (``REPRO_TELEMETRY_FLUSH_EVERY=8`` — one callback
per 8 rounds).  Best-of-N wall clock per arm, compile excluded via a
warmup run.

Writes the repo-root ``BENCH_telemetry.json`` and prints ``name,value``
rows; the measured overhead_pct numbers are what docs/observability.md
quotes (acceptance: < 15% on the CPU smoke).
"""
from __future__ import annotations

import os
import time

import jax

from benchmarks.meta import REPO_ROOT, write_bench
from repro.configs.base import GFLConfig
from repro.core.population import (
    SyntheticPopulation,
    estimate_w_ref,
    run_gfl_population,
)

OUT = REPO_ROOT / "BENCH_telemetry.json"
BUFFERED_FLUSH_EVERY = 8


def _time_arm(pop, cfg, *, iters, batch_size, w_ref, repeats):
    """Best-of-`repeats` wall seconds for one telemetry arm (post-warmup)."""
    run_gfl_population(pop, cfg, iters=iters, batch_size=batch_size,
                       seed=0, scan=True, w_ref=w_ref)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        res = run_gfl_population(pop, cfg, iters=iters,
                                 batch_size=batch_size, seed=0, scan=True,
                                 w_ref=w_ref)
        jax.block_until_ready(res.params)
        best = min(best, time.time() - t0)
    return best


def run(quick: bool = False):
    P, K, L = 4, 50, 5
    N = 50
    iters = 30 if quick else 100
    repeats = 3 if quick else 5
    batch_size = 10

    pop = SyntheticPopulation(P, K, mode="hetero", N=N, M=2, data_seed=0)
    base = dict(num_servers=P, clients_per_server=K, clients_sampled=L,
                topology="ring", privacy="hybrid", sigma_g=0.2, mu=0.1,
                grad_bound=10.0)
    w_ref = estimate_w_ref(pop, sample_clients=8, iters=200)

    off_s = _time_arm(pop, GFLConfig(**base, telemetry="off"),
                      iters=iters, batch_size=batch_size, w_ref=w_ref,
                      repeats=repeats)
    on_s = _time_arm(pop, GFLConfig(**base, telemetry="memory"),
                     iters=iters, batch_size=batch_size, w_ref=w_ref,
                     repeats=repeats)
    prev_env = os.environ.get("REPRO_TELEMETRY_FLUSH_EVERY")
    os.environ["REPRO_TELEMETRY_FLUSH_EVERY"] = str(BUFFERED_FLUSH_EVERY)
    try:
        buf_s = _time_arm(pop, GFLConfig(**base, telemetry="memory"),
                          iters=iters, batch_size=batch_size, w_ref=w_ref,
                          repeats=repeats)
    finally:
        if prev_env is None:
            del os.environ["REPRO_TELEMETRY_FLUSH_EVERY"]
        else:
            os.environ["REPRO_TELEMETRY_FLUSH_EVERY"] = prev_env
    overhead_pct = 100.0 * (on_s - off_s) / off_s
    overhead_buf_pct = 100.0 * (buf_s - off_s) / off_s

    write_bench(OUT, {
        "benchmark": "telemetry_overhead",
        "reduced": bool(quick),
        "P": P, "K": K, "L": L, "N": N, "iters": iters,
        "repeats": repeats, "batch_size": batch_size,
        "off_seconds": off_s, "on_seconds": on_s,
        "buffered_seconds": buf_s,
        "overhead_pct": overhead_pct,
        "overhead_buffered_pct": overhead_buf_pct,
        "flush_every_buffered": BUFFERED_FLUSH_EVERY,
        "sink": "memory",
        "note": ("population scan executor; the on arm flushes one "
                 "ordered io_callback per round into a memory sink, the "
                 "buffered arm batches 8 rounds per callback "
                 "(REPRO_TELEMETRY_FLUSH_EVERY)"),
    }, headline={
        # overhead is a small difference of two noisy timings that can
        # sit near (or even below) zero on a loaded host, so an absolute
        # slack in percentage points is the only stable gate — wide
        # enough to absorb timer noise either side of zero, tight enough
        # to catch a catastrophic (>20-point) regression (the hard
        # < 15% acceptance lives in docs/observability.md)
        "overhead_pct": {"value": overhead_pct, "direction": "lower",
                         "abs_tol": 20.0},
        "overhead_buffered_pct": {"value": overhead_buf_pct,
                                  "direction": "lower", "abs_tol": 20.0},
    })

    return [("telemetry_overhead/off_s", off_s),
            ("telemetry_overhead/on_s", on_s),
            ("telemetry_overhead/buffered_s", buf_s),
            ("telemetry_overhead/overhead_pct", overhead_pct),
            ("telemetry_overhead/overhead_buffered_pct", overhead_buf_pct)]


if __name__ == "__main__":
    for name, val in run(quick=True):
        print(f"{name},{val:.4g}")
    print(f"wrote {OUT}")
