"""Paper Figure 2 reproduction: MSD of the network centroid vs iteration for
non-private / iid-DP / hybrid GFL, at the paper's noise level (sigma=0.2) and
at an increased level where iid-DP degrades but the hybrid scheme does not.

Paper settings: P=10 servers, K=50 clients, M=2 logistic regression,
mu=0.1, rho=0.01.
"""
from __future__ import annotations

import csv
import os

import jax
import numpy as np

from repro.core.simulate import run_schemes

OUT = os.path.join(os.path.dirname(__file__), "results")


def run(iters: int = 400, repeats: int = 2, quick: bool = False):
    if quick:
        iters, repeats = 120, 1
    rows = []
    summary = []
    for sigma in (0.2, 1.0):
        prob, msd = run_schemes(jax.random.PRNGKey(0), iters=iters,  # fixed bench seed: reproducible trajectory  # gflint: disable=GFL001
                                sigma_g=sigma, P=10, K=50, L=10,
                                mu=0.1, repeats=repeats, topology="full")
        for scheme, trace in msd.items():
            for i, v in enumerate(trace):
                rows.append((sigma, scheme, i, v))
            tail = float(np.mean(trace[-max(iters // 10, 5):]))
            summary.append((f"fig2_msd_tail/sigma={sigma}/{scheme}", tail))
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "fig2_convergence.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["sigma_g", "scheme", "iter", "msd"])
        w.writerows(rows)
    return summary


if __name__ == "__main__":
    for name, val in run():
        print(f"{name},{val:.6g}")
